"""Table 2 reproduction: cycle counts for every (scheme x D x kernel) cell,
homogeneous + composite workloads, vs the paper's published values.

Homogeneous cells run through ``homogeneous_cycles`` (a KviWorkload per
cell through ``CycleSimBackend.run_workload``); the composite table
builds ONE composite KviWorkload — conv32 / fft256 / matmul64 pinned to
harts 0/1/2 — and times all six (scheme, D) cells in a single
``run_workload`` call (the kernel programs are config-independent here).
"""
from __future__ import annotations

import numpy as np

from benchmarks.paper_data import (CLAIMS, TABLE2_BASELINES,
                                   TABLE2_COMPOSITE, TABLE2_HOMOGENEOUS,
                                   make_config)
from repro.core.baselines import baseline_cycles
from repro.core.workloads import (BASELINE_ARGS, COMPOSITE_KERNELS,
                                  composite_workload, homogeneous_cycles)

KERNELS = ("conv4", "conv8", "conv16", "conv32", "fft256", "matmul64")


def run(emit) -> dict:
    sim_homog = {}
    ratios = []
    emit("# --- Table 2: homogeneous workload (sim vs paper, ratio) ---")
    for (scheme, D), paper_vals in TABLE2_HOMOGENEOUS.items():
        cfg = make_config(scheme, D)
        row = {}
        parts = []
        for k in KERNELS:
            sim = homogeneous_cycles(cfg, k)["avg_cycles"]
            row[k] = sim
            if k in paper_vals:
                r = sim / paper_vals[k]
                ratios.append(r)
                parts.append(f"{k}={sim:.0f}/{paper_vals[k]}({r:.2f})")
        sim_homog[(scheme, D)] = row
        emit(f"{scheme:8s} D={D}: " + " ".join(parts))

    emit("# --- Table 2: baseline cores (analytic models vs paper) ---")
    for core, vals in TABLE2_BASELINES.items():
        parts = []
        for k in KERNELS:
            kind, kw = BASELINE_ARGS[k]
            sim = baseline_cycles(core, kind, **kw)
            parts.append(f"{k}={sim}/{vals[k]}({sim / vals[k]:.2f})")
        emit(f"{core:14s}: " + " ".join(parts))

    emit("# --- Table 2: composite workload ---")
    emit("# (the paper's composite normalization is not fully specified; we")
    emit("#  report per-hart latency/instance and validate the SCHEME")
    emit("#  ORDERING + het-vs-sym closeness, which are the paper's claims)")
    from repro.kvi.cyclesim import CycleSimBackend
    comp_cells = [("SISD", 1), ("SIMD", 8), ("SymMIMD", 1),
                  ("SymMIMD", 8), ("HetMIMD", 1), ("HetMIMD", 8)]
    comp_cfgs = {cell: make_config(*cell) for cell in comp_cells}
    reps = {"conv32": 6, "fft256": 6, "matmul64": 1}
    wl = composite_workload(comp_cfgs[comp_cells[0]], reps)
    comp_res = CycleSimBackend(
        schemes={f"{s} D={D}": comp_cfgs[(s, D)] for s, D in comp_cells}
    ).run_workload(wl, functional=False)
    sim_comp = {}
    for (scheme, D) in comp_cells:
        sim = comp_res.timing[f"{scheme} D={D}"]
        r = {k: sim.per_hart[h].finish_cycle / reps[k]
             for h, k in enumerate(COMPOSITE_KERNELS)}
        r["total_cycles"] = sim.cycles
        sim_comp[(scheme, D)] = r
        p = TABLE2_COMPOSITE[(scheme, D)]
        emit(f"{scheme:8s} D={D}: " + " ".join(
            f"{k}={r[k]:.0f} (paper {p[k]})"
            for k in ("conv32", "fft256", "matmul64")))
    comp_order_ok = all(
        sim_comp[("SymMIMD", 8)][k] <= sim_comp[("SymMIMD", 1)][k] and
        sim_comp[("SymMIMD", 8)][k] <= sim_comp[("SISD", 1)][k]
        for k in ("conv32", "fft256", "matmul64"))
    het_comp = max(sim_comp[("HetMIMD", 8)][k] / sim_comp[("SymMIMD", 8)][k]
                   for k in ("conv32", "fft256", "matmul64"))

    # ---- headline-claim checks (the paper's 3x/13x/9x/19x are conv-based)
    checks = {}
    t03_small = baseline_cycles("klessydra-t03", "conv", S=4)
    best_small = min(v["conv4"] for v in sim_homog.values())
    checks["small_conv_speedup_vs_t03"] = t03_small / best_small
    t03_c = baseline_cycles("klessydra-t03", "conv", S=32)
    best_c = min(v["conv32"] for v in sim_homog.values())
    checks["large_speedup_vs_t03"] = t03_c / best_c
    checks["large_speedup_vs_zeroriscy"] = \
        baseline_cycles("zeroriscy", "conv", S=32) / best_c
    checks["large_speedup_vs_ri5cy"] = \
        baseline_cycles("ri5cy", "conv", S=32) / best_c
    checks["composite_ordering_ok"] = comp_order_ok
    checks["composite_het_vs_sym_max"] = het_comp
    het_sym = []
    for D in (1, 2, 4, 8):
        for k in KERNELS:
            het_sym.append(sim_homog[("HetMIMD", D)][k] /
                           sim_homog[("SymMIMD", D)][k])
    checks["het_vs_sym_median_pct"] = 100 * (float(np.median(het_sym)) - 1)
    checks["fit_geomean_ratio"] = float(np.exp(np.mean(np.log(ratios))))

    emit("# --- headline claims (paper -> ours) ---")
    emit(f"small conv speedup vs T03:   paper up to "
         f"{CLAIMS['small_conv_speedup_vs_t03']}x, ours "
         f"{checks['small_conv_speedup_vs_t03']:.1f}x")
    emit(f"large kernel speedup vs T03: paper {CLAIMS['large_speedup_vs_t03']}x, "
         f"ours {checks['large_speedup_vs_t03']:.1f}x")
    emit(f"vs RI5CY: paper {CLAIMS['large_speedup_vs_ri5cy']}x, ours "
         f"{checks['large_speedup_vs_ri5cy']:.1f}x; vs ZeroRiscy: paper "
         f"{CLAIMS['large_speedup_vs_zeroriscy']}x, ours "
         f"{checks['large_speedup_vs_zeroriscy']:.1f}x")
    emit(f"het vs sym median overhead: paper 1-7%, ours "
         f"{checks['het_vs_sym_median_pct']:.1f}% (composite max "
         f"{100 * (het_comp - 1):.1f}%)")
    emit(f"composite scheme ordering reproduced: {comp_order_ok}")
    emit(f"overall cell fit geomean(sim/paper) = "
         f"{checks['fit_geomean_ratio']:.2f}")
    return {"homogeneous": sim_homog, "composite": sim_comp,
            "checks": checks}
