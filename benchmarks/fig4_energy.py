"""Figure 4 reproduction: average energy per algorithmic operation,
normalized to ZeroRiscy. Energy model: E ∝ (LUT + 2FF) x cycles (dynamic
power proxy from the paper's own synthesis table; frequency cancels).
"""
from __future__ import annotations

from benchmarks.paper_data import make_config
from repro.core.baselines import baseline_cycles, synthesis_for
from repro.core.workloads import (BASELINE_ARGS, KERNEL_BUILDERS,
                                  homogeneous_cycles)

KERNELS = ("conv32", "fft256", "matmul64")
SCHEMES = [("SISD", 1), ("SIMD", 8), ("SymMIMD", 1), ("SymMIMD", 8),
           ("HetMIMD", 1), ("HetMIMD", 8)]

ALG_OPS = {"conv4": 2 * 4 * 4 * 9, "conv8": 2 * 8 * 8 * 9,
           "conv16": 2 * 16 * 16 * 9, "conv32": 2 * 32 * 32 * 9,
           "fft256": 10 * 128 * 8, "matmul64": 2 * 64 ** 3}


def _energy_per_op(scheme_name: str, D: int, cycles: float, kernel: str):
    ff, lut, _ = synthesis_for(scheme_name, D)
    return (lut + 2.0 * ff) * cycles / ALG_OPS[kernel]


def run(emit) -> dict:
    zr = {}
    for k in KERNELS:
        kind, kw = BASELINE_ARGS[k]
        cyc = baseline_cycles("zeroriscy", kind, **kw)
        zr[k] = _energy_per_op("zeroriscy", 0, cyc, k)
    emit("# --- Fig 4: energy/op relative to ZeroRiscy (lower=better) ---")
    emit(f"{'scheme':14s} " + " ".join(f"{k:>9s}" for k in KERNELS))
    out = {}
    best_saving = 0.0
    for scheme, D in SCHEMES:
        cfg = make_config(scheme, D)
        row = {}
        for k in KERNELS:
            cyc = homogeneous_cycles(cfg, k)["avg_cycles"]
            e = _energy_per_op(cfg.scheme, D, cyc, k)
            row[k] = e / zr[k]
            best_saving = max(best_saving, 100 * (1 - row[k]))
        out[f"{scheme}-D{D}"] = row
        emit(f"{scheme + f' D={D}':14s} " +
             " ".join(f"{row[k]:9.3f}" for k in KERNELS))
    for core in ("klessydra-t03", "ri5cy"):
        row = {}
        for k in KERNELS:
            kind, kw = BASELINE_ARGS[k]
            cyc = baseline_cycles(core, kind, **kw)
            row[k] = _energy_per_op(core, 0, cyc, k) / zr[k]
        out[core] = row
        emit(f"{core:14s} " + " ".join(f"{row[k]:9.3f}" for k in KERNELS))
    out["checks"] = {"best_saving_pct": best_saving}
    emit(f"# best energy saving vs ZeroRiscy: {best_saving:.0f}% "
         f"(paper: >85%)")
    return out
