"""Figure 3 reproduction: absolute execution-time speedup vs ZeroRiscy at
each core's maximum frequency (our simulated cycles x the paper's published
f_max from its synthesis table).
"""
from __future__ import annotations

from benchmarks.paper_data import make_config
from repro.core.baselines import baseline_cycles, synthesis_for
from repro.core.workloads import BASELINE_ARGS, homogeneous_cycles

KERNELS = ("conv4", "conv32", "fft256", "matmul64")
SCHEMES = [("SISD", 1), ("SIMD", 2), ("SIMD", 8),
           ("SymMIMD", 1), ("SymMIMD", 2), ("SymMIMD", 8),
           ("HetMIMD", 2), ("HetMIMD", 8)]


def exec_time_us(scheme: str, D: int, cycles: float) -> float:
    _, _, fmax = synthesis_for(scheme if D else scheme, D)
    return cycles / fmax


def run(emit) -> dict:
    # ZeroRiscy reference times
    zr = {}
    for k in KERNELS:
        kind, kw = BASELINE_ARGS[k]
        cycles = baseline_cycles("zeroriscy", kind, **kw)
        _, _, fmax = synthesis_for("zeroriscy", 0)
        zr[k] = cycles / fmax
    emit("# --- Fig 3: execution-time speedup vs ZeroRiscy @ f_max ---")
    emit(f"{'scheme':14s} " + " ".join(f"{k:>9s}" for k in KERNELS))
    out = {}
    best = {k: 0.0 for k in KERNELS}
    for scheme, D in SCHEMES:
        cfg = make_config(scheme, D)
        key = {"SISD": "SISD", "SIMD": "SIMD", "SymMIMD": "SymMIMD",
               "HetMIMD": "HetMIMD"}[scheme]
        sname = cfg.scheme
        row = {}
        for k in KERNELS:
            cyc = homogeneous_cycles(cfg, k)["avg_cycles"]
            t = exec_time_us(sname, D, cyc)
            row[k] = zr[k] / t
            best[k] = max(best[k], row[k])
        out[f"{scheme}-D{D}"] = row
        emit(f"{scheme + f' D={D}':14s} " +
             " ".join(f"{row[k]:8.1f}x" for k in KERNELS))
    # baselines relative to ZeroRiscy (T03 must beat RI5CY on absolute time)
    for core in ("klessydra-t03", "ri5cy"):
        row = {}
        for k in KERNELS:
            kind, kw = BASELINE_ARGS[k]
            cyc = baseline_cycles(core, kind, **kw)
            _, _, fmax = synthesis_for(core, 0)
            row[k] = zr[k] / (cyc / fmax)
        out[core] = row
        emit(f"{core:14s} " + " ".join(f"{row[k]:8.1f}x" for k in KERNELS))
    out["best"] = best
    checks = {
        "conv32_speedup_max": best["conv32"],
        # "T03 exhibits an absolute performance advantage over RI5CY"
        "t03_beats_ri5cy": all(out["klessydra-t03"][k] > out["ri5cy"][k]
                               for k in KERNELS),
    }
    out["checks"] = checks
    emit(f"# conv32 best speedup vs ZeroRiscy: {best['conv32']:.1f}x "
         f"(paper: up to 17x); T03 faster than RI5CY on all kernels: "
         f"{checks['t03_beats_ri5cy']} (paper: yes)")
    return out
