"""Design-space exploration benchmark: the full sweep -> Pareto story.

Runs :func:`repro.kvi.dse.report.run_dse` (schemes x lanes x sub-word
precision over the paper's conv / fft / matmul kernels plus the
composite workload) and emits ``BENCH_kvi_dse.json`` — per-point cycles
/ area / energy, per-kernel Pareto fronts and speedup-vs-D curves, and
the acceptance checks (sym-MIMD fastest, shared cheapest, het-MIMD on
the front between them; 8-bit >= 2x on the MFU-bound kernels).

``--executor`` selects the sweep executor, ``--measure-pallas`` adds
the real-walltime axis, and ``--check`` additionally regresses the
cost model's CALIBRATION constants against the paper's Table 3
energies (``repro.kvi.dse.cost.calibration_fit``), failing when the
relative fit error exceeds the documented threshold.

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_dse [--smoke]
          [--seed N] [--out PATH] [--executor NAME] [--measure-pallas]
          [--check]
or through the harness:  python -m benchmarks.run --only kvi_dse
"""
from __future__ import annotations

import argparse
import json
import sys


def run(emit, smoke: bool = False, seed: int = 0,
        executor: str = None, measure_pallas: bool = False) -> dict:
    from repro.kvi.dse.cost import calibration_fit
    from repro.kvi.dse.report import run_dse
    result, report = run_dse(smoke=smoke, seed=seed, emit=emit,
                             executor=executor,
                             measure_pallas=measure_pallas)
    report["calibration_fit"] = calibration_fit()
    emit("# --- checks ---")
    for k, v in report["checks"].items():
        emit(f"{k} = {v}")
    fit = report["calibration_fit"]
    emit(f"calibration_fit: max_rel_err={fit['max_rel_err']} "
         f"(threshold {fit['threshold']}) ok={fit['ok']}")
    for kern, data in report["kernels"].items():
        emit(f"{kern}: front={len(data['front'])} points, "
             f"subword_max={data['subword']['max_speedup']}x")
    # compact per-point rows ride along for the perf trajectory
    report["points"] = result.csv_rows()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_dse.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI fast job)")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible inputs)")
    ap.add_argument("--executor", default=None,
                    choices=("serial", "thread", "process"),
                    help="sweep executor (default: threads)")
    ap.add_argument("--measure-pallas", action="store_true",
                    help="add the Pallas walltime axis per point")
    ap.add_argument("--check", action="store_true",
                    help="also fail when the CALIBRATION constants no "
                         "longer fit the paper's Table 3 energies")
    ap.add_argument("--check-only", action="store_true",
                    help="run ONLY the calibration-fit gate (closed-"
                         "form over published Table 3 rows — no sweep) "
                         "and write its result to --out")
    args = ap.parse_args(argv)
    if args.check_only:
        from repro.kvi.dse.cost import calibration_fit
        fit = calibration_fit()
        print(f"calibration_fit: max_rel_err={fit['max_rel_err']} "
              f"(threshold {fit['threshold']}) ok={fit['ok']}")
        with open(args.out, "w") as f:
            json.dump({"calibration_fit": fit}, f, indent=2,
                      sort_keys=True)
        print(f"# wrote {args.out}")
        if not fit["ok"]:                # explicit: survives python -O
            print(f"# FAILED: CALIBRATION drifted out of the paper's "
                  f"Table-3 energy regime: max relative fit error "
                  f"{fit['max_rel_err']} > threshold "
                  f"{fit['threshold']}", file=sys.stderr)
            return 1
        return 0
    result = run(emit=print, smoke=args.smoke, seed=args.seed,
                 executor=args.executor,
                 measure_pallas=args.measure_pallas)
    checks = result["checks"]
    assert checks["all_schemes_covered"], "a scheme produced no points"
    assert checks["pareto_ordering_ok"], "paper scheme ordering broken"
    assert checks["subword_2x_on_mfu_bound"], "sub-word speedup < 2x"
    if args.check:
        fit = result["calibration_fit"]
        if not fit["ok"]:                # explicit: survives python -O
            print(f"# FAILED: CALIBRATION drifted out of the paper's "
                  f"Table-3 energy regime: max relative fit error "
                  f"{fit['max_rel_err']} > threshold "
                  f"{fit['threshold']}", file=sys.stderr)
            return 1
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
