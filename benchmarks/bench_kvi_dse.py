"""Design-space exploration benchmark: the full sweep -> Pareto story.

Runs :func:`repro.kvi.dse.report.run_dse` (schemes x lanes x sub-word
precision over the paper's conv / fft / matmul kernels plus the
composite workload) and emits ``BENCH_kvi_dse.json`` — per-point cycles
/ area / energy, per-kernel Pareto fronts and speedup-vs-D curves, and
the acceptance checks (sym-MIMD fastest, shared cheapest, het-MIMD on
the front between them; 8-bit >= 2x on the MFU-bound kernels).

``--executor`` selects the sweep executor (default ``auto``),
``--measure-pallas`` adds the real-walltime axis, and ``--check``
additionally regresses the cost model's CALIBRATION constants against
the paper's Table 3 energies (``repro.kvi.dse.cost.calibration_fit``),
failing when the relative fit error exceeds the documented threshold.

The benchmark also times the **incremental** path: the sweep runs
twice against one persistent point cache (a throwaway temp directory
unless ``--cache-dir`` pins one) — cold, then warm — and the report
gains a ``cache`` block with hit/miss/invalidation counters and the
measured ``warm_speedup``. The warm re-sweep must be byte-identical to
the cold one and, on the smoke space, at least 10x faster with 100%
point-cache hits.

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_dse [--smoke]
          [--seed N] [--out PATH] [--executor NAME] [--measure-pallas]
          [--cache-dir DIR] [--check]
or through the harness:  python -m benchmarks.run --only kvi_dse
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time

#: the warm re-sweep floor the smoke acceptance gate pins: resolving
#: every point from the store must beat recomputing the space by at
#: least this factor (measured ~50x on the 36-point smoke space; 10x
#: leaves headroom for slow CI runners)
WARM_SPEEDUP_MIN = 10.0


def run(emit, smoke: bool = False, seed: int = 0,
        executor: str = "auto", measure_pallas: bool = False,
        cache_dir: str = None) -> dict:
    from repro.kvi.dse.cost import calibration_fit
    from repro.kvi.dse.pointcache import PointCache
    from repro.kvi.dse.report import run_dse
    tmp = None
    if cache_dir is None:
        tmp = cache_dir = tempfile.mkdtemp(prefix="bench_dse_cache_")
    try:
        t0 = time.perf_counter()
        cold_cache = PointCache(cache_dir=cache_dir)
        result, report = run_dse(smoke=smoke, seed=seed, emit=emit,
                                 executor=executor,
                                 measure_pallas=measure_pallas,
                                 cache=cold_cache)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm_cache = PointCache(cache_dir=cache_dir)
        warm_result, _ = run_dse(smoke=smoke, seed=seed,
                                 emit=lambda s: None,
                                 executor=executor,
                                 measure_pallas=measure_pallas,
                                 cache=warm_cache)
        warm_s = time.perf_counter() - t0
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)
    stats = warm_cache.stats
    report["cache"] = {
        "cold_s": round(cold_s, 3), "warm_s": round(warm_s, 3),
        "warm_speedup": round(cold_s / max(warm_s, 1e-9), 1),
        "hits": stats["hits"], "misses": stats["misses"],
        "invalidations": stats["invalidations"],
        "pallas_hits": stats["pallas_hits"],
        "pallas_misses": stats["pallas_misses"],
        "cold_misses": cold_cache.stats["misses"],
        "warm_identical":
            result.canonical_json() == warm_result.canonical_json(),
    }
    report["calibration_fit"] = calibration_fit()
    emit("# --- checks ---")
    for k, v in report["checks"].items():
        emit(f"{k} = {v}")
    fit = report["calibration_fit"]
    emit(f"calibration_fit: max_rel_err={fit['max_rel_err']} "
         f"(threshold {fit['threshold']}) ok={fit['ok']}")
    c = report["cache"]
    emit(f"point cache: cold {c['cold_s']}s ({c['cold_misses']} "
         f"misses) -> warm {c['warm_s']}s ({c['hits']} hits, "
         f"{c['misses']} misses) = {c['warm_speedup']}x, "
         f"byte-identical={c['warm_identical']}")
    for kern, data in report["kernels"].items():
        emit(f"{kern}: front={len(data['front'])} points, "
             f"subword_max={data['subword']['max_speedup']}x")
    # compact per-point rows ride along for the perf trajectory
    report["points"] = result.csv_rows()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_dse.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI fast job)")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible inputs)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "thread", "process"),
                    help="sweep executor (default auto: serial for "
                         "small uncached fan-outs, process otherwise)")
    ap.add_argument("--measure-pallas", action="store_true",
                    help="add the Pallas walltime axis per point")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent point-cache directory for the "
                         "cold/warm timing (default: a throwaway temp "
                         "dir, removed after the run)")
    ap.add_argument("--check", action="store_true",
                    help="also fail when the CALIBRATION constants no "
                         "longer fit the paper's Table 3 energies")
    ap.add_argument("--check-only", action="store_true",
                    help="run ONLY the calibration-fit gate (closed-"
                         "form over published Table 3 rows — no sweep) "
                         "and write its result to --out")
    args = ap.parse_args(argv)
    if args.check_only:
        from repro.kvi.dse.cost import calibration_fit
        fit = calibration_fit()
        print(f"calibration_fit: max_rel_err={fit['max_rel_err']} "
              f"(threshold {fit['threshold']}) ok={fit['ok']}")
        with open(args.out, "w") as f:
            json.dump({"calibration_fit": fit}, f, indent=2,
                      sort_keys=True)
        print(f"# wrote {args.out}")
        if not fit["ok"]:                # explicit: survives python -O
            print(f"# FAILED: CALIBRATION drifted out of the paper's "
                  f"Table-3 energy regime: max relative fit error "
                  f"{fit['max_rel_err']} > threshold "
                  f"{fit['threshold']}", file=sys.stderr)
            return 1
        return 0
    result = run(emit=print, smoke=args.smoke, seed=args.seed,
                 executor=args.executor,
                 measure_pallas=args.measure_pallas,
                 cache_dir=args.cache_dir)
    checks = result["checks"]
    assert checks["all_schemes_covered"], "a scheme produced no points"
    assert checks["pareto_ordering_ok"], "paper scheme ordering broken"
    assert checks["subword_2x_on_mfu_bound"], "sub-word speedup < 2x"
    cache = result["cache"]
    assert cache["warm_identical"], \
        "warm re-sweep canonical JSON diverged from the cold sweep"
    assert cache["misses"] == 0 and cache["hits"] > 0, \
        f"warm re-sweep was not fully cached: {cache}"
    if args.smoke:
        # the paper-scale space is dominated by sweep compute too, but
        # only the smoke space is small/stable enough to pin a ratio on
        # shared CI runners
        assert cache["warm_speedup"] >= WARM_SPEEDUP_MIN, \
            (f"warm re-sweep speedup {cache['warm_speedup']}x below the "
             f"{WARM_SPEEDUP_MIN}x floor")
    if args.check:
        fit = result["calibration_fit"]
        if not fit["ok"]:                # explicit: survives python -O
            print(f"# FAILED: CALIBRATION drifted out of the paper's "
                  f"Table-3 energy regime: max relative fit error "
                  f"{fit['max_rel_err']} > threshold "
                  f"{fit['threshold']}", file=sys.stderr)
            return 1
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
