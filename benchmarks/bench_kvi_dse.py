"""Design-space exploration benchmark: the full sweep -> Pareto story.

Runs :func:`repro.kvi.dse.report.run_dse` (schemes x lanes x sub-word
precision over the paper's conv / fft / matmul kernels plus the
composite workload) and emits ``BENCH_kvi_dse.json`` — per-point cycles
/ area / energy, per-kernel Pareto fronts and speedup-vs-D curves, and
the acceptance checks (sym-MIMD fastest, shared cheapest, het-MIMD on
the front between them; 8-bit >= 2x on the MFU-bound kernels).

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_dse [--smoke]
          [--seed N] [--out PATH]
or through the harness:  python -m benchmarks.run --only kvi_dse
"""
from __future__ import annotations

import argparse
import json
import sys


def run(emit, smoke: bool = False, seed: int = 0) -> dict:
    from repro.kvi.dse.report import run_dse
    result, report = run_dse(smoke=smoke, seed=seed, emit=emit)
    emit("# --- checks ---")
    for k, v in report["checks"].items():
        emit(f"{k} = {v}")
    for kern, data in report["kernels"].items():
        emit(f"{kern}: front={len(data['front'])} points, "
             f"subword_max={data['subword']['max_speedup']}x")
    # compact per-point rows ride along for the perf trajectory
    report["points"] = result.csv_rows()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_dse.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI fast job)")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible inputs)")
    args = ap.parse_args(argv)
    result = run(emit=print, smoke=args.smoke, seed=args.seed)
    checks = result["checks"]
    assert checks["all_schemes_covered"], "a scheme produced no points"
    assert checks["pareto_ordering_ok"], "paper scheme ordering broken"
    assert checks["subword_2x_on_mfu_bound"], "sub-word speedup < 2x"
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
