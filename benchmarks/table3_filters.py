"""Table 3 reproduction: 5x5..11x11 filters on 32x32 matrices — cycles,
absolute time at f_max, and energy; speedup trend must continue and favor
higher DLP as filters grow.
"""
from __future__ import annotations

from benchmarks.paper_data import TABLE3_FILTERS, make_config
from repro.core.baselines import baseline_cycles, synthesis_for
from repro.core.workloads import homogeneous_cycles

FILTERS = (5, 7, 9, 11)
SCHEMES = [("SIMD", 2), ("SIMD", 8), ("SymMIMD", 2), ("SymMIMD", 8),
           ("HetMIMD", 2)]


def run(emit) -> dict:
    emit("# --- Table 3: higher-order filters (cycles x1000, sim/paper) ---")
    out = {}
    for scheme, D in SCHEMES:
        cfg = make_config(scheme, D)
        paper_key = {"SIMD": "T13 SIMD", "SymMIMD": "T13 Sym MIMD",
                     "HetMIMD": "T13 Het MIMD"}[scheme]
        row = {}
        parts = []
        for F in FILTERS:
            cyc = homogeneous_cycles(cfg, f"conv32_f{F}")["avg_cycles"]
            row[F] = cyc
            pk = TABLE3_FILTERS.get((paper_key, D))
            if pk and F in pk:
                parts.append(f"f{F}={cyc / 1000:.0f}k/{pk[F][0]}k"
                             f"({cyc / 1000 / pk[F][0]:.2f})")
        out[f"{scheme}-D{D}"] = row
        emit(f"{scheme + f' D={D}':14s}: " + " ".join(parts))
    zr = {F: baseline_cycles("zeroriscy", "conv", S=32, F=F) for F in FILTERS}
    t03 = {F: baseline_cycles("klessydra-t03", "conv", S=32, F=F)
           for F in FILTERS}
    emit("ZeroRiscy     : " + " ".join(
        f"f{F}={zr[F] / 1000:.0f}k/{TABLE3_FILTERS[('ZeroRiscy', 0)][F][0]}k"
        for F in FILTERS))
    emit("T03           : " + " ".join(
        f"f{F}={t03[F] / 1000:.0f}k/{TABLE3_FILTERS[('T03', 0)][F][0]}k"
        for F in FILTERS))

    # time speedup vs zeroriscy at f_max for the best scheme, per filter
    _, _, fz = synthesis_for("zeroriscy", 0)
    speedups = {}
    for F in FILTERS:
        t_z = zr[F] / fz
        best = None
        for scheme, D in SCHEMES:
            cfg = make_config(scheme, D)
            cyc = homogeneous_cycles(cfg, f"conv32_f{F}")["avg_cycles"]
            _, _, fm = synthesis_for(cfg.scheme, D)
            t = cyc / fm
            best = min(best, t) if best else t
        speedups[F] = t_z / best
    out["time_speedup_vs_zeroriscy"] = speedups
    emit("# time speedup vs ZeroRiscy by filter: " +
         " ".join(f"{F}x{F}:{speedups[F]:.1f}x" for F in FILTERS))
    grows = all(speedups[FILTERS[i + 1]] >= speedups[FILTERS[i]] * 0.95
                for i in range(len(FILTERS) - 1))
    out["checks"] = {"speedup_f11": speedups[11], "trend_continues": grows}
    emit(f"# paper: 'improvement grows up to 15x with 11x11' -> ours "
         f"{speedups[11]:.1f}x, trend continues: {grows}")
    return out
