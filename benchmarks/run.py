"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table2,fig2,...]
                                          [--seed N]

Prints each benchmark's detailed report, then a final
``name,us_per_call,derived`` CSV summary (us_per_call = harness wall time
per benchmark; derived = that benchmark's headline check).

``--seed`` is forwarded to every benchmark whose ``run()`` accepts a
``seed`` keyword, so the randomized inputs behind the BENCH_*.json
artifacts are reproducible run-to-run. Giving ``--seed`` while
selecting a benchmark that does *not* accept one is an error naming
that benchmark — the flag is never silently dropped — and the check
runs for every selected benchmark up front, before any of them start.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time


def bench_kwargs(name: str, mod, seed) -> dict:
    """Keyword arguments to forward to ``mod.run`` for bench ``name``.

    ``seed is None`` (flag not given) forwards nothing — seed-aware
    benches fall back to their own reproducible default. An explicit
    seed is forwarded only to a ``run()`` that declares the keyword;
    otherwise raise, naming the bench, so a typo'd ``--only`` +
    ``--seed`` combination fails loudly instead of silently measuring
    unseeded inputs."""
    if seed is None:
        return {}
    params = inspect.signature(mod.run).parameters
    if "seed" not in params:
        raise SystemExit(
            f"benchmarks.run: --seed {seed} given, but benchmark "
            f"{name!r} ({mod.__name__}.run) does not accept a 'seed' "
            f"keyword — it would be silently dropped. Re-run without "
            f"--seed, or restrict --only to seed-aware benchmarks.")
    return {"seed": seed}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table2,fig2,fig3,fig4,table3,kernels,"
                         "roofline,kvi_batch,kvi_passes,kvi_dse,"
                         "kvi_search,kvi_serve")
    ap.add_argument("--seed", type=int, default=None,
                    help="input-data seed, forwarded to seed-aware "
                         "benchmarks (error if a selected benchmark "
                         "cannot accept it)")
    args = ap.parse_args(argv)

    from benchmarks import (bench_kvi_batch, bench_kvi_dse, bench_kvi_passes,
                            bench_kvi_search, bench_kvi_serve, fig2_dlp_tlp,
                            fig3_exec_time, fig4_energy, kernel_micro,
                            roofline_report, table2_cycles, table3_filters)
    benches = {
        "table2": (table2_cycles,
                   lambda r: f"geomean_fit={r['checks']['fit_geomean_ratio']:.2f}"),
        "fig2": (fig2_dlp_tlp,
                 lambda r: f"combined_beats_dlp={r['checks']['combined_beats_dlp']}"),
        "fig3": (fig3_exec_time,
                 lambda r: f"conv32_speedup={r['checks']['conv32_speedup_max']:.1f}x"),
        "fig4": (fig4_energy,
                 lambda r: f"best_saving={r['checks']['best_saving_pct']:.0f}%"),
        "table3": (table3_filters,
                   lambda r: f"f11_speedup={r['checks']['speedup_f11']:.1f}x"),
        "kernels": (kernel_micro, lambda r: f"n_kernels={len(r)}"),
        "roofline": (roofline_report,
                     lambda r: f"cells={len(r['rows'])}"),
        "kvi_batch": (bench_kvi_batch,
                      lambda r: "batched_fewer_dispatches="
                      f"{r['checks']['batched_fewer_dispatches']},"
                      "sim_speedup="
                      f"{r['sim_perf']['speedup']}x"),
        "kvi_passes": (bench_kvi_passes,
                       lambda r: "cyclesim_reduced="
                       f"{r['checks']['cyclesim_reduced']},"
                       "pallas_calls_reduced="
                       f"{r['checks']['pallas_calls_reduced']}"),
        "kvi_dse": (bench_kvi_dse,
                    lambda r: "pareto_ordering_ok="
                    f"{r['checks']['pareto_ordering_ok']},"
                    "subword_2x="
                    f"{r['checks']['subword_2x_on_mfu_bound']}"),
        "kvi_search": (bench_kvi_search,
                       lambda r: "front_recovered="
                       f"{r['checks']['front_recovered']},"
                       "within_half_budget="
                       f"{r['checks']['within_half_budget']},"
                       "deterministic="
                       f"{r['checks']['deterministic']}"),
        "kvi_serve": (bench_kvi_serve,
                      lambda r: "speedup="
                      f"{r['checks']['batching_speedup_x']}x,"
                      "steady_hit_rate_1="
                      f"{r['checks']['steady_hit_rate_1']},"
                      "deterministic="
                      f"{r['checks']['deterministic']}"),
    }
    only = [s for s in args.only.split(",") if s]
    unknown = [s for s in only if s not in benches]
    if unknown:
        raise SystemExit(f"benchmarks.run: unknown benchmark(s) "
                         f"{unknown} in --only; available: "
                         f"{', '.join(benches)}")
    selected = [(name, mod, derive)
                for name, (mod, derive) in benches.items()
                if not only or name in only]
    # validate the seed forwarding for EVERY selected bench before any
    # of them run — a late failure would waste the finished ones
    all_kwargs = {name: bench_kwargs(name, mod, args.seed)
                  for name, mod, _ in selected}
    rows = []
    for name, mod, derive in selected:
        print(f"\n================ {name} ================", flush=True)
        t0 = time.perf_counter()
        try:
            result = mod.run(emit=print, **all_kwargs[name])
            derived = derive(result)
        except Exception as e:  # noqa: BLE001 — report but keep harness alive
            derived = f"ERROR:{type(e).__name__}:{e}"
        us = (time.perf_counter() - t0) * 1e6
        rows.append((name, us, derived))
    print("\n# name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
