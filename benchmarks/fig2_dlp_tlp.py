"""Figure 2 reproduction: DLP vs TLP cycle-count boost for 2D convolutions
across matrix sizes (the paper's key plot: TLP dominates for small vectors,
DLP grows with vector size, TLP+DLP always beats pure DLP).

Runs on the workload API: ONE homogeneous KviWorkload per conv size,
timed across all five scheme configurations in a single
``CycleSimBackend.run_workload`` call (the conv programs are
config-independent, so the workload is built once and lowered per scheme).
"""
from __future__ import annotations

from benchmarks.paper_data import make_config
from repro.core.workloads import homogeneous_workload

SIZES = ("conv4", "conv8", "conv16", "conv32")

CURVES = {
    "DLP only (D=8)": ("SIMD", 8),
    "TLP only (MIMD)": ("SymMIMD", 1),
    "TLP+DLP (D=8)": ("SymMIMD", 8),
    "Het TLP+DLP D=8": ("HetMIMD", 8),
}


def run(emit) -> dict:
    from repro.kvi.cyclesim import CycleSimBackend

    base_cfg = make_config("SISD", 1)
    schemes = {"sisd": base_cfg}
    schemes.update({label: make_config(s, D)
                    for label, (s, D) in CURVES.items()})
    backend = CycleSimBackend(schemes=schemes)

    # one workload per conv size, all schemes timed in one run
    avg = {}
    for k in SIZES:
        wl = homogeneous_workload(base_cfg, k)
        res = backend.run_workload(wl, functional=False)
        avg[k] = {label: res.timing[label].cycles / schemes[label].harts
                  for label in schemes}

    out = {"sisd": {k: avg[k]["sisd"] for k in SIZES}}
    emit("# --- Fig 2: speedup over SISD (rows: scheme, cols: conv size) ---")
    emit(f"{'scheme':16s} " + " ".join(f"{k:>8s}" for k in SIZES))
    for label in CURVES:
        boosts = {k: avg[k]["sisd"] / avg[k][label] for k in SIZES}
        out[label] = boosts
        emit(f"{label:16s} " + " ".join(f"{boosts[k]:8.2f}x" for k in SIZES))

    # the paper's qualitative findings as assertions
    checks = {
        # TLP beats DLP at the smallest size
        "tlp_beats_dlp_small": out["TLP only (MIMD)"]["conv4"] >
                               out["DLP only (D=8)"]["conv4"],
        # DLP boost grows with matrix size
        "dlp_grows": out["DLP only (D=8)"]["conv32"] >
                     out["DLP only (D=8)"]["conv4"],
        # combined always >= pure DLP
        "combined_beats_dlp": all(
            out["TLP+DLP (D=8)"][k] >= out["DLP only (D=8)"][k]
            for k in SIZES),
    }
    out["checks"] = checks
    emit(f"# checks: {checks}")
    return out
