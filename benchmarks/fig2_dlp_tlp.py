"""Figure 2 reproduction: DLP vs TLP cycle-count boost for 2D convolutions
across matrix sizes (the paper's key plot: TLP dominates for small vectors,
DLP grows with vector size, TLP+DLP always beats pure DLP).
"""
from __future__ import annotations

from benchmarks.paper_data import make_config
from repro.core.workloads import homogeneous_cycles

SIZES = ("conv4", "conv8", "conv16", "conv32")


def run(emit) -> dict:
    base = {k: homogeneous_cycles(make_config("SISD", 1), k)["avg_cycles"]
            for k in SIZES}
    out = {"sisd": base}
    emit("# --- Fig 2: speedup over SISD (rows: scheme, cols: conv size) ---")
    emit(f"{'scheme':16s} " + " ".join(f"{k:>8s}" for k in SIZES))
    curves = {
        "DLP only (D=8)": ("SIMD", 8),
        "TLP only (MIMD)": ("SymMIMD", 1),
        "TLP+DLP (D=8)": ("SymMIMD", 8),
        "Het TLP+DLP D=8": ("HetMIMD", 8),
    }
    for label, (scheme, D) in curves.items():
        cfg = make_config(scheme, D)
        boosts = {}
        for k in SIZES:
            c = homogeneous_cycles(cfg, k)["avg_cycles"]
            boosts[k] = base[k] / c
        out[label] = boosts
        emit(f"{label:16s} " + " ".join(f"{boosts[k]:8.2f}x" for k in SIZES))

    # the paper's qualitative findings as assertions
    checks = {
        # TLP beats DLP at the smallest size
        "tlp_beats_dlp_small": out["TLP only (MIMD)"]["conv4"] >
                               out["DLP only (D=8)"]["conv4"],
        # DLP boost grows with matrix size
        "dlp_grows": out["DLP only (D=8)"]["conv32"] >
                     out["DLP only (D=8)"]["conv4"],
        # combined always >= pure DLP
        "combined_beats_dlp": all(
            out["TLP+DLP (D=8)"][k] >= out["DLP only (D=8)"][k]
            for k in SIZES),
    }
    out["checks"] = checks
    emit(f"# checks: {checks}")
    return out
