"""Serving-under-load benchmark: the KVI serving engine's headline
numbers, emitted to ``BENCH_kvi_serve.json``.

One Poisson request stream (mixed kernels, mixed precisions, ~1000
simulated clients) is served three times:

  * batched, twice — signature batching + prewarmed kernel cache, run
    two times from scratch to prove the canonical report (wall-clock
    fields scrubbed) is byte-identical under the seed;
  * unbatched once — the same schedule executed one request at a time,
    the baseline the batching speedup is measured against.

Gates (the harness and CI fail when any is False):

  * ``deterministic``        — canonical reports byte-identical
  * ``steady_hit_rate_1``    — zero compiles inside the serving loop
                               (prewarming covered every batch shape)
  * ``speedup_ge_2x``        — batched steady-state wall throughput at
                               least 2x the one-at-a-time baseline
  * ``outputs_match_oracle`` — batched execution is bit-identical to
                               the scalar oracle on sampled requests

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_serve [--smoke]
or through the harness:  python -m benchmarks.run --only kvi_serve
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _engine(templates, backend, batching: bool, seed: int):
    from repro.kvi.serving import ServeEngine
    return ServeEngine(templates, n_harts=3, backend=backend,
                       batching=batching, max_batch=8, seed=seed)


def _oracle_check(templates, seed: int, per_template: int = 3) -> bool:
    """Batched Pallas execution vs the scalar oracle, bit for bit, on a
    sample of instantiated requests per template."""
    from repro.kvi.backend import get_backend
    from repro.kvi.workload import KviWorkload
    oracle = get_backend("oracle")
    pallas = get_backend("pallas", passes=())
    for name in sorted(templates):
        tpl = templates[name]
        progs = [tpl.instantiate(seed, 10_000 + i)
                 for i in range(per_template)]
        batched = pallas.run_workload(
            KviWorkload.homogeneous(progs, name=f"check.{name}"))
        for prog, got in zip(progs, batched.entry_results):
            want = oracle.run(prog)
            for k in want.outputs:
                if not np.array_equal(want.outputs[k], got.outputs[k]):
                    return False
    return True


def run(emit, seed: int = 0, smoke: bool = True) -> dict:
    from repro.kvi.backend import get_backend
    from repro.kvi.serving import (DEFAULT_MIX, SMOKE_MIX,
                                   canonical_report, make_templates,
                                   poisson_arrivals)

    mix = SMOKE_MIX if smoke else DEFAULT_MIX
    n_requests = 32 if smoke else 96
    templates = make_templates(mix, smoke=smoke, seed=seed)
    specs = poisson_arrivals(templates, n_requests,
                             mean_interarrival_cycles=80.0,
                             n_clients=1000, seed=seed)
    emit(f"# mix={sorted(templates)} requests={len(specs)} "
         f"clients={len({s.client for s in specs})}")

    emit("# --- batched serve, run A (fresh backend) ---")
    rep_a = _engine(templates, get_backend("pallas", passes=()),
                    True, seed).run(specs)
    emit("# --- batched serve, run B (fresh backend) ---")
    rep_b = _engine(templates, get_backend("pallas", passes=()),
                    True, seed).run(specs)
    deterministic = canonical_report(rep_a) == canonical_report(rep_b)

    emit("# --- unbatched baseline (one request per dispatch) ---")
    rep_u = _engine(templates, get_backend("pallas", passes=()),
                    False, seed).run(specs)

    batched_s = rep_a["throughput"]["execute_s"]
    unbatched_s = rep_u["throughput"]["execute_s"]
    speedup = round(unbatched_s / max(batched_s, 1e-9), 2)
    cc = rep_a["compile_cache"]
    lat = rep_a["latency_cycles"]
    emit(f"# batched {batched_s}s vs unbatched {unbatched_s}s "
         f"-> {speedup}x; loop misses={cc['loop_misses']} "
         f"(steady hit rate {cc['steady_hit_rate']}); "
         f"p50={lat['p50']} p95={lat['p95']} p99={lat['p99']} cycles")

    outputs_ok = _oracle_check(templates, seed)
    emit(f"# outputs_match_oracle={outputs_ok} "
         f"deterministic={deterministic}")

    return {
        "seed": seed,
        "smoke": smoke,
        "serve": rep_a,
        "unbatched": {
            "throughput": rep_u["throughput"],
            "batch_sizes": rep_u["batch_sizes"],
        },
        "checks": {
            "deterministic": deterministic,
            "steady_hit_rate_1": cc["steady_hit_rate"] == 1.0,
            "batching_speedup_x": speedup,
            "speedup_ge_2x": speedup >= 2.0,
            "outputs_match_oracle": outputs_ok,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + short stream (CI-sized)")
    ap.add_argument("--seed", type=int, default=0,
                    help="load + data seed (reproducible stream)")
    ap.add_argument("--out", default="BENCH_kvi_serve.json")
    args = ap.parse_args(argv)
    result = run(emit=print, seed=args.seed, smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    gates = {k: v for k, v in result["checks"].items()
             if isinstance(v, bool)}
    if not all(gates.values()):
        print(f"# FAILED gates: "
              f"{sorted(k for k, v in gates.items() if not v)}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
