"""Auto-tuner benchmark: search vs enumerate on the smoke space.

Runs the budget-constrained search (``repro.kvi.dse.search``) twice
with the same seed on the 36-point smoke space, then scores it against
the exhaustive sweep the driver confirms as a yardstick. Emitted to
``BENCH_kvi_search.json``:

  * evaluations — analytic scores vs cycle-accurate sims requested
    (the persistent-cache-independent budget accounting) and the
    fraction of the exhaustive grid that cost;
  * front_recovery — fraction of the exhaustive Pareto front the
    search's confirmed front covers (the <=50%-of-evals acceptance
    gate lives on these two numbers);
  * walltime — search vs exhaustive-confirmation wall seconds
    (cache-temperature dependent, reported for context);
  * deterministic — byte-identity of the two same-seed runs'
    volatile-scrubbed canonical reports.

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_search [--out PATH]
or through the harness:  python -m benchmarks.run --only kvi_search
(--seed is forwarded to the search RNG and the kernel input data).
"""
from __future__ import annotations

import argparse
import json
import sys


def run(emit, seed: int = 0, strategy: str = "successive_halving",
        out_dir: str = None) -> dict:
    from repro.kvi.dse.pointcache import PointCache
    from repro.kvi.dse.search import run_search

    results = []
    for rep in range(2):
        res = run_search(strategy=strategy, smoke=True, seed=seed,
                         compare_exhaustive=True,
                         cache=PointCache(),
                         out_dir=out_dir if rep == 0 else None,
                         emit=None)
        results.append(res)
    res, res2 = results
    deterministic = res.canonical_json() == res2.canonical_json()

    ev = res.evaluations
    rec = res.meta["recovery"]
    frac = res.exhaustive_fraction
    emit(f"{strategy} seed {seed}: {ev['high_evals']} sims "
         f"({frac:.1%} of {res.meta['grid_size']} points), "
         f"{ev['low_evals']} analytic scores")
    emit(f"front recovery {rec['front_recovery']:.1%} of "
         f"{rec['exhaustive_front_size']} members; search "
         f"{res.meta['walltime_s']}s vs exhaustive-remainder "
         f"{rec['walltime_s']}s; deterministic={deterministic}")

    return {
        "seed": seed, "strategy": strategy,
        "grid_size": res.meta["grid_size"],
        "evaluations": dict(ev),
        "exhaustive_fraction": round(frac, 6),
        "front_recovery": rec["front_recovery"],
        "exhaustive_front_size": rec["exhaustive_front_size"],
        "best": res.best.point.name if res.best else None,
        "search_walltime_s": res.meta["walltime_s"],
        "exhaustive_walltime_s": rec["walltime_s"],
        "trajectory": list(res.trajectory),
        "rungs": list(res.rungs),
        "checks": {
            "front_recovered": rec["front_recovery"] == 1.0,
            "within_half_budget": frac is not None and frac <= 0.5,
            "deterministic": deterministic,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_search.json")
    ap.add_argument("--seed", type=int, default=0,
                    help="search RNG + kernel input data seed")
    ap.add_argument("--strategy", default="successive_halving")
    args = ap.parse_args(argv)
    result = run(emit=print, seed=args.seed, strategy=args.strategy)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
