"""Pass-pipeline benchmark: what the KVI optimizing passes buy, per
backend, across the fig2/table2 program set.

Two measurement families, emitted to ``BENCH_kvi_passes.json``:

  * cyclesim — per-scheme cycles with the pipeline OFF (``passes=()``)
    vs ON (default pipeline + the FU-chaining discount the fusion plan
    enables). The paper's conv/FFT/matmul kernels plus the
    ``pipeline_demo`` stress kernel (kvcp-stitched chains + dead code —
    the shape copy_prop/dce exist for).
  * pallas — wall time and ``pallas_call`` counts, pipeline OFF vs ON.
    Fewer kernel launches = fewer compiles and fewer HBM round-trips;
    the demo kernel shows the copy_prop effect directly (each removed
    ``kvcp`` welds two fused regions into one).

Outputs are asserted bit-identical between OFF and ON for every case —
the pipeline is an optimizer, not an approximation.

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_passes [--smoke] [--out PATH]
or through the harness:  python -m benchmarks.run --only kvi_passes
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _program_set(S: int, n_fft: int, m: int, stages: int, seed: int = 0):
    """(name, program) pairs: the paper's three kernels + the pipeline
    stress kernel."""
    from repro.kvi.programs import (conv2d_program, fft_program,
                                    matmul_program, pipeline_demo_program)
    rng = np.random.default_rng(seed)
    filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
    img = rng.integers(-128, 128, (S, S)).astype(np.int32)
    A = rng.integers(-64, 64, (m, m)).astype(np.int32)
    B = rng.integers(-64, 64, (m, m)).astype(np.int32)
    return [
        (f"conv{S}", conv2d_program(img, filt, shift=4)),
        (f"fft{n_fft}",
         fft_program(rng.integers(-2048, 2048, n_fft).astype(np.int32),
                     rng.integers(-2048, 2048, n_fft).astype(np.int32))),
        (f"matmul{m}", matmul_program(A, B, shift=2)),
        ("pipeline_demo",
         pipeline_demo_program(
             rng.integers(-128, 128, 64).astype(np.int32), stages=stages)),
    ]


def _cyclesim_set(smoke: bool, seed: int = 0):
    """Paper fig2/table2 sizes — the event-driven simulator is cheap."""
    return (_program_set(S=8, n_fft=32, m=8, stages=2, seed=seed) if smoke
            else _program_set(S=32, n_fft=256, m=64, stages=6, seed=seed))


def _pallas_set(smoke: bool, seed: int = 0):
    """Interpret-mode-friendly sizes (CPU interpret wall time would
    otherwise dwarf the compile-count signal being measured)."""
    return (_program_set(S=8, n_fft=32, m=8, stages=2, seed=seed) if smoke
            else _program_set(S=16, n_fft=64, m=8, stages=6, seed=seed))


def _outputs_equal(a, b) -> bool:
    return (set(a) == set(b)
            and all(np.array_equal(a[k], b[k]) for k in a))


def _cyclesim_case(name, prog, emit) -> dict:
    from repro.kvi.cyclesim import CycleSimBackend
    off = CycleSimBackend(passes=()).run(prog)
    on = CycleSimBackend(chaining=True).run(prog)
    assert _outputs_equal(off.outputs, on.outputs), name
    row = {"kernel": name,
           "cycles_off": off.cycles, "cycles_on": on.cycles,
           "speedup": {k: round(off.cycles[k] / max(on.cycles[k], 1), 3)
                       for k in off.cycles}}
    emit(f"{name:14s} " + " ".join(
        f"{k}={off.cycles[k]}->{on.cycles[k]} ({row['speedup'][k]:.2f}x)"
        for k in off.cycles))
    return row


def _pallas_warmup():
    """Pay the one-time JAX/XLA initialization cost outside the timed
    region so it does not inflate the first measured variant."""
    from repro.kvi.programs import pipeline_demo_program
    from repro.kvi.pallas_backend import PallasBackend
    tiny = pipeline_demo_program(np.arange(8, dtype=np.int32), stages=1)
    PallasBackend(passes=()).run(tiny)


def _pallas_case(name, prog, emit) -> dict:
    from repro.kvi.pallas_backend import PallasBackend
    off = PallasBackend(passes=())
    t0 = time.perf_counter()
    r_off = off.run(prog)
    t_off = time.perf_counter() - t0
    on = PallasBackend()
    t0 = time.perf_counter()
    r_on = on.run(prog)
    t_on = time.perf_counter() - t0
    assert _outputs_equal(r_off.outputs, r_on.outputs), name
    row = {"kernel": name,
           "wall_s_off": round(t_off, 4), "wall_s_on": round(t_on, 4),
           "pallas_calls_off": off.fused_calls + off.reduce_calls,
           "pallas_calls_on": on.fused_calls + on.reduce_calls}
    emit(f"{name:14s} calls {row['pallas_calls_off']}->"
         f"{row['pallas_calls_on']}, wall {t_off:.3f}s->{t_on:.3f}s")
    return row


def run(emit, smoke: bool = False, seed: int = 0) -> dict:
    from repro.kvi.passes import default_pipeline
    cs_progs = _cyclesim_set(smoke, seed)

    emit("# --- pass pipeline: instruction-count deltas ---")
    pipe = default_pipeline()
    programs_rows = []
    for name, p in cs_progs:
        opt = pipe.run(p)
        plan = opt.meta.get("fused_regions")
        row = {"kernel": name,
               "instrs_off": p.n_instructions,
               "instrs_on": opt.n_instructions,
               "vregs_off": len(p.vregs), "vregs_on": len(opt.vregs),
               "fused_regions": len(plan.regions) if plan else 0}
        programs_rows.append(row)
        emit(f"{name:14s} instrs {row['instrs_off']}->{row['instrs_on']}"
             f" vregs {row['vregs_off']}->{row['vregs_on']}"
             f" regions={row['fused_regions']}")

    emit("# --- cyclesim: passes off vs on (+chaining) ---")
    cyclesim = [_cyclesim_case(n, p, emit) for n, p in cs_progs]

    emit("# --- pallas: passes off vs on ---")
    _pallas_warmup()
    pallas = [_pallas_case(n, p, emit)
              for n, p in _pallas_set(smoke, seed)]

    out = {
        "smoke": smoke,
        "seed": seed,
        "programs": programs_rows,
        "cyclesim": cyclesim,
        "pallas": pallas,
        "checks": {
            "bit_identical_outputs": True,    # asserted per case above
            "cyclesim_reduced": any(
                r["cycles_on"][k] < r["cycles_off"][k]
                for r in cyclesim for k in r["cycles_on"]),
            "pallas_calls_reduced": any(
                r["pallas_calls_on"] < r["pallas_calls_off"]
                for r in pallas),
        },
    }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_passes.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small program sizes (CI fast job)")
    ap.add_argument("--seed", type=int, default=0,
                    help="program input-data seed (reproducible inputs)")
    args = ap.parse_args(argv)
    result = run(emit=print, smoke=args.smoke, seed=args.seed)
    assert result["checks"]["cyclesim_reduced"], "no cyclesim win"
    assert result["checks"]["pallas_calls_reduced"], "no pallas win"
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
