"""Roofline table from the dry-run artifacts (artifacts/dryrun/*.json):
per (arch x shape x mesh): the three terms, the bottleneck, and
MODEL_FLOPS / HLO_FLOPs utilization.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_spec, SHAPES
from repro.models import model_zoo as zoo

ART = Path("artifacts/dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D fwd-only."""
    spec = get_spec(arch)
    cfg = spec.model
    shape = SHAPES[shape_name]
    n_active = zoo.active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: one token


def run(emit) -> dict:
    rows = []
    emit("# --- Roofline (per-device terms, seconds; 197TF/s, 819GB/s, "
         "50GB/s link) ---")
    emit(f"{'arch':22s}{'shape':13s}{'mesh':9s}{'t_comp':>9s}{'t_mem':>9s}"
         f"{'t_coll':>9s} {'bound':12s}{'MF/HF':>6s}{'fit':>5s}")
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok" or r.get("tag"):
            continue
        mesh = "2x16x16" if "multipod" in f.name else "16x16"
        t = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hf = r["flops_per_device"] * r["chips"]
        util = mf / hf if hf else 0.0
        bound = t["bottleneck"].replace("t_", "").replace("_s", "")
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": mesh,
            "t_compute": t["t_compute_s"], "t_memory": t["t_memory_s"],
            "t_collective": t["t_collective_s"], "bottleneck": bound,
            "model_over_hlo_flops": util, "fits": r["fits_hbm"],
        })
        emit(f"{r['arch']:22s}{r['shape']:13s}{mesh:9s}"
             f"{t['t_compute_s']:9.4f}{t['t_memory_s']:9.4f}"
             f"{t['t_collective_s']:9.4f} {bound:12s}{util:6.2f}"
             f"{'  ok' if r['fits_hbm'] else ' OOM'}")
    # summary: bottleneck histogram
    hist = {}
    for row in rows:
        if row["mesh"] == "16x16":
            hist[row["bottleneck"]] = hist.get(row["bottleneck"], 0) + 1
    emit(f"# single-pod bottleneck histogram: {hist}")
    return {"rows": rows, "bottlenecks": hist}
