"""The paper's published numbers (Tables 2-3) — inputs for validation.

Keys: (scheme, D) with scheme in {SISD, SIMD, SymMIMD, HetMIMD} (paper's
"Sym. MIMD + SIMD" etc. are the D>1 rows of the MIMD schemes).
"""

# Table 2 — average cycle count per kernel, homogeneous workload
TABLE2_HOMOGENEOUS = {
    ("SISD", 1): {"conv4": 1105, "conv8": 3060, "conv16": 9727,
                  "conv32": 34201, "fft256": 33033, "matmul64": 728187},
    ("SIMD", 2): {"conv4": 895, "conv8": 2245, "conv16": 6261,
                  "conv32": 20374, "fft256": 25647, "matmul64": 602458},
    ("SIMD", 4): {"conv4": 824, "conv8": 1768, "conv16": 4607,
                  "conv32": 13444, "fft256": 22812, "matmul64": 543164},
    ("SIMD", 8): {"conv4": 824, "conv8": 1613, "conv16": 3692,
                  "conv32": 10069, "fft256": 21555, "matmul64": 484436},
    ("SymMIMD", 1): {"conv4": 626, "conv8": 1493, "conv16": 3887,
                     "conv32": 13536, "fft256": 18726, "matmul64": 462066},
    ("SymMIMD", 2): {"conv4": 629, "conv8": 1190, "conv16": 3123,
                     "conv32": 8681, "fft256": 16827, "matmul64": 378748},
    ("SymMIMD", 4): {"conv4": 560, "conv8": 1190, "conv16": 2543,
                     "conv32": 7148, "fft256": 15993, "matmul64": 328962},
    ("SymMIMD", 8): {"conv4": 560, "conv8": 1152, "conv16": 2543,
                     "conv32": 6006, "fft256": 15726, "matmul64": 316270},
    ("HetMIMD", 1): {"conv4": 663, "conv8": 1521, "conv16": 4153,
                     "conv32": 13565, "fft256": 22839, "matmul64": 556463},
    ("HetMIMD", 2): {"conv4": 638, "conv8": 1274, "conv16": 3280,
                     "conv32": 9167, "fft256": 18468, "matmul64": 425978},
    ("HetMIMD", 4): {"conv4": 573, "conv8": 1213, "conv16": 2688,
                     "conv32": 7473, "fft256": 16887, "matmul64": 360863},
    ("HetMIMD", 8): {"conv4": 573, "conv8": 1079, "conv16": 2580,
                     "conv32": 6285, "fft256": 17604, "matmul64": 328178},
}

# Table 2 — composite workload (conv32 / fft256 / matmul64 columns)
TABLE2_COMPOSITE = {
    ("SISD", 1): {"conv32": 66043, "fft256": 80874, "matmul64": 476771},
    ("SIMD", 2): {"conv32": 21976, "fft256": 60019, "matmul64": 645705},
    ("SIMD", 4): {"conv32": 16850, "fft256": 29144, "matmul64": 431773},
    ("SIMD", 8): {"conv32": 11324, "fft256": 22482, "matmul64": 414420},
    ("SymMIMD", 1): {"conv32": 20953, "fft256": 17824, "matmul64": 292564},
    ("SymMIMD", 2): {"conv32": 16144, "fft256": 15839, "matmul64": 222370},
    ("SymMIMD", 4): {"conv32": 15868, "fft256": 14942, "matmul64": 182580},
    ("SymMIMD", 8): {"conv32": 15581, "fft256": 14613, "matmul64": 168031},
    ("HetMIMD", 1): {"conv32": 27155, "fft256": 37111, "matmul64": 265567},
    ("HetMIMD", 2): {"conv32": 15973, "fft256": 24611, "matmul64": 251201},
    ("HetMIMD", 4): {"conv32": 16042, "fft256": 19175, "matmul64": 181290},
    ("HetMIMD", 8): {"conv32": 13921, "fft256": 17298, "matmul64": 187877},
}

# Table 2 — baseline cores (homogeneous / composite)
TABLE2_BASELINES = {
    "klessydra-t03": {"conv4": 1819, "conv8": 5737, "conv16": 20714,
                      "conv32": 79230, "fft256": 47256, "matmul64": 2679304,
                      "comp_conv32": 138959, "comp_fft256": 46733,
                      "comp_matmul64": 2775779},
    "ri5cy": {"conv4": 1377, "conv8": 4247, "conv16": 15088,
              "conv32": 57020, "fft256": 37344, "matmul64": 1360854,
              "comp_conv32": 81534, "comp_fft256": 37350,
              "comp_matmul64": 1369572},
    "zeroriscy": {"conv4": 2510, "conv8": 8111, "conv16": 29583,
                  "conv32": 113793, "fft256": 61158, "matmul64": 4006241,
                  "comp_conv32": 197010, "comp_fft256": 61163,
                  "comp_matmul64": 4043376},
}

# Table 3 — higher-order filters on 32x32 (cycles x1000, T us, E uJ)
TABLE3_FILTERS = {
    ("T13 SIMD", 2): {5: (53, 362, 51), 7: (101, 694, 97),
                      9: (166, 1136, 159), 11: (247, 1689, 237)},
    ("T13 SIMD", 8): {5: (25, 179, 34), 7: (46, 335, 65),
                      9: (75, 543, 105), 11: (111, 803, 155)},
    ("T13 Sym MIMD", 2): {5: (20, 148, 27), 7: (36, 272, 49),
                          9: (57, 436, 79), 11: (84, 641, 117)},
    ("T13 Sym MIMD", 8): {5: (12, 113, 29), 7: (19, 183, 47),
                          9: (30, 284, 73), 11: (43, 408, 105)},
    ("T13 Het MIMD", 2): {5: (21, 159, 28), 7: (38, 291, 52),
                          9: (60, 467, 83), 11: (89, 687, 122)},
    ("T03", 0): {5: (247, 1120, 216), 7: (515, 2328, 448),
                 9: (881, 3985, 767), 11: (1369, 6191, 1191)},
    ("RI5CY", 0): {5: (180, 1971, 252), 7: (385, 4218, 539),
                   9: (663, 7252, 928), 11: (1000, 10949, 1400)},
    ("ZeroRiscy", 0): {5: (319, 2721, 226), 7: (675, 5754, 479),
                       9: (1130, 9637, 802), 11: (1698, 14482, 1205)},
}

# headline claims (paper §CONCLUSIONS and body)
CLAIMS = {
    "small_conv_speedup_vs_t03": 3.0,       # "up to 3x ... small matrix"
    "large_speedup_vs_t03": 13.0,           # conv32/matmul vs T03
    "large_speedup_vs_ri5cy": 9.0,
    "large_speedup_vs_zeroriscy": 19.0,
    "het_vs_sym_max_pct": 7.0,              # "1% to 7% more cycles"
    "time_speedup_vs_zeroriscy": 17.0,      # conv32, sym MIMD+SIMD
    "energy_saving_pct": 85.0,              # ">85% energy saving"
    "filter11_speedup_vs_zeroriscy": 15.0,  # "up to 15x with 11x11"
}


def make_config(scheme: str, D: int, **kw):
    from repro.configs.base import KlessydraConfig
    M, F = {"SISD": (1, 1), "SIMD": (1, 1), "SymMIMD": (3, 3),
            "HetMIMD": (3, 1)}[scheme]
    return KlessydraConfig(f"{scheme} D={D}", M=M, F=F, D=D, **kw)


SCHEME_KEYS = list(TABLE2_HOMOGENEOUS)
