"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timings only — real perf comes from the §Roofline analysis) + per-kernel
analytic roofline terms on the TPU v5e target.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

PEAK, HBM = 197e12, 819e9


def _time(fn, *args, iters=3):
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6


def run(emit) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    emit("# --- kernel microbench (interpret mode) + TPU roofline terms ---")

    # matmul 512^3 bf16
    a = jnp.asarray(rng.normal(0, 1, (512, 512)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(0, 1, (512, 512)), jnp.bfloat16)
    us = _time(lambda x, y: ops.matmul_op(x, y), a, b, iters=2)
    flops = 2 * 512 ** 3
    bts = 3 * 512 * 512 * 2
    t_c, t_m = flops / PEAK, bts / HBM
    out["spm_matmul_512"] = {"us_interp": us, "t_compute": t_c,
                             "t_memory": t_m,
                             "bound": "compute" if t_c > t_m else "memory"}
    emit(f"spm_matmul 512^3 bf16: interp={us:.0f}us, TPU compute={t_c*1e6:.1f}us "
         f"memory={t_m*1e6:.1f}us -> {out['spm_matmul_512']['bound']}-bound")

    # conv2d 256x256 f32 3x3
    img = jnp.asarray(rng.normal(0, 1, (256, 256)), jnp.float32)
    filt = jnp.asarray(rng.normal(0, 1, (3, 3)), jnp.float32)
    us = _time(lambda x, f: ops.conv2d_op(x, f), img, filt, iters=2)
    flops = 2 * 256 * 256 * 9
    bts = 2 * 256 * 256 * 4
    out["spm_conv2d_256"] = {"us_interp": us, "t_compute": flops / PEAK,
                             "t_memory": bts / HBM}
    emit(f"spm_conv2d 256x256 3x3: interp={us:.0f}us, TPU "
         f"compute={flops/PEAK*1e6:.2f}us memory={bts/HBM*1e6:.2f}us -> "
         f"memory-bound (AI={flops/bts:.1f})")

    # fft 64x256
    re = jnp.asarray(rng.normal(0, 1, (64, 256)), jnp.float32)
    im = jnp.asarray(rng.normal(0, 1, (64, 256)), jnp.float32)
    us = _time(lambda r, i: ops.fft_op(r, i), re, im, iters=2)
    flops = 64 * 10 * 128 * 8
    bts = 4 * 64 * 256 * 4
    out["spm_fft_64x256"] = {"us_interp": us}
    emit(f"spm_fft 64x256: interp={us:.0f}us, TPU compute={flops/PEAK*1e9:.1f}ns "
         f"memory={bts/HBM*1e9:.0f}ns -> memory-bound (VMEM residency is "
         f"the win: XLA per-stage HBM round-trips would be 8x the traffic)")

    # flash attention 1x4x1024x64
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 1024, 64)), jnp.bfloat16)
    us = _time(lambda q_, k_, v_: ops.attention_op(q_, k_, v_, bq=256, bk=256),
               q, k, v, iters=1)
    flops = 4 * 1 * 4 * 1024 * 1024 * 64 // 2
    hbm_flash = (1 * 4 * 1024 * 64 * 2) * 4
    hbm_xla = hbm_flash + 4 * 1 * 4 * 1024 * 1024 * 4
    out["flash_attention_1k"] = {
        "us_interp": us, "t_compute": flops / PEAK,
        "t_memory_flash": hbm_flash / HBM, "t_memory_xla": hbm_xla / HBM}
    emit(f"flash_attention 1k causal: interp={us:.0f}us; TPU "
         f"compute={flops/PEAK*1e6:.1f}us, memory flash={hbm_flash/HBM*1e6:.2f}us "
         f"vs XLA-scores-in-HBM={hbm_xla/HBM*1e6:.1f}us "
         f"({hbm_xla/hbm_flash:.0f}x traffic saved by SPM residency)")

    # ssd scan
    x = jnp.asarray(rng.normal(0, 1, (2, 512, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (2, 512, 4)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(0, 0.5, (4,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(0, 1, (2, 512, 1, 16)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (2, 512, 1, 16)), jnp.float32)
    us = _time(lambda *a: ops.ssd_scan_op(*a, chunk=128), x, dt, A, Bm, Cm,
               iters=1)
    out["ssd_scan"] = {"us_interp": us}
    emit(f"ssd_scan 2x512x4x32: interp={us:.0f}us (state rides VMEM across "
         f"chunks; HBM traffic is O(S), not O(S*N))")
    return out
