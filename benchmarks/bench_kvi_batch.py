"""Batched-execution benchmark: the perf trajectory for KviWorkload.

Three measurements, emitted to ``BENCH_kvi_batch.json``:

  * cyclesim — composite-workload cycles per coprocessor scheme (the
    paper's conv32 / fft256 / matmul64 on harts 0/1/2), i.e. the numbers
    the hart-aware batch path must keep reproducing.
  * sim_perf — wall time of the optimized simulator event loop
    (``Simulator.run``) against the retained reference loop
    (``Simulator._run_reference``) on the composite workload; the
    ``speedup`` column pins the event-loop micro-optimization
    (precomputed dispatch fields, strided scalar-run accounting).
  * pallas — wall time for N homogeneous program instances dispatched
    one ``run()`` at a time vs. one batched ``run_workload()`` (batch
    grid dimension: one compile + one dispatch per fused segment for the
    whole batch), with the ``pallas_call`` counts that explain the gap.

Run:  PYTHONPATH=src python -m benchmarks.bench_kvi_batch [--out PATH]
or through the harness:  python -m benchmarks.run --only kvi_batch
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _conv_instances(S: int, n_instances: int, seed: int = 0):
    """N conv programs sharing ONE filter (weights are instruction
    immediates, so batchable instances must share them — the DNN-inference
    shape: one model, N inputs) over different images."""
    from repro.kvi.programs import conv2d_program
    rng = np.random.default_rng(seed)
    filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
    return [conv2d_program(
        rng.integers(-128, 128, (S, S)).astype(np.int32), filt, shift=4)
        for _ in range(n_instances)]


def _sim_perf_case(emit, seed: int = 0, n_items: int = 2000,
                   repeats: int = 3) -> dict:
    """Optimized vs reference simulator event loop on one deterministic
    synthetic workload (the shapes the DSE search's confirmation rounds
    hammer): three harts of mixed vector/LSU/scalar items, het-MIMD
    contention. Asserts identical results before timing."""
    import random

    from benchmarks.paper_data import make_config
    from repro.core.isa import OPDEFS, Instr, Scalar
    from repro.core.simulator import Simulator

    rng = random.Random(seed)
    ops = list(OPDEFS)

    def prog(n):
        items = []
        for _ in range(n):
            if rng.random() < 0.3:
                items.append(Scalar(rng.randrange(1, 40)))
            else:
                items.append(Instr(rng.choice(ops), dst=0, src1=4,
                                   src2=8 if rng.random() < 0.5
                                   else None,
                                   length=rng.randrange(1, 300)))
        return items

    programs = [prog(n_items) for _ in range(3)]
    sim = Simulator(make_config("HetMIMD", 8))

    ref = sim._run_reference(programs)
    opt = sim.run(programs)
    identical = (opt.cycles == ref.cycles
                 and opt.mfu_busy_cycles == ref.mfu_busy_cycles
                 and opt.lsu_busy_cycles == ref.lsu_busy_cycles
                 and all(a.breakdown() == b.breakdown()
                         for a, b in zip(opt.per_hart, ref.per_hart)))

    opt_s = min(_timed(sim.run, programs) for _ in range(repeats))
    ref_s = min(_timed(sim._run_reference, programs)
                for _ in range(repeats))
    row = {"n_items": 3 * n_items, "cycles": opt.cycles,
           "optimized_s": round(opt_s, 4), "reference_s": round(ref_s, 4),
           "speedup": round(ref_s / max(opt_s, 1e-9), 2),
           "identical_results": identical}
    emit(f"simulator  {row['n_items']} items: optimized {opt_s:.4f}s vs "
         f"reference {ref_s:.4f}s -> {row['speedup']:.2f}x "
         f"(identical={identical})")
    return row


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def _pallas_batch_case(S: int, n_instances: int, emit,
                       seed: int = 0) -> dict:
    from repro.kvi.pallas_backend import PallasBackend
    from repro.kvi.workload import KviWorkload

    kernel = f"conv{S}"
    progs = _conv_instances(S, n_instances, seed)

    per = PallasBackend()
    t0 = time.perf_counter()
    per_results = [per.run(p) for p in progs]
    per_s = time.perf_counter() - t0

    bat = PallasBackend()
    wl = KviWorkload.homogeneous(progs)
    t0 = time.perf_counter()
    bat_result = bat.run_workload(wl)
    bat_s = time.perf_counter() - t0

    for r_per, r_bat in zip(per_results, bat_result.entry_results):
        for k in r_per.outputs:
            assert np.array_equal(r_per.outputs[k], r_bat.outputs[k]), k

    row = {
        "kernel": kernel, "n_instances": n_instances,
        "per_program_s": round(per_s, 4), "batched_s": round(bat_s, 4),
        "speedup": round(per_s / max(bat_s, 1e-9), 2),
        "per_program_pallas_calls": per.fused_calls + per.reduce_calls,
        "batched_pallas_calls": bat.fused_calls + bat.reduce_calls,
    }
    emit(f"{kernel:10s} N={n_instances}: per-program {per_s:.3f}s "
         f"({row['per_program_pallas_calls']} pallas_calls) vs batched "
         f"{bat_s:.3f}s ({row['batched_pallas_calls']} pallas_calls) "
         f"-> {row['speedup']:.2f}x")
    return row


def run(emit, seed: int = 0) -> dict:
    from benchmarks.paper_data import make_config
    from repro.core.workloads import composite_cycles

    emit("# --- cyclesim: composite workload cycles per scheme ---")
    cyclesim = {}
    for scheme, D in [("SISD", 1), ("SymMIMD", 8), ("HetMIMD", 8)]:
        r = composite_cycles(make_config(scheme, D))
        key = f"{scheme}_D{D}"
        cyclesim[key] = r
        emit(f"{key:12s} conv32={r['conv32']:.0f} fft256={r['fft256']:.0f} "
             f"matmul64={r['matmul64']:.0f} total={r['total_cycles']}")

    emit("# --- sim_perf: optimized vs reference event loop ---")
    sim_perf = _sim_perf_case(emit, seed)

    emit("# --- pallas: batched vs per-program dispatch ---")
    pallas = [
        _pallas_batch_case(8, 8, emit, seed),
        _pallas_batch_case(16, 8, emit, seed),
    ]

    out = {"seed": seed,
           "cyclesim_composite": cyclesim, "sim_perf": sim_perf,
           "pallas_batch": pallas,
           "checks": {
               "batched_fewer_dispatches": all(
                   row["batched_pallas_calls"] < row["per_program_pallas_calls"]
                   for row in pallas),
               "sim_loop_faster": sim_perf["speedup"] > 1.0
               and sim_perf["identical_results"]}}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kvi_batch.json")
    ap.add_argument("--seed", type=int, default=0,
                    help="program input-data seed (reproducible inputs)")
    args = ap.parse_args(argv)
    result = run(emit=print, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
