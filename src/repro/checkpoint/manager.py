"""Sharded, atomic, async checkpointing with elastic restore.

Layout (tensorstore-free so it runs anywhere):

  <dir>/step_000123.tmp/        — written first
      manifest.json             — tree structure, shapes, dtypes, step, hash
      arrays.npz                — flat {path: ndarray} (host-local shards on
                                  multi-host; full arrays on single host)
  <dir>/step_000123/            — atomic rename commit
  <dir>/LATEST                  — text file with the last committed step

Fault-tolerance contract:
  * a crash mid-save never corrupts an existing checkpoint (tmp + rename)
  * ``save(..., blocking=False)`` runs in a background thread (training
    continues; ``wait()`` joins before the next save or at exit)
  * restore works onto a DIFFERENT mesh/host-count (elastic): arrays are
    saved unsharded-logical and re-sharded with the target sharding on load
  * integrity: manifest carries a per-array crc32; restore verifies
"""
from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np
import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any], template):
    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [build(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        return flat[prefix[:-1]]
    return build(template)


def save(ckpt_dir, step: int, tree, *, blocking: bool = True,
         keep: int = 3) -> threading.Thread:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    # device -> host copy happens on the caller thread (consistent snapshot)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        tmp = ckpt_dir / f"step_{step:09d}.tmp"
        final = ckpt_dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **host)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": zlib.crc32(v.tobytes())}
                       for k, v in host.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                       # atomic commit
        (ckpt_dir / "LATEST.tmp").write_text(str(step))
        (ckpt_dir / "LATEST.tmp").rename(ckpt_dir / "LATEST")
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=False)
    t.start()
    return t


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    f = Path(ckpt_dir) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore(ckpt_dir, template, *, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Load into the structure of ``template``; if ``shardings`` (matching
    pytree of NamedSharding / None) is given, device_put each array with it
    — this is the elastic path (any target mesh/host count)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        host = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["arrays"].items():
            crc = zlib.crc32(host[k].tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch for {k} in {d}")
    flat_shardings = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, v in host.items():
        sh = flat_shardings.get(k)
        out[k] = jax.device_put(v, sh) if sh is not None else jax.numpy.asarray(v)
    return _unflatten(out, template), step


class CheckpointManager:
    """Coordinates periodic async saves + preemption-triggered sync save."""

    def __init__(self, ckpt_dir, *, interval: int = 100, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.interval = interval
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, *, force: bool = False):
        if not force and (self.interval <= 0 or step % self.interval):
            return False
        self.wait()
        self._pending = save(self.dir, step, tree, blocking=False,
                             keep=self.keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, template, shardings=None):
        return restore(self.dir, template, shardings=shardings)
