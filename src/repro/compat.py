"""Small jax version-compat shims (single home, imported lazily).

The repo targets current jax, but the pinned environment may lag: these
helpers paper over API moves without scattering try/except through the
codebase.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map.shard_map``
    (<= 0.4.x, where ``check_vma`` was called ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
