"""Serving driver: batched request serving with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 16 --slots 4 --max-seq 128
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs import get_spec, reduced_model
from repro.models import model_zoo as zoo
from repro.models import params as params_lib
from repro.serving.engine import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = get_spec(args.arch)
    cfg = reduced_model(spec.model) if args.reduced else spec.model
    params = params_lib.initialize(zoo.param_template(cfg),
                                   jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        engine.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    ttfts = [r.first_token_at - r.submitted_at for r in done]
    print(f"served {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s), "
          f"TTFT p50={np.percentile(ttfts, 50):.2f}s "
          f"p99={np.percentile(ttfts, 99):.2f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
