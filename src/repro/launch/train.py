"""Training driver: config-driven, checkpointed, fault-tolerant.

Single-host usage (CPU example, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real multi-host TPU fleet the same driver runs per host (jax
distributed init is a no-op on CPU); the mesh comes from launch.mesh and
data sharding from DataConfig(num_hosts, host_id). Fault tolerance:
periodic async checkpoints, preemption-triggered sync save, straggler
logging, resume-from-LATEST.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, latest_step
from repro.configs import get_spec, reduced_model
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models import model_zoo as zoo
from repro.models import params as params_lib
from repro.models import steps as steps_lib
from repro.models.sharding import make_rules
from repro.optim.optimizer import OptimizerConfig, adamw_init
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerDetector


def build_trainer(arch: str, *, reduced: bool, seq: int, batch: int,
                  steps: int, mesh=None, data_path=None, seed=0,
                  lr: float = 3e-4):
    spec = get_spec(arch)
    cfg = reduced_model(spec.model) if reduced else spec.model
    par = spec.parallelism if mesh is not None else \
        spec.parallelism.replace(remat="none", fsdp=False,
                                 sequence_parallel=False)
    shape = ShapeConfig("train", "train", seq, batch)
    rules = make_rules(mesh, cfg, par)
    opt_cfg = OptimizerConfig(lr=lr, total_steps=steps,
                              warmup_steps=max(10, steps // 20),
                              moment_dtype=par.moment_dtype)
    train_step = steps_lib.make_train_step(cfg, rules, par, opt_cfg)
    data = DataPipeline(cfg, shape, DataConfig(
        source="file" if data_path else "synthetic", path=data_path,
        seed=seed))
    return cfg, par, shape, rules, train_step, data, opt_cfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--data", default="", help="text file (byte tokenizer)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg, par, shape, rules, train_step, data, opt_cfg = build_trainer(
        args.arch, reduced=args.reduced, seq=args.seq, batch=args.batch,
        steps=args.steps, data_path=args.data or None, seed=args.seed,
        lr=args.lr)

    n_params = zoo.param_count(cfg)
    print(f"arch={args.arch} reduced={args.reduced} params={n_params:,} "
          f"seq={args.seq} batch={args.batch}")

    template = zoo.param_template(cfg)
    params = params_lib.initialize(template, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params, opt_cfg)
    start_step = 0

    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval) \
        if args.ckpt_dir else None
    if ckpt and args.resume and latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": opt_state}
        tree, start_step = ckpt.restore_latest(tree)
        params, opt_state = tree["params"], tree["opt"]
        print(f"resumed from step {start_step}")

    jit_step = jax.jit(train_step, donate_argnums=(0, 1))
    detector = StragglerDetector(hosts=[0])
    losses = []
    t_last = time.time()
    with PreemptionGuard() as guard:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in data.batch_at(step).items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                dt = time.time() - t_last
                t_last = time.time()
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)",
                      flush=True)
            if ckpt:
                ckpt.maybe_save(step + 1, {"params": params, "opt": opt_state},
                                force=guard.requested)
            if guard.requested:
                print("preemption requested: checkpoint saved, exiting")
                break
    if ckpt:
        ckpt.wait()
    if len(losses) >= 2:
        print(f"loss {losses[0][1]:.4f} -> {losses[-1][1]:.4f} "
              f"({'improved' if losses[-1][1] < losses[0][1] else 'NOT improved'})")
    data.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
