import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes (16x16 single-pod, 2x16x16 multi-pod) and record
memory/cost/collective analyses to artifacts/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
"""
import argparse
import contextlib
import json
import sys
import time
import traceback
from pathlib import Path


def _run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
              overrides=None, tag: str = "") -> dict:
    import jax
    from repro.launch.compile import (build_cell, estimate_device_memory,
                                      estimate_hbm_traffic, lower_cell)
    from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
    from repro.launch.mesh import HW, make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, overrides=overrides)
    lowered, _ = lower_cell(cell)
    t_lower = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    # trip-count-aware per-device accounting from the optimized SPMD HLO
    hlo = compiled.as_text()
    acct = analyze_hlo(hlo, top_collectives=8)
    flops = acct["dot_flops"]
    hbm_bytes = acct["hbm_bytes"]
    coll = acct["collective_bytes"]

    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    print(f"[{arch} {shape_name}] memory_analysis: {mem}", flush=True)
    print(f"[{arch} {shape_name}] cost_analysis: "
          f"flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e} "
          f"(per-instruction-once; trip-aware totals recorded in the "
          f"artifact)", flush=True)
    mem_d = {
        "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
        "xla_cost_flops_once": float(cost.get("flops", 0.0)) if cost else 0.0,
        "xla_cost_bytes_once":
            float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
    }
    est = estimate_device_memory(cell)
    traffic = estimate_hbm_traffic(cell)

    # roofline terms; per-device quantities / per-chip rates (DESIGN.md §5)
    terms = {
        "t_compute_s": flops / HW["peak_flops_bf16"],
        "t_memory_s": traffic["total"] / HW["hbm_bw"],
        "t_memory_hlo_upper_s": hbm_bytes / HW["hbm_bw"],
        "t_collective_s": coll["total"] / HW["ici_bw"],
    }
    terms["bottleneck"] = max(
        ["t_compute_s", "t_memory_s", "t_collective_s"],
        key=lambda k: terms[k])

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "chips": int(n_chips), "tag": tag,
        "kind": cell.shape.kind,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll,
        "top_collectives": acct.get("top_collectives", []),
        "memory_analysis": mem_d,
        "estimated_device_memory": est,
        "hbm_traffic_model": traffic,
        "per_device_live_bytes": est["total"],
        "fits_hbm": bool(est["total"] < HW["hbm_bytes"]),
        "roofline": terms,
        "downgrades": [list(map(str, d)) for d in cell.rules.downgrades],
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "status": "ok",
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    pod = "multipod" if multi_pod else "pod"
    name = f"{arch}_{shape_name}_{pod}{('_' + tag) if tag else ''}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=2))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--override", action="append", default=[],
                    help="key=value Parallelism/ModelConfig override")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        with contextlib.suppress(json.JSONDecodeError):
            v = json.loads(v)
        overrides[k] = v

    from repro.configs import all_cells, arch_cells
    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required without --all"
        cells = arch_cells(args.arch) if not args.shape else \
            [(args.arch, args.shape)]

    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    out_dir = Path(args.out)
    failures = []
    for arch, shape in cells:
        for mp in pods:
            pod = "multipod" if mp else "pod"
            fname = out_dir / f"{arch}_{shape}_{pod}{('_' + args.tag) if args.tag else ''}.json"
            if args.skip_existing and fname.exists():
                prev = json.loads(fname.read_text())
                if prev.get("status") == "ok":
                    print(f"SKIP {arch} {shape} {pod} (cached)")
                    continue
            label = f"{arch} {shape} {pod}"
            try:
                rec = _run_cell(arch, shape, mp, out_dir,
                                overrides=overrides or None, tag=args.tag)
                r = rec["roofline"]
                print(f"OK   {label}: compile={rec['t_compile_s']}s "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"est/dev={rec['per_device_live_bytes']/2**30:.2f}GiB "
                      f"fits={rec['fits_hbm']} "
                      f"[comp={r['t_compute_s']:.4f}s mem={r['t_memory_s']:.4f}s "
                      f"coll={r['t_collective_s']:.4f}s -> {r['bottleneck']}]",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record & continue sweep
                failures.append(label)
                out_dir.mkdir(parents=True, exist_ok=True)
                fname.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "status": "fail",
                     "error": traceback.format_exc()}, indent=2))
                print(f"FAIL {label}: {type(e).__name__}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        return 1
    print("\nALL CELLS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
