"""Trip-count-aware HLO accounting.

XLA's builtin ``compiled.cost_analysis()`` visits every instruction ONCE —
a `lax.scan` over 64 layers reports 1/64th of the real FLOPs. This module
parses the *optimized per-device* HLO text (``compiled.as_text()``), walks
the call graph (fusions, while bodies with ``known_trip_count``,
conditionals) and produces:

  * dot_flops        — 2 * result_elems * contracted_elems per dot op
  * hbm_bytes        — Σ (result + operand bytes) at fusion granularity,
                       a TPU-like HBM-traffic proxy (fusion internals free)
  * collective_bytes — per collective kind, operand bytes (wire-byte proxy)

All quantities are per-device (the HLO is the per-device SPMD program).
Validated in tests against jax's cost_analysis on loop-free programs.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*(?:\(.*\))?\s*->.*\{|"
                          r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s+\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"\b(?:calls|to_apply|body)=(%[\w\.\-]+)")
_COND_RE = re.compile(r"\bcondition=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"(%[\w\.\-]+)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM data
_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "add-dependency", "custom-call", "partition-id",
             "replica-id", "iota"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems_first(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    result_text: str
    opcode: str
    rest: str          # everything after '(' — operands + attrs

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.result_text)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # name -> result text


@dataclass
class Totals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})
    # attribution: (kind, total_bytes_incl_trips, op_name_metadata)
    coll_items: List[tuple] = field(default_factory=list)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        for kind, b, name in other.coll_items:
            self.coll_items.append((kind, b * mult, name))

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        # computation header: "%name (args) -> type {" possibly with ENTRY
        if (line.startswith("%") or line.startswith("ENTRY")) and \
                line.endswith("{"):
            name = line.split()[1] if line.startswith("ENTRY") else \
                line.split()[0]
            name = name.split("(")[0].strip()
            cur = Computation(name)
            comps[name] = cur
            if line.startswith("ENTRY"):
                entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_text, opcode, rest = m.groups()
        cur.instrs.append(Instr(name, result_text, opcode, rest))
        cur.shapes[name] = result_text
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _operand_names(rest: str) -> List[str]:
    """operand list = %names inside the first balanced paren group."""
    depth = 1
    out = []
    i = 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    inner = rest[:i - 1] if depth == 0 else rest
    return _OPERAND_RE.findall(inner)


def _dot_flops(instr: Instr, comp: Computation) -> float:
    ops = _operand_names(instr.rest)
    if not ops:
        return 0.0
    lhs_text = comp.shapes.get(ops[0], "")
    lhs_dims = _shape_elems_first(lhs_text) or []
    mc = _DOT_CONTRACT_RE.search(instr.rest)
    contracted = 1
    if mc and lhs_dims:
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    res_dims = _shape_elems_first(instr.result_text) or []
    res_elems = 1
    for d in res_dims:
        res_elems *= d
    return 2.0 * res_elems * contracted


def _analyze_comp(comp_name: str, comps: Dict[str, Computation],
                  memo: Dict[str, Totals], inside_fusion: bool) -> Totals:
    key = comp_name + ("#f" if inside_fusion else "")
    if key in memo:
        return memo[key]
    comp = comps.get(comp_name)
    t = Totals()
    memo[key] = t
    if comp is None:
        return t
    for ins in comp.instrs:
        op = ins.opcode
        if op == "dot":
            t.flops += _dot_flops(ins, comp)
            if not inside_fusion:
                t.hbm_bytes += ins.result_bytes + sum(
                    _shape_bytes(comp.shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            operand_bytes = sum(_shape_bytes(comp.shapes.get(o, ""))
                                for o in _operand_names(ins.rest))
            b = operand_bytes or ins.result_bytes
            t.coll[base] += b
            mname = re.search(r'op_name="([^"]*)"', ins.rest)
            t.coll_items.append((base, b, mname.group(1) if mname else "?"))
            if not inside_fusion:
                t.hbm_bytes += ins.result_bytes + operand_bytes
            continue
        if op == "while":
            body = _CALLS_RE.search(ins.rest)
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            if body:
                t.add(_analyze_comp(body.group(1), comps, memo, False), trip)
            continue
        if op in ("fusion", "call", "async-start"):
            called = _CALLS_RE.search(ins.rest)
            if called:
                sub = _analyze_comp(called.group(1), comps, memo,
                                    op == "fusion")
                t.add(sub, 1.0)
            if not inside_fusion:
                t.hbm_bytes += ins.result_bytes + sum(
                    _shape_bytes(comp.shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
            continue
        if op == "conditional":
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                subs = [_analyze_comp(b.strip(), comps, memo, False)
                        for b in mb.group(1).split(",")]
                if subs:
                    best = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                    t.add(best, 1.0)
            continue
        if op in _FREE_OPS:
            continue
        if not inside_fusion:
            t.hbm_bytes += ins.result_bytes + sum(
                _shape_bytes(comp.shapes.get(o, ""))
                for o in _operand_names(ins.rest))
    return t


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a per-device list of dicts, newer ones a single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_hlo(hlo_text: str, top_collectives: int = 0) -> dict:
    comps = parse_module(hlo_text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found in HLO text")
    memo: Dict[str, Totals] = {}
    t = _analyze_comp(comps["__entry__"].name, comps, memo, False)
    out = {
        "dot_flops": t.flops,
        "hbm_bytes": t.hbm_bytes,
        "collective_bytes": dict(t.coll, total=t.coll_total),
    }
    if top_collectives:
        items = sorted(t.coll_items, key=lambda x: -x[1])[:top_collectives]
        out["top_collectives"] = [
            {"kind": k, "bytes": b, "op": n[-160:]} for k, b, n in items]
    return out
