"""Production mesh definitions.

The mesh axes follow the paper's TLP/DLP decomposition: ``data`` (and
``pod``) carry thread-level parallelism (the IMT harts, scaled out),
``model`` carries data-level parallelism (the vector lanes D, scaled up).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes)


# TPU v5e-class hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16 * 1024**3,     # capacity per chip
}
