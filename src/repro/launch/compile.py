"""Shared lowering machinery for the dry-run, roofline and train/serve
drivers: build abstract params/opt-state/cache/batch for an (arch, shape,
mesh) cell and lower+compile the right step — with zero real allocation
(everything is ShapeDtypeStruct until a driver decides to materialize).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec, get_shape
from repro.configs.base import ModelConfig, Parallelism, ShapeConfig
from repro.models import model_zoo as zoo
from repro.models import params as params_lib
from repro.models import steps as steps_lib
from repro.models.sharding import Rules, make_rules
from repro.optim.optimizer import OptimizerConfig, adamw_init


@dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    par: Parallelism
    shape: ShapeConfig
    rules: Rules
    mesh: Any


def build_cell(arch: str, shape_name: str, mesh, *, overrides=None) -> Cell:
    spec = get_spec(arch)
    cfg, par = spec.model, spec.parallelism
    if overrides:
        for k, v in overrides.items():
            if hasattr(par, k):
                par = par.replace(**{k: v})
            else:
                cfg = cfg.replace(**{k: v})
    shape = get_shape(shape_name)
    if shape.kind != "train" and cfg.param_dtype == "float32":
        # serving cells load bf16 weights (standard inference checkpoints)
        cfg = cfg.replace(param_dtype="bfloat16")
    rules = make_rules(mesh, cfg, par)
    return Cell(arch, cfg, par, shape, rules, mesh)


def _attach(rules: Rules, template):
    """P-template -> ShapeDtypeStruct tree with NamedShardings attached."""
    return params_lib.abstract(template, rules)


def abstract_inputs(cell: Cell):
    """Abstract (params, opt_state?, cache?, batch) for the cell's step."""
    cfg, par, shape, rules = cell.cfg, cell.par, cell.shape, cell.rules
    p_t = zoo.param_template(cfg)
    params = _attach(rules, p_t)
    batch = _attach(rules, steps_lib.batch_template(cfg, shape))
    if shape.kind == "train":
        opt_cfg = OptimizerConfig(moment_dtype=par.moment_dtype)
        opt_state = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
        # re-attach shardings: moments shard like their parameters
        opt_state = _shard_opt_state(opt_state, params, rules)
        return {"params": params, "opt_state": opt_state, "batch": batch}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch}
    cache = _attach(rules, steps_lib.cache_template(cfg, shape))
    return {"params": params, "cache": cache, "batch": batch}


def _shard_opt_state(opt_state, params, rules: Rules):
    """Give Adam moments the same sharding as their parameter (int8 moment
    dicts {q,s}: q like the param, s like the param minus last dim)."""
    if rules.mesh is None:
        return opt_state

    def like_param(mom, par_leaf):
        if isinstance(mom, dict) and set(mom) == {"q", "s"}:
            q = jax.ShapeDtypeStruct(mom["q"].shape, mom["q"].dtype,
                                     sharding=par_leaf.sharding)
            # scale: same spec with last dim replicated
            spec = par_leaf.sharding.spec if par_leaf.sharding else None
            if spec is not None and len(mom["s"].shape):
                sspec = list(spec) + [None] * (len(mom["s"].shape) - len(spec))
                sspec = sspec[:len(mom["s"].shape) - 1] + [None]
                sh = jax.sharding.NamedSharding(
                    rules.mesh, jax.sharding.PartitionSpec(*sspec))
            else:
                sh = None
            s = jax.ShapeDtypeStruct(mom["s"].shape, mom["s"].dtype, sharding=sh)
            return {"q": q, "s": s}
        return jax.ShapeDtypeStruct(mom.shape, mom.dtype,
                                    sharding=par_leaf.sharding)

    is_mom = lambda x: (isinstance(x, dict) and set(x) == {"q", "s"}) or \
        isinstance(x, jax.ShapeDtypeStruct)
    new = dict(opt_state)
    for key in ("m", "v"):
        new[key] = jax.tree_util.tree_map(
            like_param, opt_state[key], params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"})
    return new


def make_step_fn(cell: Cell):
    cfg, par, shape, rules = cell.cfg, cell.par, cell.shape, cell.rules
    step = steps_lib.make_step(cfg, rules, par, shape)
    kind = shape.kind
    if kind == "train":
        fn = lambda params, opt_state, batch: step(params, opt_state, batch)
        donate = (0, 1)
    elif kind == "prefill":
        fn = lambda params, batch: step(params, batch)
        donate = ()
    else:
        fn = lambda params, cache, batch: step(params, cache, batch)
        donate = (1,)
    return fn, donate


def lower_cell(cell: Cell):
    """jit(...).lower(...) for the cell; returns (lowered, abstract args)."""
    inputs = abstract_inputs(cell)
    fn, donate = make_step_fn(cell)
    jfn = jax.jit(fn, donate_argnums=donate)
    if cell.shape.kind == "train":
        args = (inputs["params"], inputs["opt_state"], inputs["batch"])
    elif cell.shape.kind == "prefill":
        args = (inputs["params"], inputs["batch"])
    else:
        args = (inputs["params"], inputs["cache"], inputs["batch"])
    if cell.mesh is not None:
        with cell.mesh:
            lowered = jfn.lower(*args)
    else:
        lowered = jfn.lower(*args)
    return lowered, args


# ---------------------------------------------------------------------------
# analytic per-device memory estimate (TPU HBM fit)
#
# XLA:CPU's buffer assignment over-estimates TPU HBM use (f32 promotion of
# bf16 dots, conservative aliasing, host-friendly scheduling), so the
# ``fits_hbm`` verdict uses this analytic model; the raw memory_analysis()
# numbers are recorded alongside for reference.
# ---------------------------------------------------------------------------

def _sharded_leaf_bytes(p, rules: Rules) -> float:
    spec = rules.spec(p.axes, p.shape)
    denom = 1
    for axes in spec:
        if axes is not None:
            denom *= rules.axis_size(axes)
    return float(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize / max(denom, 1)


def _template_bytes(template, rules: Rules) -> float:
    leaves = jax.tree_util.tree_leaves(
        template, is_leaf=lambda x: isinstance(x, params_lib.P))
    return sum(_sharded_leaf_bytes(p, rules) for p in leaves)


def _template_elems(template, rules: Rules) -> float:
    leaves = jax.tree_util.tree_leaves(
        template, is_leaf=lambda x: isinstance(x, params_lib.P))
    return sum(_sharded_leaf_bytes(p, rules) / jnp.dtype(p.dtype).itemsize
               for p in leaves)


def estimate_device_memory(cell: Cell) -> dict:
    """Per-device HBM bytes by component (documented in EXPERIMENTS.md)."""
    cfg, par, shape, rules = cell.cfg, cell.par, cell.shape, cell.rules
    p_t = zoo.param_template(cfg)
    params_b = _template_bytes(p_t, rules)
    batch_b = _template_bytes(steps_lib.batch_template(cfg, shape), rules)
    out = {"params": params_b, "batch": batch_b}

    dsize = rules.axis_size(rules.mapping.get("batch")) or 1
    msize = rules.axis_size("model") if rules.mesh is not None else 1
    B_loc = max(shape.global_batch // max(dsize, 1), 1)
    S = shape.seq_len if shape.kind != "decode" else 1
    sp = rules.axis_size(rules.mapping.get("seq_sp")) \
        if par.sequence_parallel else 1
    S_loc = max(S // max(sp, 1), 1)
    act_bytes = jnp.dtype(cfg.dtype).itemsize

    if shape.kind == "train":
        out["grads"] = params_b                      # params stored in f32
        if par.moment_dtype == "int8":
            out["moments"] = 2 * (params_b / 4 * 1.03)     # q + per-row scales
        elif par.moment_dtype == "bfloat16":
            out["moments"] = 2 * params_b / 2
        else:
            out["moments"] = 2 * params_b
        layers = cfg.num_layers + cfg.encoder_layers
        out["saved_activations"] = (layers * B_loc * S_loc * cfg.d_model *
                                    act_bytes)
        Vp_loc = zoo.padded_vocab(cfg.vocab_size) // max(msize, 1)
        out["logits_transient"] = B_loc * S_loc * Vp_loc * (4 + 2)
    else:
        if shape.kind in ("prefill", "decode"):
            out["cache"] = _template_bytes(
                steps_lib.cache_template(cfg, shape), rules)
    # transient working set of one block (attention tiles + ffn hidden)
    width = max(cfg.d_ff // max(msize, 1),
                (cfg.num_heads or 1) * max(cfg.head_dim, 1) // max(msize, 1),
                cfg.d_inner if cfg.ssm_state else 0,
                par.attn_kv_block * 4)
    out["block_transient"] = 4 * B_loc * min(S_loc, 32768) * width * act_bytes
    out["total"] = float(sum(out.values()))
    return out


# ---------------------------------------------------------------------------
# analytic HBM traffic model (per device, per step) — the roofline memory
# term. The HLO-derived byte count is recorded as an upper bound (XLA:CPU
# fuses far less than TPU and promotes bf16->f32), this model is the
# TPU-granularity estimate; every component is reported so the numbers can
# be audited. Formulas documented in EXPERIMENTS.md §Roofline.
# ---------------------------------------------------------------------------

def estimate_hbm_traffic(cell: Cell, *, attention_impl: str = "xla") -> dict:
    cfg, par, shape, rules = cell.cfg, cell.par, cell.shape, cell.rules
    f32, act = 4, jnp.dtype(cfg.dtype).itemsize
    msize = rules.axis_size("model") if rules.mesh is not None else 1
    dsize = rules.axis_size(rules.mapping.get("batch")) or 1
    B_loc = max(shape.global_batch // max(dsize, 1), 1)
    S = shape.seq_len
    train = shape.kind == "train"
    decode = shape.kind == "decode"

    p_t = zoo.param_template(cfg)
    P_loc = _template_elems(p_t, rules)                # param elems / device
    if cfg.num_experts:
        frac_active = zoo.active_param_count(cfg) / zoo.param_count(cfg)
    else:
        frac_active = 1.0

    out = {}
    if train:
        # bf16 casts read 3x (fwd, bwd, remat) + f32 p r/w + grad w/r + m,v r/w
        out["weights"] = P_loc * (3 * act + 3 * f32 + 4 * f32)
    elif decode:
        out["weights"] = P_loc * frac_active * act     # single sparse read
    else:
        out["weights"] = P_loc * act                   # prefill: one full read

    layers = cfg.num_layers + cfg.encoder_layers
    if decode:
        T_loc = B_loc
    else:
        T_loc = B_loc * S
    D = cfg.d_model
    F_loc = cfg.d_ff / max(msize, 1) if cfg.d_ff else 0
    Hhd_loc = max(cfg.num_heads * max(cfg.head_dim, 1) / max(msize, 1), 0)
    di = cfg.d_inner if cfg.ssm_state else 0
    # r/w passes over layer activations: ~10 major ops fwd (x2 for r+w),
    # x2.2 for bwd+remat in training
    passes = 22 * (2.2 if train else 1.0)
    per_layer = T_loc * (D * passes + F_loc * 8 + Hhd_loc * 8 + di * 10) * act
    if cfg.num_experts:
        topk_cf = cfg.num_experts_per_tok * cfg.capacity_factor
        per_layer += T_loc * topk_cf * (D * 8 + F_loc * 8) * act
    out["activations"] = layers * per_layer

    # attention score traffic (XLA path materializes block scores in HBM;
    # the Pallas flash kernel keeps them in VMEM -> term vanishes)
    if cfg.num_heads and not decode and attention_impl == "xla":
        H_loc = max(cfg.num_heads / max(msize, 1), 1)
        # baseline masks but still computes the full S x S score blocks;
        # swa_block_skip only visits the (window + q_block) span
        if cfg.sliding_window and par.swa_block_skip:
            S_eff = min(S, cfg.sliding_window + par.attn_q_block)
        else:
            S_eff = S
        s2 = B_loc * H_loc * S * S_eff * f32
        out["attn_scores"] = s2 * 4 * (3 if train else 1)
    if decode and cfg.num_heads:
        slots = steps_lib.cache_slots(cfg, shape)
        KV_loc = cfg.num_kv_heads * max(cfg.head_dim, 1) / \
            (max(msize, 1) if cfg.num_kv_heads % max(msize, 1) == 0 else 1)
        out["kv_cache"] = layers * B_loc * slots * KV_loc * 2 * act
    if decode and cfg.ssm_state:
        st = B_loc * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state
        out["ssm_state"] = layers * st * 2 * f32
    Vp_loc = zoo.padded_vocab(cfg.vocab_size) / max(msize, 1)
    toks_logits = T_loc if train else B_loc
    out["logits"] = toks_logits * Vp_loc * ((act + 3 * f32) if train else act)
    out["total"] = float(sum(out.values()))
    return out
