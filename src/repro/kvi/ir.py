"""Typed KVI program IR — the paper's Table-1 vector ISA, authored once.

A :class:`KviProgram` is a backend-neutral description of a Klessydra-T
vector computation: named virtual vector registers (``VReg``), main-memory
buffers (``MemRef``), and a linear sequence of :class:`KviInstr` /
:class:`ScalarBlock` items. The same program object runs on any registered
:class:`~repro.kvi.backend.Backend`:

  * ``oracle``    — pure numpy functional semantics (repro.core.mfu),
  * ``cyclesim``  — functional semantics + cycle timing for the paper's
                    three coprocessor schemes (repro.core.simulator),
  * ``pallas``    — fused Pallas kernels (element-wise subgraphs compiled
                    into single ``pl.pallas_call`` invocations).

Operands are :class:`Ref` values: (space, id, element offset). A ``View``
is a builder-side convenience — a (register, offset, length) window that
op emitters accept wherever a vector operand is expected.

Sub-word SIMD: every ``VReg`` carries ``elem_bytes`` (4/2/1 for
32/16/8-bit lanes, paper §"sub-word SIMD"); instructions inherit it from
their operands and backends pack lanes accordingly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


class KviOp(Enum):
    """Paper Table 1, verbatim. ``value`` is the assembly mnemonic and the
    key into ``repro.core.isa.OPDEFS`` (timing/contention classes)."""

    KMEMLD = "kmemld"
    KMEMSTR = "kmemstr"
    KADDV = "kaddv"
    KSUBV = "ksubv"
    KVMUL = "kvmul"
    KVRED = "kvred"
    KDOTP = "kdotp"
    KSVADDSC = "ksvaddsc"
    KSVADDRF = "ksvaddrf"
    KSVMULSC = "ksvmulsc"
    KSVMULRF = "ksvmulrf"
    KDOTPPS = "kdotpps"
    KSRLV = "ksrlv"
    KSRAV = "ksrav"
    KRELU = "krelu"
    KVSLT = "kvslt"
    KSVSLT = "ksvslt"
    KVCP = "kvcp"


# op classes (drive backend dispatch)
MEM_OPS = frozenset({KviOp.KMEMLD, KviOp.KMEMSTR})
REDUCTION_OPS = frozenset({KviOp.KVRED, KviOp.KDOTP, KviOp.KDOTPPS,
                           KviOp.KSVADDRF, KviOp.KSVMULRF})
ELEMWISE_OPS = frozenset({KviOp.KADDV, KviOp.KSUBV, KviOp.KVMUL,
                          KviOp.KSVADDSC, KviOp.KSVMULSC, KviOp.KSRLV,
                          KviOp.KSRAV, KviOp.KRELU, KviOp.KVSLT,
                          KviOp.KSVSLT, KviOp.KVCP})
TWO_SOURCE_OPS = frozenset({KviOp.KADDV, KviOp.KSUBV, KviOp.KVMUL,
                            KviOp.KVSLT, KviOp.KDOTP, KviOp.KDOTPPS})


@dataclass(frozen=True)
class Ref:
    """One operand reference: a window base inside a vreg or a memory
    buffer handle. ``offset`` is in elements (not bytes)."""

    space: str                       # "vreg" | "mem"
    id: int
    offset: int = 0

    def __post_init__(self):
        if self.space not in ("vreg", "mem"):
            raise ValueError(f"bad operand space {self.space!r}")
        if self.offset < 0:
            raise ValueError(
                f"negative offset {self.offset} in {self.space} operand "
                f"#{self.id}")


@dataclass(frozen=True)
class KviInstr:
    """One KVI instruction over IR operands. Frozen — programs are
    immutable once built, so every backend sees the same trace."""

    op: KviOp
    dst: Optional[Ref] = None
    src1: Optional[Ref] = None
    src2: Optional[Ref] = None
    scalar: int = 0
    length: int = 0
    elem_bytes: int = 4

    def __post_init__(self):
        if not isinstance(self.op, KviOp):
            raise TypeError(f"op must be KviOp, got {self.op!r}")
        if self.length <= 0:
            raise ValueError(f"{self.op.value}: length must be > 0")
        if self.elem_bytes not in (1, 2, 4):
            raise ValueError(f"elem_bytes must be 1/2/4, got {self.elem_bytes}")
        if self.op in TWO_SOURCE_OPS and self.src2 is None:
            raise ValueError(f"{self.op.value} needs two vector sources")


@dataclass(frozen=True)
class ScalarBlock:
    """A compressed run of ``count`` scalar (non-coprocessor) instructions
    — loop bookkeeping, address arithmetic, branches."""

    count: int


Item = Union[KviInstr, ScalarBlock]


class VReg:
    """A named virtual vector register (an SPM-resident vector in the
    hardware model; a VMEM/regfile tile on Pallas). Index/slice to get a
    sub-window ``View``."""

    __slots__ = ("name", "id", "length", "elem_bytes")

    def __init__(self, name: str, id: int, length: int, elem_bytes: int = 4):
        if length <= 0:
            raise ValueError(
                f"vreg {name!r}: length must be > 0, got {length}")
        if elem_bytes not in (1, 2, 4):
            raise ValueError(
                f"vreg {name!r}: elem_bytes must be 1/2/4, got {elem_bytes}")
        self.name = name
        self.id = id
        self.length = length
        self.elem_bytes = elem_bytes

    def view(self, offset: int, length: int) -> "View":
        return View(self, offset, length)

    def __getitem__(self, key) -> "View":
        if isinstance(key, slice):
            start, stop, step = key.indices(self.length)
            if step != 1:
                raise IndexError("strided vreg views are not supported")
            return self.view(start, stop - start)
        return self.view(int(key), 1)

    def __len__(self) -> int:
        return self.length

    def __repr__(self):
        return (f"VReg({self.name!r}, id={self.id}, len={self.length}, "
                f"eb={self.elem_bytes})")


class View:
    """A (vreg, offset, length) window — what op emitters consume."""

    __slots__ = ("reg", "offset", "length")

    def __init__(self, reg: VReg, offset: int, length: int):
        if offset < 0:
            raise ValueError(
                f"view of vreg {reg.name!r}: negative offset {offset}")
        if length <= 0:
            raise ValueError(
                f"view of vreg {reg.name!r}: length must be > 0, "
                f"got {length}")
        if offset + length > reg.length:
            raise IndexError(
                f"view [{offset}:{offset + length}) outside vreg "
                f"{reg.name!r} of length {reg.length}")
        self.reg = reg
        self.offset = offset
        self.length = length

    @property
    def ref(self) -> Ref:
        return Ref("vreg", self.reg.id, self.offset)

    @property
    def elem_bytes(self) -> int:
        return self.reg.elem_bytes

    def __len__(self) -> int:
        return self.length

    def __repr__(self):
        return (f"View({self.reg.name!r}[{self.offset}:"
                f"{self.offset + self.length}])")


Vec = Union[VReg, View]


def as_view(v: Vec) -> View:
    if isinstance(v, VReg):
        return View(v, 0, v.length)
    if isinstance(v, View):
        return v
    raise TypeError(f"expected VReg or View, got {type(v).__name__}")


@dataclass(frozen=True)
class MemRef:
    """A main-memory buffer handle. ``is_output`` marks buffers collected
    into :class:`BackendResult.outputs` after execution."""

    name: str
    id: int
    length: int
    elem_bytes: int = 4
    is_output: bool = False


@dataclass(frozen=True)
class KviProgram:
    """An immutable KVI program: the single source of truth every backend
    executes. ``mem_init[m.id]`` holds each buffer's initial contents."""

    name: str
    items: Tuple[Item, ...]
    vregs: Tuple[VReg, ...]
    mems: Tuple[MemRef, ...]
    mem_init: Dict[int, np.ndarray]
    alg_ops: int = 0                 # algorithmic mul+add count (energy denom)
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def n_instructions(self) -> int:
        return sum(i.count if isinstance(i, ScalarBlock) else 1
                   for i in self.items)

    @property
    def outputs(self) -> Tuple[MemRef, ...]:
        return tuple(m for m in self.mems if m.is_output)

    def vreg_by_id(self, rid: int) -> VReg:
        return self.vregs[rid]

    def mem_by_id(self, mid: int) -> MemRef:
        return self.mems[mid]

    def replace(self, **kw) -> "KviProgram":
        """A copy with the given fields swapped — how optimizing passes
        rewrite programs (``mem_init`` is shared, never mutated)."""
        return dataclasses.replace(self, **kw)

    def with_meta(self, **kw) -> "KviProgram":
        """A copy with extra ``meta`` entries (e.g. the fusion plan)."""
        return self.replace(meta={**self.meta, **kw})

    def __repr__(self):
        return (f"KviProgram({self.name!r}, {len(self.items)} items, "
                f"{len(self.vregs)} vregs, {len(self.mems)} mem bufs)")


_NP_DTYPE = {1: np.int8, 2: np.int16, 4: np.int32}


def np_dtype(elem_bytes: int):
    return _NP_DTYPE[elem_bytes]


class KviProgramBuilder:
    """Assembler for :class:`KviProgram`: declare vregs / memory buffers,
    emit instructions through named-op methods, then :meth:`build`.

    One program definition drives every backend::

        b = KviProgramBuilder("saxpy")
        hx = b.mem_in("x", x_np)
        r = b.vreg("v", len(x_np))
        b.kmemld(r, hx)
        b.ksvmulsc(r, r, scalar=3)
        b.krelu(r, r)
        hy = b.mem_out("y", len(x_np))
        b.kmemstr(hy, r)
        prog = b.build()
        get_backend("oracle").run(prog).outputs["y"]
    """

    def __init__(self, name: str):
        self.name = name
        self._vregs: List[VReg] = []
        self._mems: List[MemRef] = []
        self._mem_init: Dict[int, np.ndarray] = {}
        self._items: List[Item] = []

    # ---- declarations ---------------------------------------------------
    def vreg(self, name: str, length: int, elem_bytes: int = 4) -> VReg:
        if any(r.name == name for r in self._vregs):
            raise ValueError(
                f"duplicate vreg name {name!r} in program {self.name!r}")
        r = VReg(name, len(self._vregs), length, elem_bytes)
        self._vregs.append(r)
        return r

    def _mem(self, name: str, arr: np.ndarray, elem_bytes: int,
             is_output: bool) -> MemRef:
        if any(m.name == name for m in self._mems):
            raise ValueError(
                f"duplicate memory buffer name {name!r} in program "
                f"{self.name!r}")
        arr = np.ascontiguousarray(arr)
        m = MemRef(name, len(self._mems), int(arr.size), elem_bytes,
                   is_output)
        self._mems.append(m)
        self._mem_init[m.id] = arr
        return m

    def mem_in(self, name: str, arr: np.ndarray,
               elem_bytes: int = 4) -> MemRef:
        """Declare an input buffer with initial contents ``arr``."""
        return self._mem(name, arr, elem_bytes, is_output=False)

    def mem_out(self, name: str, length: int, elem_bytes: int = 4,
                shape: Optional[Tuple[int, ...]] = None) -> MemRef:
        """Declare an output buffer (zero-initialised, collected into
        ``BackendResult.outputs[name]``)."""
        arr = np.zeros(shape if shape is not None else length,
                       np_dtype(elem_bytes))
        return self._mem(name, arr, elem_bytes, is_output=True)

    # ---- emission -------------------------------------------------------
    def _emit(self, op: KviOp, dst: Optional[Ref], src1: Optional[Ref],
              src2: Optional[Ref], scalar: int, length: int,
              elem_bytes: int) -> KviInstr:
        i = KviInstr(op, dst, src1, src2, int(scalar), int(length),
                     elem_bytes)
        self._items.append(i)
        return i

    def scalar(self, n: int):
        """Account ``n`` scalar (non-coprocessor) instructions."""
        if n > 0:
            self._items.append(ScalarBlock(int(n)))

    def kmemld(self, dst: Vec, mem: MemRef,
               length: Optional[int] = None) -> KviInstr:
        d = as_view(dst)
        if mem.length > len(d):
            # the MFU's kmemld always transfers the whole buffer — a
            # buffer larger than the destination window would silently
            # overrun the adjacent SPM allocation
            raise ValueError(
                f"kmemld: buffer {mem.name!r} ({mem.length} elems) does "
                f"not fit destination window of {len(d)} elems")
        n = length if length is not None else min(len(d), mem.length)
        if n > mem.length or n > len(d):
            # the MFU transfers exactly the whole buffer — a declared
            # length beyond the buffer (or the window) would misstate
            # what the instruction writes to every analysis downstream
            raise ValueError(
                f"kmemld: length {n} exceeds buffer {mem.name!r} "
                f"({mem.length} elems) or destination window "
                f"({len(d)} elems)")
        return self._emit(KviOp.KMEMLD, d.ref, Ref("mem", mem.id), None,
                          0, n, d.elem_bytes)

    def kmemstr(self, mem: MemRef, src: Vec,
                length: Optional[int] = None) -> KviInstr:
        s = as_view(src)
        n = length if length is not None else min(len(s), mem.length)
        return self._emit(KviOp.KMEMSTR, Ref("mem", mem.id), s.ref, None,
                          0, n, s.elem_bytes)

    def _vv(self, op: KviOp, dst: Vec, a: Vec, b: Vec,
            scalar: int = 0) -> KviInstr:
        d, va, vb = as_view(dst), as_view(a), as_view(b)
        if len(va) != len(vb):
            raise ValueError(f"{op.value}: source length mismatch "
                             f"{len(va)} vs {len(vb)}")
        return self._emit(op, d.ref, va.ref, vb.ref, scalar, len(va),
                          va.elem_bytes)

    def _vs(self, op: KviOp, dst: Vec, a: Vec, scalar: int = 0) -> KviInstr:
        d, va = as_view(dst), as_view(a)
        return self._emit(op, d.ref, va.ref, None, scalar, len(va),
                          va.elem_bytes)

    # element-wise
    def kaddv(self, dst: Vec, a: Vec, b: Vec):
        return self._vv(KviOp.KADDV, dst, a, b)

    def ksubv(self, dst: Vec, a: Vec, b: Vec):
        return self._vv(KviOp.KSUBV, dst, a, b)

    def kvmul(self, dst: Vec, a: Vec, b: Vec):
        return self._vv(KviOp.KVMUL, dst, a, b)

    def kvslt(self, dst: Vec, a: Vec, b: Vec):
        return self._vv(KviOp.KVSLT, dst, a, b)

    def ksvaddsc(self, dst: Vec, a: Vec, scalar: int):
        return self._vs(KviOp.KSVADDSC, dst, a, scalar)

    def ksvmulsc(self, dst: Vec, a: Vec, scalar: int):
        return self._vs(KviOp.KSVMULSC, dst, a, scalar)

    def ksrlv(self, dst: Vec, a: Vec, scalar: int):
        return self._vs(KviOp.KSRLV, dst, a, scalar)

    def ksrav(self, dst: Vec, a: Vec, scalar: int):
        return self._vs(KviOp.KSRAV, dst, a, scalar)

    def krelu(self, dst: Vec, a: Vec):
        return self._vs(KviOp.KRELU, dst, a)

    def ksvslt(self, dst: Vec, a: Vec, scalar: int):
        return self._vs(KviOp.KSVSLT, dst, a, scalar)

    def kvcp(self, dst: Vec, a: Vec):
        return self._vs(KviOp.KVCP, dst, a)

    # reductions — dst is a single-element view (the register-file result
    # spilled to its architectural destination)
    def _red(self, op: KviOp, dst: Vec, a: Vec, b: Optional[Vec],
             scalar: int = 0) -> KviInstr:
        d, va = as_view(dst), as_view(a)
        if len(d) != 1:
            raise ValueError(f"{op.value}: reduction dst must be a "
                             f"single-element view, got length {len(d)}")
        vb = as_view(b) if b is not None else None
        if vb is not None and len(vb) != len(va):
            raise ValueError(f"{op.value}: source length mismatch")
        return self._emit(op, d.ref, va.ref,
                          vb.ref if vb is not None else None, scalar,
                          len(va), va.elem_bytes)

    def kvred(self, dst: Vec, a: Vec):
        return self._red(KviOp.KVRED, dst, a, None)

    def kdotp(self, dst: Vec, a: Vec, b: Vec):
        return self._red(KviOp.KDOTP, dst, a, b)

    def kdotpps(self, dst: Vec, a: Vec, b: Vec, shift: int):
        return self._red(KviOp.KDOTPPS, dst, a, b, shift)

    def ksvaddrf(self, dst: Vec, a: Vec, scalar: int):
        return self._red(KviOp.KSVADDRF, dst, a, None, scalar)

    def ksvmulrf(self, dst: Vec, a: Vec, scalar: int):
        return self._red(KviOp.KSVMULRF, dst, a, None, scalar)

    # ---- finish ---------------------------------------------------------
    def build(self, alg_ops: int = 0, **meta) -> KviProgram:
        return KviProgram(self.name, tuple(self._items), tuple(self._vregs),
                          tuple(self._mems),
                          {k: v.copy() for k, v in self._mem_init.items()},
                          alg_ops, dict(meta))
