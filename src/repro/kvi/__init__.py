"""repro.kvi — the unified KVI program IR + pluggable execution backends.

Author a vector program once with :class:`KviProgramBuilder`, then run it
on any registered backend:

========== ==================================================== =========
name       implementation                                       timing
========== ==================================================== =========
oracle     pure numpy (repro.core.mfu)                          no
cyclesim   event-driven simulator, 3 coprocessor schemes        SimResult
pallas     fused pl.pallas_call kernels (TPU / interpret)       no
========== ==================================================== =========

Batched & composite execution: bundle programs into a
:class:`KviWorkload` — N data instances of one kernel (homogeneous) or
different kernels pinned to different harts (composite, the paper's
conv/FFT/matmul protocol) — and execute it with
``backend.run_workload(workload)``. :class:`~repro.kvi.scheduler.
HartScheduler` packs a queue of programs onto free harts continuously.

Compiler pipeline: every ``run_workload()`` first sends each program
through the optimizing pass pipeline (``repro.kvi.passes``: copy_prop ->
dce -> fuse_regions), and lowering binds vregs to scratchpad addresses
with liveness-based register reuse. ``get_backend(name, passes=())``
runs the raw program; an impossible fit raises :class:`SpmOverflowError`.

See ``repro.kvi.programs`` for the paper's conv2d / FFT-256 / matmul
kernels on this API, and README.md for the full protocol description.
"""
from repro.kvi.backend import (Backend, BackendBase, BackendResult,
                               available_backends, get_backend,
                               register_backend)
from repro.kvi.ir import (ELEMWISE_OPS, MEM_OPS, REDUCTION_OPS, KviInstr,
                          KviOp, KviProgram, KviProgramBuilder, MemRef,
                          Ref, ScalarBlock, VReg, View)
from repro.kvi.lowering import LoweredTrace, SpmOverflowError, lower
from repro.kvi.passes import (DEFAULT_PASSES, FusedRegion, FusionPlan,
                              PassPipeline, default_pipeline,
                              optimize_program, plan_fusion_regions)
from repro.kvi.workload import (HartAssignment, KviWorkload, WorkloadEntry,
                                WorkloadResult, structural_signature)

__all__ = [
    "Backend", "BackendBase", "BackendResult", "available_backends",
    "get_backend", "register_backend", "KviInstr", "KviOp", "KviProgram",
    "KviProgramBuilder", "MemRef", "Ref", "ScalarBlock", "VReg", "View",
    "ELEMWISE_OPS", "MEM_OPS", "REDUCTION_OPS", "LoweredTrace", "lower",
    "SpmOverflowError", "PassPipeline", "DEFAULT_PASSES",
    "default_pipeline", "optimize_program", "plan_fusion_regions",
    "FusedRegion", "FusionPlan", "HartAssignment", "KviWorkload",
    "WorkloadEntry", "WorkloadResult", "structural_signature",
]
