"""repro.kvi — the unified KVI program IR + pluggable execution backends.

Author a vector program once with :class:`KviProgramBuilder`, then run it
on any registered backend:

========== ==================================================== =========
name       implementation                                       timing
========== ==================================================== =========
oracle     pure numpy (repro.core.mfu)                          no
cyclesim   event-driven simulator, 3 coprocessor schemes        SimResult
pallas     fused pl.pallas_call kernels (TPU / interpret)       no
========== ==================================================== =========

See ``repro.kvi.programs`` for the paper's conv2d / FFT-256 / matmul
kernels on this API, and README.md for the full protocol description.
"""
from repro.kvi.backend import (Backend, BackendResult, available_backends,
                               get_backend, register_backend)
from repro.kvi.ir import (ELEMWISE_OPS, MEM_OPS, REDUCTION_OPS, KviInstr,
                          KviOp, KviProgram, KviProgramBuilder, MemRef,
                          Ref, ScalarBlock, VReg, View)
from repro.kvi.lowering import LoweredTrace, lower

__all__ = [
    "Backend", "BackendResult", "available_backends", "get_backend",
    "register_backend", "KviInstr", "KviOp", "KviProgram",
    "KviProgramBuilder", "MemRef", "Ref", "ScalarBlock", "VReg", "View",
    "ELEMWISE_OPS", "MEM_OPS", "REDUCTION_OPS", "LoweredTrace", "lower",
]
