"""Composite workloads: batches of KVI programs with hart assignments.

The paper's central claim is the synergy between interleaved multi-
threading and data-level parallelism: three harts each drive vector work,
including *composite* workloads where conv / FFT / matmul run on different
harts concurrently. A :class:`KviWorkload` makes that batch a first-class
object every backend executes through ``Backend.run_workload()``:

  * one entry       — equivalent to the legacy single-program ``run()``,
  * homogeneous     — N data instances of one program structure (the
                      paper's homogeneous protocol; the Pallas backend
                      compiles the whole batch into ONE ``pallas_call``
                      per fused segment via a batch grid dimension),
  * composite       — different programs pinned to different harts (the
                      paper's conv32 / fft256 / matmul64 on harts 0/1/2).

A :class:`HartAssignment` pins an entry to a hart; unpinned entries are
placed round-robin over the machine's harts at execution time (see
:meth:`KviWorkload.assign_harts`). Entries pinned to the same hart execute
back-to-back in entry order — exactly the repeated-kernel streams of the
paper's composite measurement protocol.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.kvi.backend import BackendResult
from repro.kvi.ir import KviProgram


@dataclass(frozen=True)
class HartAssignment:
    """Placement of one workload entry.

    hart — pinned hart index, or None to let the executor place the entry
           (round-robin over the scheme's harts, in entry order).
    """

    hart: Optional[int] = None

    def __post_init__(self):
        if self.hart is not None and self.hart < 0:
            raise ValueError(f"hart must be >= 0, got {self.hart}")


@dataclass(frozen=True)
class WorkloadEntry:
    """One (program, hart-assignment) pair; the program's ``mem_init``
    buffers are this entry's data instance."""

    program: KviProgram
    assignment: HartAssignment = HartAssignment()

    @property
    def hart(self) -> Optional[int]:
        return self.assignment.hart


def structural_signature(program: KviProgram) -> tuple:
    """Hashable key identifying a program's *structure* — instruction
    stream, register shapes, buffer shapes — ignoring the data in
    ``mem_init``. Two programs with equal signatures are data instances of
    the same computation, which is what lets the Pallas backend batch them
    into one compiled kernel."""
    return (
        program.items,
        tuple((r.length, r.elem_bytes) for r in program.vregs),
        tuple((m.name, m.length, m.elem_bytes, m.is_output)
              for m in program.mems),
    )


@dataclass(frozen=True)
class KviWorkload:
    """An immutable batch of (program, hart-assignment, data-instance)
    entries — the unit of execution for ``Backend.run_workload()``."""

    name: str
    entries: Tuple[WorkloadEntry, ...]
    meta: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self):
        if not self.entries:
            raise ValueError("workload needs at least one entry")

    # ---- constructors ---------------------------------------------------
    @classmethod
    def single(cls, program: KviProgram) -> "KviWorkload":
        """One program, unpinned — the legacy ``run()`` protocol."""
        return cls(program.name, (WorkloadEntry(program),))

    @classmethod
    def replicate(cls, program: KviProgram, n: int) -> "KviWorkload":
        """The same program (same data) on ``n`` harts — the paper's
        homogeneous measurement protocol for one kernel."""
        return cls(f"{program.name}x{n}",
                   tuple(WorkloadEntry(program, HartAssignment(h))
                         for h in range(n)))

    @classmethod
    def homogeneous(cls, programs: Sequence[KviProgram],
                    name: Optional[str] = None,
                    pin_harts: bool = False) -> "KviWorkload":
        """N data instances of one program structure. All programs must
        share a structural signature; with ``pin_harts`` instance i is
        pinned to hart i."""
        programs = list(programs)
        if not programs:
            raise ValueError("workload needs at least one entry")
        sig = structural_signature(programs[0])
        for p in programs[1:]:
            if structural_signature(p) != sig:
                raise ValueError(
                    f"homogeneous workload requires structurally identical "
                    f"programs; {p.name!r} differs from {programs[0].name!r}")
        entries = tuple(
            WorkloadEntry(p, HartAssignment(i if pin_harts else None))
            for i, p in enumerate(programs))
        return cls(name or f"{programs[0].name}x{len(programs)}", entries)

    @classmethod
    def composite(cls, by_hart: Mapping[int, Sequence[KviProgram]],
                  name: str = "composite") -> "KviWorkload":
        """Different program streams pinned to different harts. Entry order
        within a hart is execution order (back-to-back repetitions)."""
        entries = []
        for hart in sorted(by_hart):
            for p in by_hart[hart]:
                entries.append(WorkloadEntry(p, HartAssignment(hart)))
        return cls(name, tuple(entries))

    # ---- structure ------------------------------------------------------
    def map_programs(self, fn) -> "KviWorkload":
        """A workload with each entry's program replaced by
        ``fn(program)``; assignments and meta are preserved. ``fn`` runs
        once per distinct program OBJECT, so entries sharing a program
        keep sharing the mapped one (identity-keyed caches downstream —
        dedup, lowering — stay effective). Returns ``self`` when ``fn``
        is an identity on every entry (the no-op-pass fast path)."""
        cache: Dict[int, KviProgram] = {}
        entries = []
        changed = False
        for e in self.entries:
            mapped = cache.get(id(e.program))
            if mapped is None:
                mapped = fn(e.program)
                cache[id(e.program)] = mapped
            if mapped is e.program:
                entries.append(e)
            else:
                changed = True
                entries.append(WorkloadEntry(mapped, e.assignment))
        if not changed:
            return self
        return KviWorkload(self.name, tuple(entries), dict(self.meta))

    @property
    def programs(self) -> Tuple[KviProgram, ...]:
        return tuple(e.program for e in self.entries)

    @property
    def is_homogeneous(self) -> bool:
        """True when every entry is a data instance of the same program
        structure (batchable into one compiled kernel)."""
        sigs = {structural_signature(e.program) for e in self.entries}
        return len(sigs) == 1

    def assign_harts(self, n_harts: int) -> List[List[int]]:
        """Resolve assignments for a machine with ``n_harts`` harts:
        returns per-hart lists of entry indices in execution order. Pinned
        entries keep their hart (error if out of range); unpinned entries
        are placed round-robin in entry order."""
        per_hart: List[List[int]] = [[] for _ in range(n_harts)]
        rr = 0
        for i, e in enumerate(self.entries):
            h = e.hart
            if h is None:
                h = rr % n_harts
                rr += 1
            elif h >= n_harts:
                raise ValueError(
                    f"entry {i} ({e.program.name!r}) pinned to hart {h} "
                    f"but the machine has {n_harts} harts")
            per_hart[h].append(i)
        return per_hart

    def __repr__(self):
        return (f"KviWorkload({self.name!r}, {len(self.entries)} entries, "
                f"{'homogeneous' if self.is_homogeneous else 'composite'})")


def dedup_entry_outputs(entries: Sequence[WorkloadEntry], run_program
                        ) -> List[Dict[str, object]]:
    """Per-entry outputs with each distinct program OBJECT executed once:
    ``run_program(program) -> outputs dict`` runs on first sight; sibling
    entries reusing the same object get array copies, so mutating one
    entry's buffers cannot corrupt the others. Shared by the oracle and
    cyclesim backends — their bit-identical guarantee rides on this one
    implementation."""
    cache: Dict[int, Dict[str, object]] = {}
    seen = set()
    outs = []
    for e in entries:
        k = id(e.program)
        if k not in cache:
            cache[k] = run_program(e.program)
        out = cache[k]
        if k in seen:
            out = {name: v.copy() for name, v in out.items()}
        seen.add(k)
        outs.append(out)
    return outs


@dataclass
class WorkloadResult:
    """What one backend run of a workload produced.

    entry_results — one :class:`BackendResult` per workload entry, in
                    entry order (``outputs`` filled; per-entry ``timing``
                    left None — timing is a workload-level property).
    timing        — scheme name -> SimResult for the WHOLE workload
                    (cyclesim only): all harts, all entries, with
                    contention between them.
    """

    backend: str
    workload: KviWorkload
    entry_results: Tuple[BackendResult, ...]
    timing: Optional[Dict[str, object]] = None
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def cycles(self) -> Optional[Dict[str, int]]:
        if self.timing is None:
            return None
        return {k: v.cycles for k, v in self.timing.items()}

    @property
    def hart_utilization(self) -> Optional[Dict[str, List[Dict[str, float]]]]:
        """Per-scheme, per-hart busy/stall/idle cycle breakdown (the
        :class:`~repro.core.simulator.HartStats` accounting, previously
        discarded here). ``None`` for timing-less backends. Each entry
        satisfies busy + stall + idle == total (the workload's cycles)."""
        if self.timing is None:
            return None
        return {scheme: [dict(h.breakdown(), utilization=h.utilization)
                         for h in sim.per_hart]
                for scheme, sim in self.timing.items()}

    @property
    def outputs(self) -> Tuple[Dict[str, object], ...]:
        return tuple(r.outputs for r in self.entry_results)

    @property
    def pallas_calls(self) -> Optional[int]:
        """Compiled-kernel launches this run issued (Pallas backend
        only — ``None`` elsewhere). The DSE walltime axis records this
        next to ``meta['wall_s']``."""
        n = self.meta.get("pallas_calls")
        return None if n is None else int(n)

    def entry_result(self, i: int = 0) -> BackendResult:
        """Entry ``i``'s result, with the workload-level timing attached
        (what the legacy single-program ``run()`` returns)."""
        r = self.entry_results[i]
        if self.timing is not None and r.timing is None:
            return BackendResult(r.backend, r.outputs, self.timing)
        return r
