"""Lowering: KviProgram (virtual registers) -> core Instr trace (SPM
addresses), shared by the oracle and cycle-sim backends.

Virtual registers become SPM allocations (bump allocator, SPM-line
aligned, exactly like a programmer laying out the scratchpads); memory
buffers become main-memory handles. Reduction instructions whose IR dst is
a vreg view get the legacy ``rf_store`` annotation — the register-file
result spilled to its architectural destination, modelled as one scalar
store by the cycle simulator (see ``repro.core.programs``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar
from repro.core.spm import SpmSpace
from repro.kvi.ir import (REDUCTION_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock)

Item = Union[Instr, Scalar]


@dataclass
class LoweredTrace:
    """One KviProgram bound to one machine configuration."""

    program: KviProgram
    config: KlessydraConfig
    items: List[Item]
    spm: SpmSpace
    mem: Dict[int, np.ndarray]       # legacy handle -> buffer
    vreg_addr: Dict[int, int]        # vreg id -> SPM byte address
    out_handles: Dict[str, int]      # output name -> legacy mem handle

    def execute(self) -> Dict[str, np.ndarray]:
        """Run the trace functionally on the SPM/main-memory model and
        collect the program's output buffers (bit-exact Mfu semantics)."""
        from repro.core.programs import _run_items
        _run_items(self.items, self.spm, self.mem)
        return self.collect_outputs()

    def collect_outputs(self) -> Dict[str, np.ndarray]:
        out = {}
        for m in self.program.outputs:
            shape = self.program.mem_init[m.id].shape
            out[m.name] = self.mem[m.id].reshape(shape).copy()
        return out


def lower(program: KviProgram, config: KlessydraConfig) -> LoweredTrace:
    """Bind a program's vregs/buffers to one machine config and emit the
    dynamic Instr/Scalar trace the simulator and Mfu consume."""
    spm = SpmSpace(config)
    vreg_addr = {r.id: spm.alloc(r.name, r.length, r.elem_bytes)
                 for r in program.vregs}
    # legacy memory handles are the MemRef ids (declaration order)
    mem = {m.id: program.mem_init[m.id].copy() for m in program.mems}
    out_handles = {m.name: m.id for m in program.outputs}

    def vaddr(ref):
        r = program.vreg_by_id(ref.id)
        return vreg_addr[ref.id] + r.elem_bytes * ref.offset

    items: List[Item] = []
    for it in program.items:
        if isinstance(it, ScalarBlock):
            items.append(Scalar(it.count))
            continue
        assert isinstance(it, KviInstr)
        op = it.op
        if op is KviOp.KMEMLD:
            items.append(Instr("kmemld", dst=vaddr(it.dst), src1=it.src1.id,
                               length=it.length, elem_bytes=it.elem_bytes))
        elif op is KviOp.KMEMSTR:
            items.append(Instr("kmemstr", dst=it.dst.id,
                               src1=vaddr(it.src1), length=it.length,
                               elem_bytes=it.elem_bytes))
        elif op in REDUCTION_OPS:
            i = Instr(op.value,
                      src1=vaddr(it.src1),
                      src2=vaddr(it.src2) if it.src2 is not None else None,
                      scalar=it.scalar, length=it.length,
                      elem_bytes=it.elem_bytes)
            # register-file result spilled to the dst view's SPM location
            dreg = program.vreg_by_id(it.dst.id)
            i.rf_store = (vreg_addr[it.dst.id], it.dst.offset,
                          dreg.elem_bytes)
            items.append(i)
        else:
            items.append(Instr(op.value, dst=vaddr(it.dst),
                               src1=vaddr(it.src1),
                               src2=vaddr(it.src2) if it.src2 is not None
                               else None,
                               scalar=it.scalar, length=it.length,
                               elem_bytes=it.elem_bytes))
    return LoweredTrace(program, config, items, spm, mem, vreg_addr,
                        out_handles)
