"""Lowering: KviProgram (virtual registers) -> core Instr trace (SPM
addresses), shared by the oracle and cycle-sim backends.

Virtual registers become SPM allocations via **liveness-based linear
scan**: each vreg's live range (first touch .. last touch) is computed
with :mod:`repro.kvi.passes.liveness` and registers whose ranges do not
overlap share scratchpad lines. Programs whose *peak-live* footprint
fits the SPM therefore lower even when the *total* vreg footprint does
not — the reuse a programmer would hand-craft. A genuine overflow raises
:class:`SpmOverflowError` naming the program, its peak-live bytes and
the capacity.

Memory buffers become main-memory handles. Reduction instructions whose
IR dst is a vreg view get the legacy ``rf_store`` annotation — the
register-file result spilled to its architectural destination, modelled
as one scalar store by the cycle simulator (see ``repro.core.programs``).

With ``chaining=True`` the lowered element-wise instructions inside a
planned :class:`~repro.kvi.passes.fusion.FusedRegion` (after the first)
carry a ``chain_discount`` — the FU-chaining setup savings the cycle
simulator subtracts (the paper's back-to-back SPM-resident op streams).

Timing-only callers pass ``functional=False``: the lowered trace then
*aliases* the program's ``mem_init`` buffers instead of copying them
(simulation never touches memory contents) and refuses to ``execute()``.
:class:`TraceCache` builds on that to share one lowered trace per
``(program, config fingerprint, chaining)`` across run protocols — the
design-space sweep's preflight, homogeneous and composite runs all hit
the same allocation instead of re-running the SPM linear scan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar
from repro.core.spm import SpmError, SpmSpace
from repro.kvi.ir import (REDUCTION_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock)
from repro.kvi.passes.fusion import META_KEY, FusionPlan
from repro.kvi.passes.liveness import peak_live_bytes, reg_intervals

Item = Union[Instr, Scalar]


class SpmOverflowError(SpmError):
    """The SPM allocator cannot place a program's vregs. Usually the
    peak-live footprint genuinely exceeds capacity (no register-reuse
    schedule can fit it); rarely the linear scan fragments a fit that
    exists in principle — the message distinguishes the two."""

    def __init__(self, program: KviProgram, peak_live: int, capacity: int,
                 config: KlessydraConfig):
        self.program_name = program.name
        self.peak_live_bytes = peak_live
        self.capacity_bytes = capacity
        self.fragmented = peak_live <= capacity
        spm = f"{capacity} B (N={config.N} x {config.spm_kbytes} KiB)"
        if self.fragmented:
            msg = (f"SPM overflow lowering {program.name!r}: peak-live "
                   f"vreg footprint {peak_live} B fits the SPM capacity "
                   f"{spm}, but linear-scan placement fragmented it — "
                   f"reorder register lifetimes or raise spm_kbytes")
        else:
            msg = (f"SPM overflow lowering {program.name!r}: peak-live "
                   f"vreg footprint {peak_live} B exceeds SPM capacity "
                   f"{spm}; no live-range reuse can fit this program — "
                   f"shrink vectors or raise spm_kbytes")
        super().__init__(msg)


def _align_up(x: int, a: int) -> int:
    return -(-x // a) * a


def allocate_vregs(program: KviProgram,
                   config: KlessydraConfig) -> Dict[int, int]:
    """Linear-scan SPM allocation: vreg id -> byte address.

    Registers are placed in live-range start order at the lowest
    SPM-line-aligned address not occupied by any register whose live
    range overlaps. Registers not fully defined before first read are
    pinned live-from-start so they never inherit recycled lines (their
    unwritten elements read as zeros on every backend). Untouched vregs
    get no address (nothing references them). Raises
    :class:`SpmOverflowError` on overflow.
    """
    line = max(config.D * 4, 4)
    capacity = config.spm_capacity_bytes
    intervals = reg_intervals(program, pin_uninitialized=True)
    placed: List[Tuple[int, int, int, int]] = []   # (addr, size, start, end)
    addr_of: Dict[int, int] = {}
    order = sorted(intervals, key=lambda rid: (intervals[rid][0], rid))
    for rid in order:
        r = program.vreg_by_id(rid)
        size = _align_up(r.length * r.elem_bytes, line)
        s, e = intervals[rid]
        busy = sorted((a, sz) for a, sz, s2, e2 in placed
                      if not (e < s2 or e2 < s))
        cur = 0
        for a, sz in busy:
            if a - cur >= size:
                break
            cur = max(cur, a + sz)
        if cur + size > capacity:
            raise SpmOverflowError(
                program,
                peak_live_bytes(program, line, pin_uninitialized=True),
                capacity, config)
        placed.append((cur, size, s, e))
        addr_of[rid] = cur
    return addr_of


def _chained_items(program: KviProgram) -> frozenset:
    """Item indices eligible for the FU-chaining discount: every region
    member after its region's first op (the head pays full setup)."""
    plan = program.meta.get(META_KEY)
    if not isinstance(plan, FusionPlan):
        return frozenset()
    return frozenset(i for r in plan.regions for i in r.items[1:])


@dataclass
class LoweredTrace:
    """One KviProgram bound to one machine configuration.

    ``functional=False`` marks a timing-only trace: its ``mem`` dict
    aliases the program's ``mem_init`` buffers (no copies were made), so
    executing it would corrupt the immutable program — ``execute()``
    refuses."""

    program: KviProgram
    config: KlessydraConfig
    items: List[Item]
    spm: SpmSpace
    mem: Dict[int, np.ndarray]       # legacy handle -> buffer
    vreg_addr: Dict[int, int]        # vreg id -> SPM byte address
    out_handles: Dict[str, int]      # output name -> legacy mem handle
    functional: bool = True

    def execute(self) -> Dict[str, np.ndarray]:
        """Run the trace functionally on the SPM/main-memory model and
        collect the program's output buffers (bit-exact Mfu semantics)."""
        if not self.functional:
            raise RuntimeError(
                f"trace of {self.program.name!r} was lowered with "
                f"functional=False (mem buffers alias the program's "
                f"mem_init); re-lower functionally to execute")
        from repro.core.programs import _run_items
        _run_items(self.items, self.spm, self.mem)
        return self.collect_outputs()

    def collect_outputs(self) -> Dict[str, np.ndarray]:
        out = {}
        for m in self.program.outputs:
            shape = self.program.mem_init[m.id].shape
            out[m.name] = self.mem[m.id].reshape(shape).copy()
        return out


def lower(program: KviProgram, config: KlessydraConfig,
          chaining: bool = False, functional: bool = True,
          vreg_addr: Optional[Dict[int, int]] = None) -> LoweredTrace:
    """Bind a program's vregs/buffers to one machine config and emit the
    dynamic Instr/Scalar trace the simulator and Mfu consume.

    ``functional=False`` skips the ``mem_init`` buffer copies (the trace
    aliases the program's buffers and cannot be executed — timing-only).
    ``vreg_addr`` injects a precomputed SPM allocation so repeated lowers
    of one (program, config) pair skip the linear-scan allocator — the
    :class:`TraceCache` fast path."""
    spm = SpmSpace(config)
    if vreg_addr is None:
        vreg_addr = allocate_vregs(program, config)
    # legacy memory handles are the MemRef ids (declaration order)
    if functional:
        mem = {m.id: program.mem_init[m.id].copy() for m in program.mems}
    else:
        mem = {m.id: program.mem_init[m.id] for m in program.mems}
    out_handles = {m.name: m.id for m in program.outputs}
    chained = _chained_items(program) if chaining else frozenset()

    def vaddr(ref):
        r = program.vreg_by_id(ref.id)
        return vreg_addr[ref.id] + r.elem_bytes * ref.offset

    items: List[Item] = []
    for idx, it in enumerate(program.items):
        if isinstance(it, ScalarBlock):
            items.append(Scalar(it.count))
            continue
        assert isinstance(it, KviInstr)
        op = it.op
        if op is KviOp.KMEMLD:
            items.append(Instr("kmemld", dst=vaddr(it.dst), src1=it.src1.id,
                               length=it.length, elem_bytes=it.elem_bytes))
        elif op is KviOp.KMEMSTR:
            items.append(Instr("kmemstr", dst=it.dst.id,
                               src1=vaddr(it.src1), length=it.length,
                               elem_bytes=it.elem_bytes))
        elif op in REDUCTION_OPS:
            i = Instr(op.value,
                      src1=vaddr(it.src1),
                      src2=vaddr(it.src2) if it.src2 is not None else None,
                      scalar=it.scalar, length=it.length,
                      elem_bytes=it.elem_bytes)
            # register-file result spilled to the dst view's SPM location
            dreg = program.vreg_by_id(it.dst.id)
            i.rf_store = (vreg_addr[it.dst.id], it.dst.offset,
                          dreg.elem_bytes)
            items.append(i)
        else:
            i = Instr(op.value, dst=vaddr(it.dst),
                      src1=vaddr(it.src1),
                      src2=vaddr(it.src2) if it.src2 is not None
                      else None,
                      scalar=it.scalar, length=it.length,
                      elem_bytes=it.elem_bytes)
            if idx in chained:
                # chained op: operands stream straight off the previous
                # op's result lines — skip the FU startup latency
                i.chain_discount = config.vector_setup_cycles
            items.append(i)
    return LoweredTrace(program, config, items, spm, mem, vreg_addr,
                        out_handles, functional=functional)


# ---------------------------------------------------------------------------
# Trace caching across run protocols
# ---------------------------------------------------------------------------


def config_fingerprint(config: KlessydraConfig) -> tuple:
    """A stable hashable identity for one machine configuration —
    every field, so any parameter that could change lowering or timing
    distinguishes cache entries. In-memory only (tuples of live
    values); the persistent sweep cache
    (:mod:`repro.kvi.dse.pointcache`) covers the same ground
    content-addressably via the point's canonical dict + program
    fingerprints."""
    return dataclasses.astuple(config)


@dataclass
class TraceCache:
    """Caches :func:`lower` results keyed on
    ``(program identity, config fingerprint, chaining)``.

    One sweep point runs each kernel through up to three protocols —
    SPM preflight, the homogeneous run, the composite run — and without
    caching each of them re-runs the linear-scan SPM allocator and
    re-copies ``mem_init``. Through the cache the allocator runs exactly
    once per (program, config): timing-only traces (``functional=False``)
    are shared outright (simulation never mutates them), and functional
    lowers reuse the cached SPM allocation while still getting fresh
    buffer copies (execution mutates memory).

    ``hits`` / ``misses`` count cache lookups; ``misses`` equals the
    number of allocator runs, which is what the sweep's per-point
    accounting asserts on.

    Keys use program *identity* (programs are pinned alive so ids can't
    recycle), so the cache only pays off when callers hand the backend
    stable program objects — i.e. ``passes=()`` with pre-optimized
    programs, the DSE configuration. A backend whose pass pipeline is
    active rewrites programs into fresh objects per run, making every
    lookup a miss; scope a TraceCache to one program set (the sweep
    builds one per point), don't share it across unrelated runs.
    """

    hits: int = 0
    misses: int = 0
    # key -> timing-only trace; each trace's .program field keeps the
    # keyed program alive, so id() keys can never be recycled onto a
    # different program object
    _traces: Dict[tuple, LoweredTrace] = field(default_factory=dict)

    def _key(self, program: KviProgram, config: KlessydraConfig,
             chaining: bool) -> tuple:
        return (id(program), config_fingerprint(config), bool(chaining))

    def lower(self, program: KviProgram, config: KlessydraConfig,
              chaining: bool = False,
              functional: bool = True) -> LoweredTrace:
        """Drop-in for :func:`lower` with caching."""
        key = self._key(program, config, chaining)
        trace = self._traces.get(key)
        if trace is None:
            self.misses += 1
            trace = lower(program, config, chaining=chaining,
                          functional=False)
            self._traces[key] = trace
        else:
            self.hits += 1
        if not functional:
            return trace
        return lower(program, config, chaining=chaining, functional=True,
                     vreg_addr=trace.vreg_addr)

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}
