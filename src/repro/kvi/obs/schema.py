"""Trace integrity validation: every exported trace must satisfy the
schema below before it is worth opening in Perfetto.

Checked invariants (the test suite gates every producer on these):

  * top-level shape — ``traceEvents`` list + ``displayTimeUnit``;
  * per event — a known Chrome phase, integer pid/tid, a numeric
    non-negative ``ts``, a name, and the phase-specific requirements
    (``X`` needs a non-negative ``dur``, flow phases need an ``id``,
    counters need numeric ``args``);
  * per track — timestamps non-decreasing in serialized order (the
    exporter sorts; a violation means a producer wrote through the
    exporter's back);
  * span nesting — any explicit ``B``/``E`` pairs balance per track;
  * flows — every flow id has exactly one start and one end, with
    ``ts(start) <= ts(step) <= ts(end)``;
  * clocks — every non-metadata event is tagged with a known clock
    domain, and cycle-domain timestamps are integers (virtual cycles).

``TRACE_SCHEMA`` documents the same contract as a JSON-Schema object
(for humans and external tooling); :func:`validate_trace` is the
dependency-free implementation CI and the tests call.
"""
from __future__ import annotations

from typing import Dict, List

from repro.kvi.obs.trace import CLOCK_CYCLES, CLOCK_WALL

#: phases the exporter can produce (+ explicit B/E for completeness)
_PHASES = frozenset({"X", "B", "E", "i", "C", "s", "t", "f", "M"})

#: the contract, as a JSON-Schema document (informational; the enforced
#: implementation is :func:`validate_trace`)
TRACE_SCHEMA: Dict[str, object] = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "title": "kvi-trace-v1",
    "type": "object",
    "required": ["traceEvents", "displayTimeUnit"],
    "properties": {
        "displayTimeUnit": {"type": "string"},
        "traceEvents": {
            "type": "array",
            "items": {
                "type": "object",
                "required": ["ph", "pid", "tid", "ts", "name"],
                "properties": {
                    "ph": {"enum": sorted(_PHASES)},
                    "pid": {"type": "integer", "minimum": 0},
                    "tid": {"type": "integer", "minimum": 0},
                    "ts": {"type": "number", "minimum": 0},
                    "dur": {"type": "number", "minimum": 0},
                    "name": {"type": "string"},
                    "cat": {"type": "string"},
                    "id": {"type": "integer"},
                    "clock": {"enum": [CLOCK_CYCLES, CLOCK_WALL]},
                    "args": {"type": "object"},
                },
            },
        },
    },
}


def validate_trace(trace: object) -> List[str]:
    """Every violation of the kvi-trace-v1 contract, as messages; an
    empty list means the trace is valid."""
    errs: List[str] = []
    if not isinstance(trace, dict):
        return ["trace is not a dict"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    if not isinstance(trace.get("displayTimeUnit"), str):
        errs.append("displayTimeUnit missing")

    last_ts: Dict[tuple, float] = {}
    open_spans: Dict[tuple, List[str]] = {}
    flows: Dict[object, Dict[str, List[float]]] = {}
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not a dict")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errs.append(f"{where}: unknown phase {ph!r}")
            continue
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errs.append(f"{where}: {k} not an integer")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or isinstance(ts, bool) \
                or ts < 0:
            errs.append(f"{where}: ts not a non-negative number: {ts!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: name missing")
        if ph == "M":
            continue
        clock = ev.get("clock")
        if clock not in (CLOCK_CYCLES, CLOCK_WALL):
            errs.append(f"{where}: unknown clock {clock!r}")
        elif clock == CLOCK_CYCLES and ts != int(ts):
            errs.append(f"{where}: cycle-domain ts {ts!r} not integral")

        track = (ev.get("pid"), ev.get("tid"), clock)
        if ts < last_ts.get(track, 0):
            errs.append(f"{where}: ts {ts} decreases on track "
                        f"pid={track[0]} tid={track[1]} "
                        f"(last {last_ts[track]})")
        last_ts[track] = ts

        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool) \
                    or dur < 0:
                errs.append(f"{where}: X event needs dur >= 0, "
                            f"got {dur!r}")
        elif ph == "B":
            open_spans.setdefault(track, []).append(ev.get("name", ""))
        elif ph == "E":
            stack = open_spans.get(track)
            if not stack:
                errs.append(f"{where}: E without matching B on track "
                            f"pid={track[0]} tid={track[1]}")
            else:
                stack.pop()
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float)) or isinstance(v, bool)
                    for v in args.values()):
                errs.append(f"{where}: counter args must be a non-empty "
                            f"dict of numbers")
        elif ph in ("s", "t", "f"):
            if "id" not in ev:
                errs.append(f"{where}: flow event without id")
            else:
                rec = flows.setdefault(ev["id"], {"s": [], "t": [],
                                                  "f": []})
                rec[ph].append(ts)

    for track, stack in open_spans.items():
        if stack:
            errs.append(f"unclosed span(s) {stack} on track "
                        f"pid={track[0]} tid={track[1]}")
    for fid, rec in flows.items():
        if len(rec["s"]) != 1 or len(rec["f"]) != 1:
            errs.append(f"flow {fid}: needs exactly one start and one "
                        f"end, got {len(rec['s'])}/{len(rec['f'])}")
            continue
        s, f = rec["s"][0], rec["f"][0]
        if s > f:
            errs.append(f"flow {fid}: start ts {s} after end ts {f}")
        if any(t < s or t > f for t in rec["t"]):
            errs.append(f"flow {fid}: step outside [start, end]")
    return errs
