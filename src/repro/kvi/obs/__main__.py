"""CLI: summarize or validate saved KVI telemetry.

    python -m repro.kvi.obs view kvi_trace.json [--metrics kvi_metrics.json]
    python -m repro.kvi.obs validate kvi_trace.json [--metrics ...]

``view`` prints a text timeline per cycle-domain track (busy ``█`` /
stall ``▒`` / idle ``·``), the serving request-flow summary (requests,
makespan, latency percentiles — recomputed from the flow events alone,
cross-checked against the engine's report in tests) and the top-k stall
attribution by span name. ``validate`` checks the trace against the
kvi-trace-v1 schema (and the metrics snapshot when given) and exits
non-zero on any violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.kvi.obs.metrics import validate_metrics
from repro.kvi.obs.schema import validate_trace
from repro.kvi.obs.trace import CLOCK_CYCLES, load_trace

#: timeline bar width in characters
_WIDTH = 60


def _percentiles(xs) -> Dict[str, int]:
    """Nearest-rank percentiles, the serving engine's exact convention
    (so the trace-derived numbers reproduce the report's)."""
    if not xs:
        return {"p50": 0, "p95": 0, "p99": 0, "mean": 0, "max": 0}
    arr = np.sort(np.asarray(xs, dtype=np.int64))

    def rank(q: float) -> int:
        return int(arr[min(len(arr) - 1,
                           max(0, int(np.ceil(q * len(arr))) - 1))])

    return {"p50": rank(0.50), "p95": rank(0.95), "p99": rank(0.99),
            "mean": int(np.floor(arr.mean())), "max": int(arr[-1])}


def _track_names(events) -> Dict[Tuple[int, int], str]:
    """(pid, tid) -> "process/lane" from the metadata events."""
    procs: Dict[int, str] = {}
    lanes: Dict[Tuple[int, int], str] = {}
    for ev in events:
        if ev.get("ph") != "M":
            continue
        if ev.get("name") == "process_name":
            procs[ev["pid"]] = ev.get("args", {}).get("name", "?")
        elif ev.get("name") == "thread_name":
            lanes[(ev["pid"], ev["tid"])] = \
                ev.get("args", {}).get("name", "?")
    return {(pid, tid): f"{procs.get(pid, pid)}/{lane}"
            for (pid, tid), lane in lanes.items()}


def flow_summary(events) -> Optional[Dict[str, object]]:
    """Makespan + latency percentiles reconstructed from the request
    flow events alone: latency(id) = ts(flow end) - ts(flow start)."""
    starts: Dict[object, float] = {}
    ends: Dict[object, float] = {}
    for ev in events:
        if ev.get("ph") == "s":
            starts[ev.get("id")] = ev["ts"]
        elif ev.get("ph") == "f":
            ends[ev.get("id")] = ev["ts"]
    done = sorted(set(starts) & set(ends), key=str)
    if not done:
        return None
    latencies = [int(ends[i] - starts[i]) for i in done]
    return {"requests": len(done),
            "makespan_cycles": int(max(ends[i] for i in done)),
            "latency_cycles": _percentiles(latencies)}


def _bar(busy: List[Tuple[float, float]], stall: List[Tuple[float, float]],
         t_end: float, width: int = _WIDTH) -> str:
    """busy/stall/idle occupancy of [0, t_end) as one character bar;
    busy wins a column over stall, stall over idle."""
    cols = []
    scale = t_end / width if t_end else 1

    def covered(iv, lo, hi):
        return any(s < hi and e > lo for s, e in iv)

    for c in range(width):
        lo, hi = c * scale, (c + 1) * scale
        if covered(busy, lo, hi):
            cols.append("█")
        elif covered(stall, lo, hi):
            cols.append("▒")
        else:
            cols.append("·")
    return "".join(cols)


def stall_attribution(events, top: int = 5) -> List[Tuple[str, int, int]]:
    """(span name, total stalled cycles, occurrences) for cycle-domain
    stall spans, largest first — "what were the harts waiting on"."""
    agg: Dict[str, List[int]] = {}
    for ev in events:
        if ev.get("ph") == "X" and ev.get("cat") == "stall" \
                and ev.get("clock") == CLOCK_CYCLES:
            a = agg.setdefault(ev["name"], [0, 0])
            a[0] += int(ev.get("dur", 0))
            a[1] += 1
    rows = sorted(((n, d, c) for n, (d, c) in agg.items()),
                  key=lambda r: (-r[1], r[0]))
    return rows[:top]


def view(trace_path: str, metrics_path: Optional[str] = None,
         top: int = 5, out=print) -> Dict[str, object]:
    """Print the trace summary; returns the computed summary dict (the
    tests cross-check it against the engine's report)."""
    trace = load_trace(trace_path)
    events = trace.get("traceEvents", [])
    names = _track_names(events)
    out(f"# {trace_path}: {len(events)} events, "
        f"{len(names)} tracks")

    # per-track cycle-domain occupancy bars
    per_track: Dict[tuple, Dict[str, list]] = {}
    t_end = 0.0
    for ev in events:
        if ev.get("ph") != "X" or ev.get("clock") != CLOCK_CYCLES:
            continue
        key = (ev["pid"], ev["tid"])
        d = per_track.setdefault(key, {"busy": [], "stall": []})
        iv = (ev["ts"], ev["ts"] + ev.get("dur", 0))
        kind = "stall" if ev.get("cat") == "stall" else \
            None if ev.get("cat") == "idle" else "busy"
        if kind:
            d[kind].append(iv)
        t_end = max(t_end, iv[1])
    if per_track:
        out(f"\n## timeline (0..{int(t_end)} cycles; "
            f"█ busy ▒ stall · idle)")
        for key in sorted(per_track):
            d = per_track[key]
            label = names.get(key, f"pid{key[0]}/tid{key[1]}")
            out(f"  {label:36s} {_bar(d['busy'], d['stall'], t_end)}")

    summary: Dict[str, object] = {}
    flows = flow_summary(events)
    if flows:
        summary.update(flows)
        lat = flows["latency_cycles"]
        out(f"\n## request flows")
        out(f"  requests={flows['requests']} "
            f"makespan={flows['makespan_cycles']} cycles")
        out(f"  latency p50={lat['p50']} p95={lat['p95']} "
            f"p99={lat['p99']} mean={lat['mean']} max={lat['max']}")

    stalls = stall_attribution(events, top=top)
    if stalls:
        out(f"\n## top-{len(stalls)} stall attribution")
        for name, dur, cnt in stalls:
            out(f"  {name:24s} {dur:10d} cycles over {cnt} waits")
    summary["stalls"] = [{"name": n, "cycles": d, "count": c}
                         for n, d, c in stalls]

    if metrics_path:
        with open(metrics_path) as f:
            snap = json.load(f)
        out(f"\n## metrics ({metrics_path})")
        for k, v in snap.get("counters", {}).items():
            out(f"  counter {k} = {v}")
        for k, v in snap.get("gauges", {}).items():
            out(f"  gauge   {k} = {v}")
        for k, h in snap.get("histograms", {}).items():
            out(f"  hist    {k}: n={h['count']} p50={h['p50']} "
                f"p99={h['p99']} max={h['max']}")
    return summary


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.kvi.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("view", help="summarize a saved trace")
    v.add_argument("trace")
    v.add_argument("--metrics", default=None,
                   help="also summarize a metrics snapshot JSON")
    v.add_argument("--top", type=int, default=5,
                   help="stall-attribution rows to print")
    c = sub.add_parser("validate",
                       help="schema-validate a trace (+ metrics)")
    c.add_argument("trace")
    c.add_argument("--metrics", default=None)
    args = ap.parse_args(argv)

    if args.cmd == "view":
        view(args.trace, metrics_path=args.metrics, top=args.top)
        return 0
    errs = validate_trace(load_trace(args.trace))
    if args.metrics:
        with open(args.metrics) as f:
            errs += validate_metrics(json.load(f))
    for e in errs:
        print(f"INVALID: {e}", file=sys.stderr)
    label = args.trace + (f" + {args.metrics}" if args.metrics else "")
    print(f"{label}: " + ("OK" if not errs else f"{len(errs)} errors"))
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
