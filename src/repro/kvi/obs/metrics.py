"""Cross-layer metrics registry: counters, gauges and exact-bucket
histograms behind one ``snapshot() -> dict`` with a stable schema.

Every execution layer already counted *something* — the Pallas backend's
``KernelCache`` hits/misses, the lowering ``TraceCache``, the DSE's
``PointCache``, the scheduler's queue, the serving engine's latency
percentiles — each with its own ad-hoc dict shape. A
:class:`MetricsRegistry` absorbs them behind three primitive types:

  * :class:`Counter`   — monotonically increasing event count,
  * :class:`Gauge`     — last-written value,
  * :class:`Histogram` — exact-bucket distribution (every distinct
    observed value keeps its own bucket — latencies here are integer
    virtual cycles, so exact buckets are both small and lossless, and
    nearest-rank percentiles computed from them are *identical* to the
    percentiles computed from the raw samples).

Metric names are dotted paths (``"serving.latency_cycles"``), created on
first use. ``snapshot()`` returns a plain sorted dict — deterministic
whenever the recorded values are — and ``save()`` writes it as JSON.
Wall-clock observations belong under names carrying a ``_s``/``_us``
suffix listed in :data:`~repro.kvi.obs.scrub.TRACE_VOLATILE`-style key
sets, so canonical comparisons can scrub them with the shared helper.

The disabled path allocates nothing: :data:`NULL_METRICS` hands every
caller the same no-op instruments, so instrumented code never needs a
``None`` check around ``metrics.counter("x").inc()``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (int or float)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Exact-bucket distribution: every observed value is its own
    bucket, so the summary percentiles are exact nearest-rank — the same
    convention the serving engine's ``_percentiles`` uses on raw
    samples."""

    __slots__ = ("buckets", "count", "total")

    def __init__(self):
        self.buckets: Dict[float, int] = {}
        self.count = 0
        self.total = 0

    def observe(self, v, n: int = 1) -> None:
        v = v if isinstance(v, float) else int(v)
        self.buckets[v] = self.buckets.get(v, 0) + n
        self.count += n
        self.total += v * n

    def percentile(self, q: float):
        """Exact nearest-rank percentile over the buckets."""
        if not self.count:
            return 0
        rank = max(1, -(-int(q * self.count * 100) // 100))  # ceil
        seen = 0
        for v in sorted(self.buckets):
            seen += self.buckets[v]
            if seen >= rank:
                return v
        return max(self.buckets)

    def summary(self) -> Dict[str, object]:
        if not self.count:
            return {"count": 0, "sum": 0, "min": 0, "max": 0,
                    "mean": 0.0, "p50": 0, "p95": 0, "p99": 0,
                    "buckets": {}}
        return {
            "count": self.count,
            "sum": self.total,
            "min": min(self.buckets),
            "max": max(self.buckets),
            "mean": round(self.total / self.count, 6),
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
            "buckets": {str(k): self.buckets[k]
                        for k in sorted(self.buckets)},
        }


class MetricsRegistry:
    """Name -> instrument store with a stable ``snapshot()`` schema.

    Instruments are created on first use and shared thereafter; the
    snapshot is ``{"counters": {...}, "gauges": {...}, "histograms":
    {name: summary}}`` with names sorted — byte-deterministic whenever
    the recorded values are."""

    enabled = True

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- instruments ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram()
        return h

    def absorb(self, prefix: str, stats: Dict[str, int]) -> None:
        """Fold a legacy ``{"hits": n, "misses": m, ...}`` counter dict
        into ``<prefix>.<key>`` counters — the adapter the scattered
        cache-stat dicts (KernelCache / TraceCache / PointCache) ride in
        on."""
        for k in sorted(stats):
            v = stats[k]
            if isinstance(v, bool) or not isinstance(v, int):
                continue
            self.counter(f"{prefix}.{k}").inc(v)

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        return {
            "schema": "kvi-metrics-v1",
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": {k: self._histograms[k].summary()
                           for k in sorted(self._histograms)},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")


class _NullInstrument:
    """One shared do-nothing counter/gauge/histogram."""

    __slots__ = ()
    value = 0
    count = 0
    total = 0
    buckets: Dict[float, int] = {}

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v, n: int = 1) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics(MetricsRegistry):
    """Zero-allocation disabled registry: every lookup returns the one
    shared no-op instrument and ``snapshot()`` is empty."""

    enabled = False

    def __init__(self):
        super().__init__()

    def counter(self, name: str):
        return _NULL_INSTRUMENT

    def gauge(self, name: str):
        return _NULL_INSTRUMENT

    def histogram(self, name: str):
        return _NULL_INSTRUMENT

    def absorb(self, prefix: str, stats: Dict[str, int]) -> None:
        pass


NULL_METRICS = NullMetrics()


def validate_metrics(snapshot: object) -> List[str]:
    """Structural check of a metrics snapshot (the saved-artifact gate):
    returns a list of problems, empty when valid."""
    errs: List[str] = []
    if not isinstance(snapshot, dict):
        return ["snapshot is not a dict"]
    if snapshot.get("schema") != "kvi-metrics-v1":
        errs.append(f"bad schema tag {snapshot.get('schema')!r}")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(snapshot.get(section), dict):
            errs.append(f"missing section {section!r}")
    for name, v in (snapshot.get("counters") or {}).items():
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            errs.append(f"counter {name!r} not a non-negative int: {v!r}")
    for name, h in (snapshot.get("histograms") or {}).items():
        if not isinstance(h, dict):
            errs.append(f"histogram {name!r} not a dict")
            continue
        missing = [k for k in ("count", "sum", "min", "max",
                               "p50", "p95", "p99", "buckets")
                   if k not in h]
        if missing:
            errs.append(f"histogram {name!r} missing {missing}")
            continue
        n = sum(h["buckets"].values()) if isinstance(h["buckets"], dict) \
            else -1
        if h["count"] != n:
            errs.append(f"histogram {name!r}: count {h['count']} != "
                        f"bucket total {n}")
        if h["count"] and not (h["min"] <= h["p50"] <= h["p95"]
                               <= h["p99"] <= h["max"]):
            errs.append(f"histogram {name!r}: percentile ordering broken")
    return errs
