"""Unified telemetry for the KVI stack: tracing, metrics, scrubbing.

One :class:`Obs` bundle rides through every execution layer —
``CycleSimBackend``, ``PallasBackend``, ``HartScheduler``,
``ServeEngine`` and the DSE ``sweep()`` all take an optional ``obs=``
parameter (default off, zero overhead). When enabled it collects:

  * a :class:`~repro.kvi.obs.trace.Tracer` — span/instant/counter/flow
    events on dual clocks (virtual cycles + wall seconds), exported as
    Chrome trace-event JSON for Perfetto / ``chrome://tracing``;
  * a :class:`~repro.kvi.obs.metrics.MetricsRegistry` — counters,
    gauges and exact-bucket histograms behind one ``snapshot()``.

``python -m repro.kvi.obs view TRACE`` summarizes a saved trace (text
timeline + top-k stall attribution); ``... validate TRACE`` checks it
against the kvi-trace-v1 schema. The volatile-key scrubber every
canonical-report producer shares lives in :mod:`repro.kvi.obs.scrub`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.kvi.obs.metrics import (NULL_METRICS, Counter, Gauge,  # noqa: F401
                                   Histogram, MetricsRegistry,
                                   NullMetrics, validate_metrics)
from repro.kvi.obs.schema import TRACE_SCHEMA, validate_trace  # noqa: F401
from repro.kvi.obs.scrub import (ALL_VOLATILE, DSE_VOLATILE,  # noqa: F401
                                 SERVE_VOLATILE, TRACE_VOLATILE, scrub)
from repro.kvi.obs.trace import (CLOCK_CYCLES, CLOCK_WALL,  # noqa: F401
                                 NULL_TRACER, NullTracer, Tracer,
                                 canonical_trace, load_trace)


@dataclass
class Obs:
    """The observability bundle instrumented layers thread through.

    Construct with :meth:`on` for a live collector, or pass ``None``
    (the default everywhere) for a true no-op — instrumented code
    guards on ``obs is not None and obs.enabled`` so the disabled path
    costs nothing."""

    tracer: Tracer = field(default_factory=lambda: NULL_TRACER)
    metrics: MetricsRegistry = field(default_factory=lambda: NULL_METRICS)

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled or self.metrics.enabled

    @classmethod
    def on(cls) -> "Obs":
        """A live bundle: fresh tracer + fresh metrics registry."""
        return cls(tracer=Tracer(), metrics=MetricsRegistry())

    def save(self, trace_path=None, metrics_path=None) -> None:
        """Write whatever was collected (either path may be None)."""
        if trace_path:
            self.tracer.save(trace_path)
        if metrics_path:
            self.metrics.save(metrics_path)


#: the canonical disabled bundle (shared; allocates nothing per use)
NULL_OBS = Obs()
