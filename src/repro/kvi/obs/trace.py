"""Cycle-accurate tracer with a Chrome-trace-event JSON exporter.

Every execution layer emits *events* onto named tracks; the exporter
writes the Chrome trace-event JSON that Perfetto and ``chrome://tracing``
load directly, with process/thread metadata so the UI shows readable
lanes ("cyclesim:het_mimd" / "hart0", "serving" / "hart2", ...).

Two clock domains coexist, as separate tracks:

  * **cycles** — virtual simulated cycles, the deterministic domain.
    One cycle maps to one trace microsecond (``ts`` is the cycle
    number), so per-hart busy/stall/idle intervals, instruction spans
    and request flows land at exact simulated times, byte-reproducible
    under a fixed seed.
  * **wall**   — real seconds since tracer construction, for the layers
    with no virtual clock (Pallas compile/execute, DSE point walltime).
    Wall tracks are volatile by nature; :func:`canonical_trace` drops
    them (and scrubs wall argument fields) so determinism gates can
    byte-compare what remains.

Event kinds map to Chrome phases: :meth:`Tracer.span` -> complete
(``X``), :meth:`Tracer.instant` -> ``i``, :meth:`Tracer.counter` ->
``C``, and :meth:`Tracer.flow_start` / ``flow_step`` / ``flow_end`` ->
``s``/``t``/``f`` — the arrows linking one request's arrival ->
admission -> completion across tracks.

The disabled path is zero-allocation: :data:`NULL_TRACER` implements the
same surface as no-ops with ``enabled = False``, and instrumented hot
loops (the cycle simulator's inner loop) additionally gate their
recording on ``obs is not None`` so a run without observability executes
the exact pre-instrumentation instruction path.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

from repro.kvi.obs.scrub import TRACE_VOLATILE, scrub

#: clock-domain tags events carry (a non-Chrome field; viewers ignore it)
CLOCK_CYCLES = "cycles"
CLOCK_WALL = "wall"

Track = Tuple[str, str]            # (process name, thread/lane name)


class Tracer:
    """Span/instant/counter/flow event collector over named tracks.

    A *track* is a ``(process, lane)`` name pair — e.g.
    ``("cyclesim:het_mimd", "hart0")`` — mapped lazily to stable integer
    pid/tid in first-use order (deterministic for a deterministic event
    stream). ``clock`` selects the event's domain: ``"cycles"``
    (default; ``ts`` is a virtual cycle) or ``"wall"`` (``ts`` in real
    microseconds since tracer construction, or supplied explicitly).
    """

    enabled = True

    def __init__(self):
        self.events: List[dict] = []
        self._pids: Dict[str, int] = {}
        self._tids: Dict[Track, int] = {}
        self._wall0 = time.perf_counter()

    # -- track bookkeeping ---------------------------------------------
    def _ids(self, track: Track) -> Tuple[int, int]:
        pid = self._pids.get(track[0])
        if pid is None:
            pid = self._pids[track[0]] = len(self._pids) + 1
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = \
                sum(t[0] == track[0] for t in self._tids) + 1
        return pid, tid

    def wall_us(self) -> float:
        """Microseconds since tracer construction (the wall domain)."""
        return (time.perf_counter() - self._wall0) * 1e6

    # -- emitters ------------------------------------------------------
    def _emit(self, ph: str, track: Track, name: str, ts, cat: str,
              clock: str, args: Optional[dict], **extra) -> None:
        pid, tid = self._ids(track)
        ev = {"ph": ph, "pid": pid, "tid": tid, "name": name,
              "cat": cat, "ts": ts, "clock": clock}
        if args:
            ev["args"] = args
        ev.update(extra)
        self.events.append(ev)

    def span(self, track: Track, name: str, ts, dur, cat: str = "span",
             clock: str = CLOCK_CYCLES,
             args: Optional[dict] = None) -> None:
        """A complete event: ``[ts, ts + dur)`` on ``track``."""
        self._emit("X", track, name, ts, cat, clock, args, dur=dur)

    def instant(self, track: Track, name: str, ts, cat: str = "mark",
                clock: str = CLOCK_CYCLES,
                args: Optional[dict] = None) -> None:
        self._emit("i", track, name, ts, cat, clock, args, s="t")

    def counter(self, track: Track, name: str, ts, values: Dict[str, float],
                clock: str = CLOCK_CYCLES) -> None:
        """A counter sample: ``values`` are the series of one chart."""
        self._emit("C", track, name, ts, "counter", clock, dict(values))

    def flow_start(self, track: Track, name: str, ts, flow_id: int,
                   cat: str = "flow", clock: str = CLOCK_CYCLES,
                   args: Optional[dict] = None) -> None:
        self._emit("s", track, name, ts, cat, clock, args, id=flow_id)

    def flow_step(self, track: Track, name: str, ts, flow_id: int,
                  cat: str = "flow", clock: str = CLOCK_CYCLES,
                  args: Optional[dict] = None) -> None:
        self._emit("t", track, name, ts, cat, clock, args, id=flow_id)

    def flow_end(self, track: Track, name: str, ts, flow_id: int,
                 cat: str = "flow", clock: str = CLOCK_CYCLES,
                 args: Optional[dict] = None) -> None:
        self._emit("f", track, name, ts, cat, clock, args,
                   id=flow_id, bp="e")

    def wall_span(self, track: Track, name: str, start_us: float,
                  cat: str = "wall", args: Optional[dict] = None) -> None:
        """A wall-domain span from ``start_us`` (a prior
        :meth:`wall_us` reading) to now."""
        self.span(track, name, round(start_us, 3),
                  round(self.wall_us() - start_us, 3), cat=cat,
                  clock=CLOCK_WALL, args=args)

    # -- export --------------------------------------------------------
    def to_chrome(self) -> Dict[str, object]:
        """The Chrome trace-event JSON object: metadata naming every
        track, then all events sorted by (pid, tid, ts, emission
        order) — the deterministic serialization the schema validator
        and the byte-identity tests consume."""
        events: List[dict] = []
        for pname, pid in sorted(self._pids.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "pid": pid, "tid": 0,
                           "name": "process_name", "cat": "__metadata",
                           "ts": 0, "args": {"name": pname}})
        for (pname, lname), tid in sorted(self._tids.items(),
                                          key=lambda kv: kv[1]):
            events.append({"ph": "M", "pid": self._pids[pname],
                           "tid": tid, "name": "thread_name",
                           "cat": "__metadata", "ts": 0,
                           "args": {"name": lname}})
        order = {id(ev): i for i, ev in enumerate(self.events)}
        events.extend(sorted(
            self.events,
            key=lambda ev: (ev["pid"], ev["tid"], ev["ts"],
                            order[id(ev)])))
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1, sort_keys=True)
            f.write("\n")


class NullTracer(Tracer):
    """Zero-allocation disabled tracer: every emitter returns
    immediately, ``events`` stays empty."""

    enabled = False

    def _emit(self, ph, track, name, ts, cat, clock, args, **extra):
        pass

    def wall_us(self) -> float:
        return 0.0


NULL_TRACER = NullTracer()


def canonical_trace(trace: Dict[str, object]) -> Dict[str, object]:
    """The deterministic view of an exported trace: wall-domain events
    dropped (their timestamps are real time), volatile argument fields
    scrubbed everywhere else. Two runs with the same seed and
    configuration produce byte-identical canonical traces — what the
    determinism tests compare."""
    events = [scrub(ev, TRACE_VOLATILE)
              for ev in trace.get("traceEvents", [])
              if ev.get("clock") != CLOCK_WALL]
    out = {k: v for k, v in trace.items() if k != "traceEvents"}
    out["traceEvents"] = events
    return out


def load_trace(path: str) -> Dict[str, object]:
    """Read a saved Chrome trace JSON (the viewer/validator entry)."""
    with open(path) as f:
        return json.load(f)
