"""Minimal stdlib-only SVG chart writer.

The DSE reports want two figures next to ``dse_report.md`` — speedup-
vs-D curves and the (cycles, area) Pareto front — without pulling
matplotlib into the dependency set. This module draws exactly what
those need: framed axes with ticks, polyline series, scatter markers
and a legend, as a deterministic SVG string (fixed float formatting, no
timestamps) so the artifacts are byte-stable run to run.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

#: series colors (Okabe-Ito, readable on white and colorblind-safe)
PALETTE = ("#0072B2", "#D55E00", "#009E73", "#CC79A7",
           "#E69F00", "#56B4E9", "#000000", "#F0E442")

#: marker shapes cycled alongside the palette
MARKERS = ("circle", "square", "diamond", "triangle")

_W, _H = 640, 400
_ML, _MR, _MT, _MB = 64, 160, 36, 48       # margins (legend lives right)


def _fmt(x: float) -> str:
    return f"{x:.2f}".rstrip("0").rstrip(".")


def _ticks(lo: float, hi: float, n: int = 5) -> List[float]:
    """<= ``n`` round tick positions covering [lo, hi]."""
    if hi <= lo:
        return [lo]
    raw = (hi - lo) / n
    mag = 10 ** math.floor(math.log10(raw))
    step = min(s * mag for s in (1, 2, 5, 10) if s * mag >= raw)
    first = math.ceil(lo / step) * step
    out = []
    t = first
    while t <= hi + 1e-9:
        out.append(round(t, 10))
        t += step
    return out or [lo]


class Chart:
    """One framed x/y chart; add line/scatter series, then render."""

    def __init__(self, title: str, xlabel: str, ylabel: str,
                 log_x: bool = False):
        self.title = title
        self.xlabel = xlabel
        self.ylabel = ylabel
        self.log_x = log_x
        self.series: List[Tuple[str, List[Tuple[float, float]], str]] = []

    def add(self, label: str, points: Sequence[Tuple[float, float]],
            style: str = "line") -> None:
        """``style`` is ``"line"`` (polyline + markers) or
        ``"scatter"`` (markers only)."""
        pts = [(float(x), float(y)) for x, y in points]
        if pts:
            self.series.append((label, sorted(pts), style))

    # -- rendering -----------------------------------------------------
    def _tx(self, x: float) -> float:
        return math.log10(x) if self.log_x else x

    def render(self) -> str:
        if not self.series:
            return (f'<svg xmlns="http://www.w3.org/2000/svg" '
                    f'width="{_W}" height="{_H}">'
                    f'<text x="20" y="30">{self.title}: no data</text>'
                    f'</svg>')
        xs = [self._tx(x) for _, pts, _ in self.series for x, _ in pts]
        ys = [y for _, pts, _ in self.series for _, y in pts]
        x0, x1 = min(xs), max(xs)
        y0, y1 = min(ys), max(ys)
        if x1 == x0:
            x0, x1 = x0 - 0.5, x1 + 0.5
        if y1 == y0:
            y0, y1 = y0 - 0.5, y1 + 0.5
        pad_y = 0.06 * (y1 - y0)
        y0, y1 = y0 - pad_y, y1 + pad_y
        pw = _W - _ML - _MR
        ph = _H - _MT - _MB

        def px(x: float) -> float:
            return _ML + pw * (self._tx(x) - x0) / (x1 - x0)

        def py(y: float) -> float:
            return _MT + ph * (1 - (y - y0) / (y1 - y0))

        e: List[str] = [
            f'<svg xmlns="http://www.w3.org/2000/svg" width="{_W}" '
            f'height="{_H}" viewBox="0 0 {_W} {_H}" '
            f'font-family="sans-serif" font-size="12">',
            f'<rect width="{_W}" height="{_H}" fill="white"/>',
            f'<text x="{_ML}" y="20" font-size="14" '
            f'font-weight="bold">{self.title}</text>',
            f'<rect x="{_ML}" y="{_MT}" width="{pw}" height="{ph}" '
            f'fill="none" stroke="#999"/>',
        ]
        # ticks + grid
        if self.log_x:
            lo_d, hi_d = math.floor(x0), math.ceil(x1)
            xticks = [10 ** d for d in range(lo_d, hi_d + 1)
                      if x0 - 1e-9 <= d <= x1 + 1e-9]
            xticks = xticks or [10 ** x0]
        else:
            xticks = _ticks(x0, x1)
        for t in xticks:
            x = px(t) if not self.log_x else \
                _ML + pw * (math.log10(t) - x0) / (x1 - x0)
            e.append(f'<line x1="{x:.1f}" y1="{_MT}" x2="{x:.1f}" '
                     f'y2="{_MT + ph}" stroke="#eee"/>')
            e.append(f'<text x="{x:.1f}" y="{_MT + ph + 16}" '
                     f'text-anchor="middle">{_fmt(t)}</text>')
        for t in _ticks(y0, y1):
            y = py(t)
            e.append(f'<line x1="{_ML}" y1="{y:.1f}" x2="{_ML + pw}" '
                     f'y2="{y:.1f}" stroke="#eee"/>')
            e.append(f'<text x="{_ML - 6}" y="{y + 4:.1f}" '
                     f'text-anchor="end">{_fmt(t)}</text>')
        e.append(f'<text x="{_ML + pw / 2:.1f}" y="{_H - 10}" '
                 f'text-anchor="middle">{self.xlabel}</text>')
        e.append(f'<text x="16" y="{_MT + ph / 2:.1f}" '
                 f'text-anchor="middle" transform="rotate(-90 16 '
                 f'{_MT + ph / 2:.1f})">{self.ylabel}</text>')

        # series + legend
        for i, (label, pts, style) in enumerate(self.series):
            color = PALETTE[i % len(PALETTE)]
            marker = MARKERS[i % len(MARKERS)]
            if style == "line" and len(pts) > 1:
                path = " ".join(f"{px(x):.1f},{py(y):.1f}"
                                for x, y in pts)
                e.append(f'<polyline points="{path}" fill="none" '
                         f'stroke="{color}" stroke-width="1.8"/>')
            for x, y in pts:
                e.append(_marker(marker, px(x), py(y), color))
            ly = _MT + 14 + 16 * i
            e.append(_marker(marker, _W - _MR + 14, ly - 4, color))
            e.append(f'<text x="{_W - _MR + 26}" y="{ly}">'
                     f'{label}</text>')
        e.append("</svg>")
        return "\n".join(e)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.render() + "\n")


def _marker(shape: str, x: float, y: float, color: str,
            r: float = 3.5) -> str:
    if shape == "circle":
        return (f'<circle cx="{x:.1f}" cy="{y:.1f}" r="{r}" '
                f'fill="{color}"/>')
    if shape == "square":
        return (f'<rect x="{x - r:.1f}" y="{y - r:.1f}" '
                f'width="{2 * r:.1f}" height="{2 * r:.1f}" '
                f'fill="{color}"/>')
    if shape == "diamond":
        pts = f"{x:.1f},{y - r - 1:.1f} {x + r + 1:.1f},{y:.1f} " \
              f"{x:.1f},{y + r + 1:.1f} {x - r - 1:.1f},{y:.1f}"
        return f'<polygon points="{pts}" fill="{color}"/>'
    pts = f"{x:.1f},{y - r - 1:.1f} {x + r + 1:.1f},{y + r:.1f} " \
          f"{x - r - 1:.1f},{y + r:.1f}"
    return f'<polygon points="{pts}" fill="{color}"/>'


def line_chart(title: str, xlabel: str, ylabel: str,
               series: Dict[str, Sequence[Tuple[float, float]]],
               log_x: bool = False) -> str:
    """Convenience: one polyline per ``series`` entry."""
    c = Chart(title, xlabel, ylabel, log_x=log_x)
    for label in series:
        c.add(label, series[label], style="line")
    return c.render()


def scatter_chart(title: str, xlabel: str, ylabel: str,
                  series: Dict[str, Sequence[Tuple[float, float]]],
                  front: Optional[Sequence[Tuple[float, float]]] = None,
                  ) -> str:
    """Scatter per series; ``front`` (if given) is additionally drawn
    as a connecting staircase line — the Pareto-front overlay."""
    c = Chart(title, xlabel, ylabel)
    for label in series:
        c.add(label, series[label], style="scatter")
    svg = c.render()
    if front:
        pts = sorted((float(x), float(y)) for x, y in front)
        # re-render with the front as an extra line series drawn first
        c2 = Chart(title, xlabel, ylabel)
        c2.add("pareto front", pts, style="line")
        for label in series:
            c2.add(label, series[label], style="scatter")
        svg = c2.render()
    return svg
