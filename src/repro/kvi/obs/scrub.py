"""The one volatile-key scrubber every canonical-output producer shares.

Three subsystems emit reports that must be *byte-deterministic* across
runs, executors and cache temperatures — the DSE sweep
(``SweepResult.canonical_json``), the serving engine
(``canonical_report``) and the telemetry layer itself (saved traces and
metrics snapshots). Each of them measures wall-clock quantities that are
nondeterministic by nature, so each needs the same operation: "this
object, with every wall-clock / run-shape field removed, recursively".

Before this module, that operation existed three times (the sweep's
``VOLATILE_KEYS``, the serving engine's ``SERVE_VOLATILE``, and ad-hoc
wall-field handling in trace consumers) with the risk of the sets
drifting apart. Now there is one :func:`scrub` and one place the key
sets live; ``repro.kvi.dse.sweep`` and ``repro.kvi.serving.engine``
re-export their historical names from here, and a regression test pins
byte-identical canonical output across all producers.
"""
from __future__ import annotations

#: wall-clock / run-shape fields of the DSE sweep: timing measurements,
#: the executor label (names *how* the sweep ran, not what it measured)
#: and point-cache metadata (differs cold vs. warm by definition).
DSE_VOLATILE = frozenset({"wall_s", "walltime_s", "pallas_walltime_s",
                          "pallas_compile_s", "pallas_steady_s",
                          "total_wall_s", "executor",
                          "cached", "point_cache", "fresh_evals"})

#: the serving engine's wall-clock / rate fields, on top of the DSE set
#: (its report embeds backend meta that carries the DSE names).
SERVE_VOLATILE = DSE_VOLATILE | frozenset(
    {"req_per_s", "execute_s", "prewarm_s", "engine_s"})

#: wall-clock fields telemetry events and metrics snapshots carry next
#: to their deterministic virtual-cycle payload.
TRACE_VOLATILE = frozenset({"wall_s", "wall_us", "dur_wall_us",
                            "points_per_s", "eta_s"})

#: the union — safe as a default because the sets are disjoint from
#: every deterministic key any producer emits (pinned by tests).
ALL_VOLATILE = DSE_VOLATILE | SERVE_VOLATILE | TRACE_VOLATILE


def scrub(obj, keys: frozenset = ALL_VOLATILE):
    """``obj`` with every ``keys`` entry removed, recursively — the
    canonical (timing- and executor-free) view of a report, trace or
    metrics snapshot. Dicts and lists/tuples are rebuilt; scalars pass
    through."""
    if isinstance(obj, dict):
        return {k: scrub(v, keys) for k, v in obj.items()
                if k not in keys}
    if isinstance(obj, (list, tuple)):
        return [scrub(v, keys) for v in obj]
    return obj
