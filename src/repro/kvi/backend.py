"""The pluggable execution-backend protocol for KVI programs.

The unit of execution is a :class:`~repro.kvi.workload.KviWorkload` — a
batch of (program, hart-assignment, data-instance) entries executed by
``run_workload()``, which returns a
:class:`~repro.kvi.workload.WorkloadResult` (per-entry output buffers,
plus workload-level per-scheme timing for timing-aware backends).

The single-program ``run()`` remains as a thin wrapper: it wraps the
program into a one-entry workload (:class:`BackendBase`) and unwraps the
first entry's :class:`BackendResult`.

Backends self-register under a short name::

    @register_backend("oracle")
    class OracleBackend(BackendBase): ...

    get_backend("oracle").run(program)              # one program
    get_backend("oracle").run_workload(workload)    # a composite batch

``available_backends()`` lists what is importable in this environment (the
Pallas backend needs jax; the registry degrades gracefully without it).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, Optional, Protocol,
                    runtime_checkable)

import numpy as np

from repro.kvi.ir import KviProgram

if TYPE_CHECKING:                      # pragma: no cover - typing only
    from repro.kvi.workload import KviWorkload, WorkloadResult


@dataclass
class BackendResult:
    """What one backend run produced.

    outputs — every ``mem_out`` buffer of the program, by name, reshaped
              to its declared shape.
    timing  — scheme name -> SimResult (cycle backend only; the paper's
              shared / symmetric-MIMD / heterogeneous-MIMD schemes).
    backend — the producing backend's registered name.
    """

    backend: str
    outputs: Dict[str, np.ndarray]
    timing: Optional[Dict[str, "object"]] = None

    @property
    def cycles(self) -> Optional[Dict[str, int]]:
        if self.timing is None:
            return None
        return {k: v.cycles for k, v in self.timing.items()}


@runtime_checkable
class Backend(Protocol):
    """Anything that can execute KVI work. ``run_workload`` is the
    primary protocol method; ``run`` is the single-program convenience."""

    name: str

    def run(self, program: KviProgram) -> BackendResult:
        ...

    def run_workload(self, workload: "KviWorkload") -> "WorkloadResult":
        ...


class BackendBase:
    """Shared backend behavior: the legacy single-program ``run()`` is a
    thin wrapper over ``run_workload()`` on a one-entry workload, and
    ``optimize_workload()`` applies the optimizing pass pipeline
    (``repro.kvi.passes``) every ``run_workload()`` implementation calls
    first.

    ``self.passes`` selects the pipeline: ``None`` (the default) runs
    the full ``copy_prop -> dce -> fuse_regions`` pipeline, ``()``
    disables optimization entirely, and a sequence of pass names or
    callables runs a custom pipeline. Every built-in backend ctor
    forwards a ``passes=`` keyword here.

    Lowering backends may additionally accept a
    :class:`~repro.kvi.lowering.TraceCache` (``trace_cache=`` on the
    cyclesim ctor) so callers running one program set through several
    workloads — the DSE sweep's preflight + homogeneous + composite
    protocols — bind each (program, config) pair exactly once. The
    cache keys on program *identity*, so pair it with ``passes=()``
    and pre-optimized programs: an active pipeline rewrites programs
    into fresh objects on every ``run_workload()``, which would turn
    every lookup into a miss (and pin each rewritten program alive).

    ``verify`` gates the static analyzer (:mod:`repro.kvi.analysis`) in
    front of execution: the workload is verified (structural checks,
    fusion audit, cross-hart race check) and rejected with a
    :class:`~repro.kvi.analysis.KviVerificationError` on any
    error-severity diagnostic, and the pass pipeline re-verifies after
    every pass (:class:`~repro.kvi.passes.PassVerificationError` names
    the offending pass). Every built-in backend ctor takes ``verify=``,
    and ``run_workload(verify=...)`` overrides it per call.
    """

    passes = None                    # None => default pipeline; () => off
    verify = False                   # True => static-verify before running

    def run(self, program: KviProgram) -> BackendResult:
        from repro.kvi.workload import KviWorkload
        return self.run_workload(KviWorkload.single(program)).entry_result(0)

    def optimize_workload(self, workload: "KviWorkload",
                          verify: Optional[bool] = None) -> "KviWorkload":
        """The optimized workload this backend actually executes. Each
        distinct program object is optimized once; pipelines that change
        nothing hand back the identical workload object.

        ``verify=None`` defers to ``self.verify``; ``True`` statically
        verifies the workload first (raising
        :class:`~repro.kvi.analysis.KviVerificationError` on errors) and
        runs the pipeline in its self-checking mode."""
        check = self.verify if verify is None else verify
        if check:
            from repro.kvi.analysis import (DiagnosticReport,
                                            KviVerificationError,
                                            analyze_workload)
            rep = analyze_workload(workload)
            if not rep.ok:
                raise KviVerificationError(
                    DiagnosticReport(rep.errors),
                    context=f"backend {self.name!r} rejected workload "
                            f"{workload.name!r}")
        from repro.kvi.passes import PassPipeline
        pipe = PassPipeline.from_spec(getattr(self, "passes", None),
                                      verify=check)
        if not pipe:
            return workload
        return workload.map_programs(pipe.run)


_REGISTRY: Dict[str, Callable[..., Backend]] = {}


def register_backend(name: str):
    """Class decorator registering a backend factory under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str, **kwargs) -> Backend:
    """Instantiate a registered backend (kwargs forwarded to the ctor)."""
    _ensure_builtin_backends()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; available: "
                       f"{sorted(_REGISTRY)}") from None
    return factory(**kwargs)


def available_backends() -> Dict[str, Callable[..., Backend]]:
    _ensure_builtin_backends()
    return dict(_REGISTRY)


_BOOTED = False


def _ensure_builtin_backends():
    """Import the built-in backend modules so their ``@register_backend``
    decorators run. The Pallas backend is optional (requires jax)."""
    global _BOOTED
    if _BOOTED:
        return
    _BOOTED = True
    from repro.kvi import cyclesim, oracle  # noqa: F401  (side-effect import)
    with contextlib.suppress(ImportError):     # pragma: no cover - no jax
        from repro.kvi import pallas_backend  # noqa: F401
