"""Per-program liveness analysis over virtual vector registers.

Granularity is the whole vreg: a read of any window makes the register
live, and only a write covering the full register kills it. That is
conservative for partial writes (the untouched elements survive), which
is exactly what the downstream consumers need:

  * :func:`observable_items` — backward may-observe analysis feeding the
    ``dce`` pass (an instruction is dead when nothing it writes can reach
    an output buffer),
  * :func:`reg_intervals` — first-touch/last-touch live ranges feeding
    the linear-scan SPM allocator in ``repro.kvi.lowering`` (two vregs
    with disjoint ranges may share scratchpad lines),
  * :func:`peak_live_bytes` — the allocator's true capacity requirement,
    reported by :class:`~repro.kvi.lowering.SpmOverflowError`.

Memory buffers are tracked alongside: a ``kmemstr`` is observable when
its target buffer is a program output *or* is loaded again later; a
``kmemld`` keeps its source buffer live.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.kvi.ir import (REDUCTION_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock)


def _kmemld_width(program: KviProgram, instr: KviInstr) -> int:
    """Elements a ``kmemld`` writes: the MFU transfers exactly the WHOLE
    buffer into the destination window, independent of the instruction's
    declared ``length`` (see ``Mfu.execute`` / ``KviProgramBuilder.
    kmemld``, which rejects lengths overstating the buffer)."""
    return program.mem_by_id(instr.src1.id).length


def _is_full_def(program: KviProgram, instr: KviInstr) -> bool:
    """True when ``instr`` overwrites every element of its dst vreg."""
    reg = program.vreg_by_id(instr.dst.id)
    if instr.op is KviOp.KMEMLD:
        width = _kmemld_width(program, instr)
    elif instr.op in REDUCTION_OPS:
        width = 1                     # register-file result, one element
    else:
        width = instr.length
    return instr.dst.offset == 0 and width >= reg.length


def observable_items(program: KviProgram) -> List[bool]:
    """Per-item flag: can this item's effect reach an output buffer?

    Backward walk. ``ScalarBlock`` items are always observable (they
    model scalar work the timing backends must keep). ``kmemstr`` to a
    buffer that is neither an output nor re-loaded later is dead; a full
    re-store of a buffer kills earlier stores to it.
    """
    items = program.items
    live = [True] * len(items)
    live_regs: set = set()
    live_mems = {m.id for m in program.mems if m.is_output}
    for idx in range(len(items) - 1, -1, -1):
        it = items[idx]
        if isinstance(it, ScalarBlock):
            continue
        op = it.op
        if op is KviOp.KMEMSTR:
            mid = it.dst.id
            if mid not in live_mems:
                live[idx] = False
                continue
            if it.length >= program.mem_by_id(mid).length:
                live_mems.discard(mid)   # full overwrite kills older stores
            live_regs.add(it.src1.id)
            continue
        if op is KviOp.KMEMLD:
            if it.dst.id not in live_regs:
                live[idx] = False
                continue
            if _is_full_def(program, it):
                live_regs.discard(it.dst.id)
            live_mems.add(it.src1.id)
            continue
        # MFU op writing a vreg (element-wise or reduction-with-spill)
        if it.dst.id not in live_regs:
            live[idx] = False
            continue
        if _is_full_def(program, it):
            live_regs.discard(it.dst.id)
        live_regs.add(it.src1.id)
        if it.src2 is not None:
            live_regs.add(it.src2.id)
    return live


def reg_intervals(program: KviProgram,
                  pin_uninitialized: bool = False
                  ) -> Dict[int, Tuple[int, int]]:
    """vreg id -> (first touch, last touch) item indices, inclusive.
    Registers never referenced by any instruction are absent.

    With ``pin_uninitialized=True`` (what the SPM allocator uses), any
    register whose first touch is NOT a full-width definition — an
    uninitialized read, or a partial first write whose untouched elements
    may be read later — has its interval start pinned to item 0. Pinned
    registers can never inherit another register's recycled scratchpad
    lines, so their unwritten elements read as fresh zeros, exactly the
    pre-reuse semantics every backend agrees on."""
    iv: Dict[int, Tuple[int, int]] = {}
    pinned: set = set()

    def touch(rid: int, idx: int, full_def: bool):
        if rid not in iv:
            iv[rid] = (idx, idx)
            if not full_def:
                pinned.add(rid)
        else:
            s, e = iv[rid]
            iv[rid] = (min(s, idx), max(e, idx))

    for idx, it in enumerate(program.items):
        if not isinstance(it, KviInstr):
            continue
        # reads logically precede the write within one instruction
        for ref in (it.src1, it.src2):
            if ref is not None and ref.space == "vreg":
                touch(ref.id, idx, full_def=False)
        if it.dst is not None and it.dst.space == "vreg":
            touch(it.dst.id, idx, full_def=_is_full_def(program, it))
    if pin_uninitialized:
        for rid in pinned:
            iv[rid] = (0, iv[rid][1])
    return iv


def peak_live_bytes(program: KviProgram, align: int = 4,
                    pin_uninitialized: bool = False) -> int:
    """Maximum over all program points of the summed (alignment-padded)
    footprint of simultaneously live vregs — the smallest SPM that can
    hold the program under perfect register reuse."""
    iv = reg_intervals(program, pin_uninitialized)
    deltas: Dict[int, int] = {}
    for rid, (s, e) in iv.items():
        r = program.vreg_by_id(rid)
        size = -(-r.length * r.elem_bytes // align) * align
        deltas[s] = deltas.get(s, 0) + size
        deltas[e + 1] = deltas.get(e + 1, 0) - size
    peak = cur = 0
    for idx in sorted(deltas):
        cur += deltas[idx]
        peak = max(peak, cur)
    return peak


def total_vreg_bytes(program: KviProgram, align: int = 4) -> int:
    """Alignment-padded footprint of ALL declared vregs — what the old
    bump allocator needed."""
    return sum(-(-r.length * r.elem_bytes // align) * align
               for r in program.vregs)
