"""Backend-neutral fusion-region planning.

The Klessydra speedups hinge on *chaining*: a run of element-wise vector
ops whose intermediates never round-trip through main memory (hardware:
SPM-resident operands feeding back-to-back FU passes; Pallas: one fused
``pl.pallas_call`` with a VMEM slot file). Planning which ops chain used
to be a private heuristic inside ``pallas_backend``; this pass computes
it ONCE on the IR so every backend sees the same regions:

  * ``pallas`` compiles each :class:`FusedRegion` into a single fused
    kernel call (no re-derivation),
  * ``cyclesim`` can apply an optional chaining discount to region
    members after the first (the FU skips its startup latency when fed
    by the previous op's stream).

A region is a maximal run of element-wise instructions (``kvcp`` — pure
data movement — excluded) sharing one vector length and element width,
cut when a window would read a stale value or overlap pending writes
(the flush hazards of the old Pallas walk), or when the slot-file bounds
``max_ops`` / ``max_inputs`` are hit. ``ScalarBlock`` items do not break
a region; any other instruction does.

The plan is attached as ``program.meta["fused_regions"]`` by the
:func:`fuse_regions` pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.kvi.ir import (ELEMWISE_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock)

# one region-internal slot instruction: (op, dst_slot, src1, src2|None, imm)
SlotOp = Tuple[str, int, int, Optional[int], int]
# one operand window: (vreg id, element offset, length)
Key = Tuple[int, int, int]

MAX_FUSED_OPS = 64                    # slot-file pressure bounds
MAX_FUSED_INPUTS = 24

META_KEY = "fused_regions"


def _overlaps(a: Key, b: Key) -> bool:
    return (a[0] == b[0] and a != b
            and a[1] < b[1] + b[2] and b[1] < a[1] + a[2])


@dataclass(frozen=True)
class FusedRegion:
    """One maximal element-wise chain, ready for fused execution.

    items   — indices into ``program.items`` (ascending; non-contiguous
              only across ScalarBlock fillers).
    ops     — the slot program, in instruction order.
    inputs  — (window, slot) pairs gathered before the region runs.
    outputs — (window, slot) pairs written back after, in first-write
              order.
    """

    items: Tuple[int, ...]
    length: int
    elem_bytes: int
    ops: Tuple[SlotOp, ...]
    inputs: Tuple[Tuple[Key, int], ...]
    outputs: Tuple[Tuple[Key, int], ...]
    n_slots: int


@dataclass(frozen=True)
class FusionPlan:
    """All regions of one program plus the bounds they were planned
    under (backends re-plan if their slot-file bounds differ)."""

    regions: Tuple[FusedRegion, ...]
    max_ops: int = MAX_FUSED_OPS
    max_inputs: int = MAX_FUSED_INPUTS

    @property
    def n_fused_ops(self) -> int:
        return sum(len(r.ops) for r in self.regions)

    def member_items(self) -> frozenset:
        return frozenset(i for r in self.regions for i in r.items)


class _Builder:
    """Mutable accumulation state for one region being planned."""

    def __init__(self, length: int, elem_bytes: int):
        self.length = length
        self.elem_bytes = elem_bytes
        self.item_idx: List[int] = []
        self.ops: List[SlotOp] = []
        self.slot_of: Dict[Key, int] = {}
        self.gathered: List[Key] = []
        self.written: List[Key] = []

    def slot_for(self, key: Key, is_dst: bool,
                 max_inputs: int) -> Optional[int]:
        """Slot index for ``key``; None means the region must be cut
        first (window overlaps pending writes, or input file full)."""
        if (key not in self.written
                and any(_overlaps(key, w) for w in self.written)):
            # reads: the gathered window went stale; writes: two
            # overlapping written windows would write back in first-write
            # order — both hazards end the region here
            return None
        if key in self.slot_of:
            return self.slot_of[key]
        if not is_dst and len(self.gathered) >= max_inputs:
            return None
        s = len(self.slot_of)
        self.slot_of[key] = s
        if not is_dst:
            self.gathered.append(key)
        return s

    def finish(self) -> FusedRegion:
        return FusedRegion(
            items=tuple(self.item_idx),
            length=self.length, elem_bytes=self.elem_bytes,
            ops=tuple(self.ops),
            inputs=tuple((k, self.slot_of[k]) for k in self.gathered),
            outputs=tuple((k, self.slot_of[k]) for k in self.written),
            n_slots=len(self.slot_of))


def plan_fusion_regions(program: KviProgram,
                        max_ops: int = MAX_FUSED_OPS,
                        max_inputs: int = MAX_FUSED_INPUTS) -> FusionPlan:
    """Segment ``program`` into maximal fusable element-wise regions.

    Pure function of the instruction stream — structurally identical
    programs get identical plans, which is what lets batched backends
    share one plan per group.
    """
    regions: List[FusedRegion] = []
    seg: Optional[_Builder] = None

    def cut():
        nonlocal seg
        if seg is not None and seg.ops:
            regions.append(seg.finish())
        seg = None

    for idx, it in enumerate(program.items):
        if isinstance(it, ScalarBlock):
            continue                  # scalar work does not break a chain
        i: KviInstr = it
        if i.op not in ELEMWISE_OPS or i.op is KviOp.KVCP:
            cut()                     # data movement / reductions end it
            continue
        if seg is not None and (seg.length != i.length
                                or seg.elem_bytes != i.elem_bytes
                                or len(seg.ops) >= max_ops):
            cut()
        while True:
            if seg is None:
                seg = _Builder(i.length, i.elem_bytes)
            slots = []
            ok = True
            for ref, is_dst in ((i.src1, False), (i.src2, False),
                                (i.dst, True)):
                if ref is None:
                    slots.append(None)
                    continue
                s = seg.slot_for((ref.id, ref.offset, i.length), is_dst,
                                 max_inputs)
                if s is None:
                    ok = False
                    break
                slots.append(s)
            if ok:
                break
            cut()
        s1, s2, d = slots
        seg.ops.append((i.op.value, d, s1, s2, i.scalar))
        seg.item_idx.append(idx)
        dkey = (i.dst.id, i.dst.offset, i.length)
        if dkey not in seg.written:
            seg.written.append(dkey)
    cut()
    return FusionPlan(tuple(regions), max_ops, max_inputs)


def fuse_regions(program: KviProgram) -> KviProgram:
    """The pipeline pass: attach the fusion plan as program metadata."""
    plan = plan_fusion_regions(program)
    if not plan.regions:
        return program
    return program.with_meta(**{META_KEY: plan})
