"""The optimizing pass pipeline driver.

A *pass* is a pure function ``KviProgram -> KviProgram`` that preserves
functional semantics bit-for-bit (validated by the differential fuzz
suite in ``tests/kvi/test_passes.py``). A :class:`PassPipeline` applies
a sequence of passes; ``Backend.run_workload`` runs the default pipeline
on every entry before execution, with ``passes=()`` as the escape hatch
and ``passes=("dce",)``-style specs for custom selections.

Default order::

    copy_prop -> dce -> fuse_regions

``copy_prop`` first (it strands the moves it bypasses), ``dce`` second
(it sweeps them plus anything never observed), ``fuse_regions`` last (it
plans on the final instruction stream and only attaches metadata).

Passes that change nothing return the *same object*, so an unoptimizable
program flows through the pipeline untouched — important for callers
that key caches on program identity.

With ``verify=True`` the pipeline becomes its own sanitizer: the static
analyzer (:mod:`repro.kvi.analysis`) runs on the input program and again
after **every** pass, and the first pass whose output carries a
diagnostic the previous stage did not raises
:class:`PassVerificationError` naming that pass — a miscompiling pass
is caught at the pass boundary instead of as a backend divergence.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple, Union

from repro.kvi.ir import KviProgram
from repro.kvi.passes.copy_prop import copy_prop
from repro.kvi.passes.dce import dce
from repro.kvi.passes.fusion import fuse_regions

Pass = Callable[[KviProgram], KviProgram]
PassSpec = Union[str, Pass]

#: name -> pass, the vocabulary accepted in ``passes=(...)`` specs
REGISTERED_PASSES: Dict[str, Pass] = {
    "copy_prop": copy_prop,
    "dce": dce,
    "fuse_regions": fuse_regions,
}

DEFAULT_PASSES: Tuple[str, ...] = ("copy_prop", "dce", "fuse_regions")


def _resolve(spec: PassSpec) -> Pass:
    if callable(spec):
        return spec
    try:
        return REGISTERED_PASSES[spec]
    except KeyError:
        raise KeyError(f"unknown pass {spec!r}; available: "
                       f"{sorted(REGISTERED_PASSES)}") from None


class PassVerificationError(RuntimeError):
    """A pass (or the pipeline's input) failed static verification.

    ``pass_name`` is the pass whose output first showed the new
    diagnostics (``"<input>"`` when the program was broken before any
    pass ran); ``report`` carries the offending diagnostics."""

    def __init__(self, pass_name: str, report, program_name: str):
        self.pass_name = pass_name
        self.report = report
        self.program_name = program_name
        where = ("input program" if pass_name == "<input>"
                 else f"pass {pass_name!r}")
        super().__init__(
            f"pipeline verification of {program_name!r}: {where} "
            f"introduced {len(report)} new diagnostic"
            f"{'s' if len(report) != 1 else ''}:\n"
            + report.render_text())


@dataclass(frozen=True)
class PassPipeline:
    """An ordered sequence of semantics-preserving program passes.

    ``verify=True`` runs the static analyzer between every pass and
    attributes the first new error to the pass that introduced it."""

    passes: Tuple[Pass, ...]
    verify: bool = False

    @classmethod
    def from_spec(cls, spec, verify: bool = False) -> "PassPipeline":
        """Build a pipeline from ``None`` (the default pipeline), an
        existing pipeline, or a sequence of pass names / callables
        (``()`` disables optimization entirely)."""
        if isinstance(spec, PassPipeline):
            if verify and not spec.verify:
                return dataclasses.replace(spec, verify=True)
            return spec
        if spec is None:
            spec = DEFAULT_PASSES
        elif isinstance(spec, (str, bytes)) or callable(spec):
            spec = (spec,)
        return cls(tuple(_resolve(s) for s in spec), verify=verify)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(getattr(p, "__name__", repr(p)) for p in self.passes)

    def run(self, program: KviProgram) -> KviProgram:
        if not self.verify:
            for p in self.passes:
                program = p(program)
            return program
        return self._run_verified(program)

    def _run_verified(self, program: KviProgram) -> KviProgram:
        """Analyze input + every intermediate; raise on the pass whose
        output first carries an error-severity diagnostic not already
        present before it ran. Diagnostic identity is the pass-stable
        ``Diagnostic.key`` (code + subject name), not item indices —
        indices shift as passes delete instructions."""
        from repro.kvi.analysis import DiagnosticReport, analyze_program
        rep = analyze_program(program)
        if not rep.ok:
            raise PassVerificationError(
                "<input>", DiagnosticReport(rep.errors), program.name)
        baseline = rep.keys()
        for p, name in zip(self.passes, self.names):
            program = p(program)
            rep = analyze_program(program)
            new = [d for d in rep.errors if d.key not in baseline]
            if new:
                raise PassVerificationError(
                    name, DiagnosticReport(new), program.name)
            baseline |= rep.keys()
        return program

    def __bool__(self) -> bool:
        return bool(self.passes)


def default_pipeline() -> PassPipeline:
    return PassPipeline.from_spec(None)


def optimize_program(program: KviProgram, passes=None) -> KviProgram:
    """One-shot convenience: run ``program`` through a pipeline spec."""
    return PassPipeline.from_spec(passes).run(program)
