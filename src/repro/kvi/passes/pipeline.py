"""The optimizing pass pipeline driver.

A *pass* is a pure function ``KviProgram -> KviProgram`` that preserves
functional semantics bit-for-bit (validated by the differential fuzz
suite in ``tests/kvi/test_passes.py``). A :class:`PassPipeline` applies
a sequence of passes; ``Backend.run_workload`` runs the default pipeline
on every entry before execution, with ``passes=()`` as the escape hatch
and ``passes=("dce",)``-style specs for custom selections.

Default order::

    copy_prop -> dce -> fuse_regions

``copy_prop`` first (it strands the moves it bypasses), ``dce`` second
(it sweeps them plus anything never observed), ``fuse_regions`` last (it
plans on the final instruction stream and only attaches metadata).

Passes that change nothing return the *same object*, so an unoptimizable
program flows through the pipeline untouched — important for callers
that key caches on program identity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Sequence, Tuple, Union

from repro.kvi.ir import KviProgram
from repro.kvi.passes.copy_prop import copy_prop
from repro.kvi.passes.dce import dce
from repro.kvi.passes.fusion import fuse_regions

Pass = Callable[[KviProgram], KviProgram]
PassSpec = Union[str, Pass]

#: name -> pass, the vocabulary accepted in ``passes=(...)`` specs
REGISTERED_PASSES: Dict[str, Pass] = {
    "copy_prop": copy_prop,
    "dce": dce,
    "fuse_regions": fuse_regions,
}

DEFAULT_PASSES: Tuple[str, ...] = ("copy_prop", "dce", "fuse_regions")


def _resolve(spec: PassSpec) -> Pass:
    if callable(spec):
        return spec
    try:
        return REGISTERED_PASSES[spec]
    except KeyError:
        raise KeyError(f"unknown pass {spec!r}; available: "
                       f"{sorted(REGISTERED_PASSES)}") from None


@dataclass(frozen=True)
class PassPipeline:
    """An ordered sequence of semantics-preserving program passes."""

    passes: Tuple[Pass, ...]

    @classmethod
    def from_spec(cls, spec) -> "PassPipeline":
        """Build a pipeline from ``None`` (the default pipeline), an
        existing pipeline, or a sequence of pass names / callables
        (``()`` disables optimization entirely)."""
        if spec is None:
            return cls(tuple(_resolve(s) for s in DEFAULT_PASSES))
        if isinstance(spec, PassPipeline):
            return spec
        if isinstance(spec, (str, bytes)) or callable(spec):
            spec = (spec,)
        return cls(tuple(_resolve(s) for s in spec))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(getattr(p, "__name__", repr(p)) for p in self.passes)

    def run(self, program: KviProgram) -> KviProgram:
        for p in self.passes:
            program = p(program)
        return program

    def __bool__(self) -> bool:
        return bool(self.passes)


def default_pipeline() -> PassPipeline:
    return PassPipeline.from_spec(None)


def optimize_program(program: KviProgram, passes=None) -> KviProgram:
    """One-shot convenience: run ``program`` through a pipeline spec."""
    return PassPipeline.from_spec(passes).run(program)
