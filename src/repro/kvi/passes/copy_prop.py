"""Copy propagation over whole-register ``kvcp`` moves.

After ``kvcp d, s`` where both windows cover their full registers (same
length, same element width), later reads of ``d`` are redirected to the
equivalent window of ``s`` — until either register is written again.
Chains resolve transitively (``kvcp b, a; kvcp c, b`` makes reads of
``c`` read ``a``). Identity copies left behind by the substitution are
dropped outright; copies whose destination is never read again become
dead and fall to the ``dce`` pass.

This matters beyond cycle counts: a ``kvcp`` is data movement, so it
BREAKS an element-wise fusion region (on the Pallas backend it forces a
segment flush — an extra ``pallas_call`` and a VMEM round-trip; on the
hardware model an extra SPM copy). Removing the move lets the fusion
planner weld the two halves into one region.

Partial-window copies (e.g. the FFT bit-reversal's single-element moves)
are left untouched — only their *source* operands get substituted.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.kvi.ir import KviInstr, KviOp, KviProgram, Ref, ScalarBlock


def _is_full(program: KviProgram, ref: Ref, length: int) -> bool:
    return (ref is not None and ref.space == "vreg" and ref.offset == 0
            and length == program.vreg_by_id(ref.id).length)


def copy_prop(program: KviProgram) -> KviProgram:
    copies: Dict[int, int] = {}       # dst vreg id -> equivalent src id
    items = []
    changed = False

    def sub(ref: Optional[Ref]) -> Optional[Ref]:
        nonlocal changed
        if (ref is not None and ref.space == "vreg"
                and ref.id in copies):
            changed = True
            return Ref("vreg", copies[ref.id], ref.offset)
        return ref

    def invalidate(rid: int):
        copies.pop(rid, None)
        for d in [d for d, s in copies.items() if s == rid]:
            del copies[d]

    for it in program.items:
        if isinstance(it, ScalarBlock):
            items.append(it)
            continue
        src1, src2 = sub(it.src1), sub(it.src2)
        if it.op is KviOp.KMEMSTR:     # dst is a memory buffer, no reg def
            items.append(it if src1 is it.src1 else
                         KviInstr(it.op, it.dst, src1, src2, it.scalar,
                                  it.length, it.elem_bytes))
            continue
        if (it.op is KviOp.KVCP and _is_full(program, it.dst, it.length)
                and _is_full(program, src1, it.length)
                and program.vreg_by_id(it.dst.id).elem_bytes
                == program.vreg_by_id(src1.id).elem_bytes):
            if src1.id == it.dst.id:   # identity move — drop it
                changed = True
                continue
            invalidate(it.dst.id)
            copies[it.dst.id] = src1.id
            items.append(it if src1 is it.src1 else
                         KviInstr(it.op, it.dst, src1, None, it.scalar,
                                  it.length, it.elem_bytes))
            continue
        # any other definition of dst ends equivalences through it
        invalidate(it.dst.id)
        if src1 is it.src1 and src2 is it.src2:
            items.append(it)
        else:
            items.append(KviInstr(it.op, it.dst, src1, src2, it.scalar,
                                  it.length, it.elem_bytes))
    if not changed:
        return program
    from repro.kvi.passes.dce import _drop_stale_plan
    return program.replace(items=tuple(items),
                           meta=_drop_stale_plan(program.meta))
