"""Dead-code elimination: drop never-observed instructions and the vregs
nothing references afterwards.

An instruction is observed when a value it writes can reach an output
buffer (:func:`~repro.kvi.passes.liveness.observable_items`). Dropping a
dead instruction can strand a vreg entirely; stranded vregs are removed
and the survivors renumbered (declaration order preserved), with every
``Ref`` remapped. ``ScalarBlock`` items always survive — they model
scalar work the cycle backends charge for.

Semantics-preserving by construction: output buffers see the exact same
writes. Beyond dropping work, DCE shrinks the liveness footprint the SPM
allocator packs, so it can *unlock* programs near the scratchpad
capacity limit.
"""
from __future__ import annotations

from typing import Optional

from repro.kvi.ir import (KviInstr, KviProgram, Ref, ScalarBlock, VReg)
from repro.kvi.passes.liveness import observable_items


def _drop_stale_plan(meta: dict) -> dict:
    """A rewritten instruction stream invalidates any attached fusion
    plan (item indices shift, vreg ids remap) — strip it; a later
    ``fuse_regions`` re-plans on the new stream."""
    from repro.kvi.passes.fusion import META_KEY
    return {k: v for k, v in meta.items() if k != META_KEY}


def dce(program: KviProgram) -> KviProgram:
    live = observable_items(program)
    items = [it for it, keep in zip(program.items, live) if keep]

    referenced = set()
    for it in items:
        if isinstance(it, KviInstr):
            for ref in (it.dst, it.src1, it.src2):
                if ref is not None and ref.space == "vreg":
                    referenced.add(ref.id)

    if all(live) and len(referenced) == len(program.vregs):
        return program                # nothing to do: keep identity

    keep_regs = [r for r in program.vregs if r.id in referenced]
    remap = {r.id: i for i, r in enumerate(keep_regs)}
    vregs = tuple(VReg(r.name, remap[r.id], r.length, r.elem_bytes)
                  for r in keep_regs)

    def sub(ref: Optional[Ref]) -> Optional[Ref]:
        if ref is None or ref.space != "vreg":
            return ref
        return Ref("vreg", remap[ref.id], ref.offset)

    new_items = []
    for it in items:
        if isinstance(it, ScalarBlock):
            new_items.append(it)
        else:
            new_items.append(KviInstr(it.op, sub(it.dst), sub(it.src1),
                                      sub(it.src2), it.scalar, it.length,
                                      it.elem_bytes))
    return program.replace(items=tuple(new_items), vregs=vregs,
                           meta=_drop_stale_plan(program.meta))
