"""repro.kvi.passes — the optimizing pass pipeline over KviProgram.

Runs on the backend-neutral IR *before* any backend sees the program, so
every executor benefits identically:

  * :func:`~repro.kvi.passes.copy_prop.copy_prop` — bypass whole-register
    ``kvcp`` moves (they break fusion regions and cost SPM copies),
  * :func:`~repro.kvi.passes.dce.dce` — drop never-observed instructions
    and stranded vregs (liveness-driven),
  * :func:`~repro.kvi.passes.fusion.fuse_regions` — plan maximal
    element-wise chains ONCE as :class:`~repro.kvi.passes.fusion.
    FusedRegion` metadata (Pallas compiles them; cyclesim's optional
    chaining discount reads them),

driven by :class:`~repro.kvi.passes.pipeline.PassPipeline`, with
register liveness (:mod:`~repro.kvi.passes.liveness`) shared with the
linear-scan SPM allocator in ``repro.kvi.lowering``.

Every pass is semantics-preserving: bit-identical outputs on every
backend, enforced by the differential fuzz tests.
"""
from repro.kvi.passes.copy_prop import copy_prop
from repro.kvi.passes.dce import dce
from repro.kvi.passes.fusion import (FusedRegion, FusionPlan, MAX_FUSED_INPUTS,
                                     MAX_FUSED_OPS, META_KEY, fuse_regions,
                                     plan_fusion_regions)
from repro.kvi.passes.liveness import (observable_items, peak_live_bytes,
                                       reg_intervals, total_vreg_bytes)
from repro.kvi.passes.pipeline import (DEFAULT_PASSES, REGISTERED_PASSES,
                                       PassPipeline, PassVerificationError,
                                       default_pipeline, optimize_program)

__all__ = [
    "copy_prop", "dce", "fuse_regions", "plan_fusion_regions",
    "FusedRegion", "FusionPlan", "MAX_FUSED_OPS", "MAX_FUSED_INPUTS",
    "META_KEY", "observable_items", "peak_live_bytes", "reg_intervals",
    "total_vreg_bytes", "PassPipeline", "PassVerificationError",
    "DEFAULT_PASSES", "REGISTERED_PASSES", "default_pipeline",
    "optimize_program",
]
