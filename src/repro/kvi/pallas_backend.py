"""PallasBackend — compiles KVI programs onto fused Pallas kernels.

The Klessydra insight, translated to TPU: vector operands live in the SPM
across a whole *sequence* of vector instructions. Maximal runs of
element-wise instructions — the :class:`~repro.kvi.passes.fusion.
FusedRegion` plan computed by the ``fuse_regions`` pass and attached to
the program's metadata — are compiled into a **single fused
``pl.pallas_call``** each (one VMEM-resident slot file, one HBM read per
input window, one write per output window); reductions go through the
Pallas kdotp/kvred kernels; ``kmemld``/``kmemstr``/``kvcp`` are data
movement handled on the register file. This backend no longer derives
the fusion segmentation itself: it executes the plan handed to it,
re-planning (through the same planner) only when the program carries no
plan (``passes=()``) or one planned under different slot-file bounds.

Workload batching: a homogeneous :class:`~repro.kvi.workload.KviWorkload`
(N data instances of one program structure) executes with a **batch grid
dimension** — every fused segment is ONE ``pallas_call`` over an
``(N, grid)`` grid and every reduction is one vmapped kernel launch, so N
instances cost one compile and one dispatch per segment instead of N.
Heterogeneous workloads are grouped by program structure and each group is
batched the same way.

``fused_elementwise_call`` is the public compile-and-run primitive for an
element-wise slot program. It supersedes the untyped tuple protocol that
used to live in ``repro.kernels.kvi_vops`` (kept there as a deprecation
shim).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, pick_block
from repro.kvi.backend import (BackendBase, BackendResult, register_backend)
from repro.kvi.ir import (ELEMWISE_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock, np_dtype)
from repro.kvi.passes.fusion import (MAX_FUSED_INPUTS, MAX_FUSED_OPS,
                                     META_KEY, FusedRegion, FusionPlan,
                                     plan_fusion_regions)
from repro.kvi.workload import (KviWorkload, WorkloadResult,
                                structural_signature)

# one fused element-wise slot instruction: (op, dst, src1, src2|None, imm)
SlotOp = Tuple[str, int, int, Optional[int], int]

_UNSIGNED = {jnp.int8.dtype: jnp.uint8, jnp.int16.dtype: jnp.uint16,
             jnp.int32.dtype: jnp.uint32}


@dataclass
class KernelCache:
    """Compiled-call cache: slot-program structure -> a ``jax.jit``-wrapped
    callable closing over its ``pl.pallas_call`` (or vmapped reduction
    kernel). Keys carry everything baked into the trace — the op/slot
    program, batch shape, block split, dtype and interpret flag — so a hit
    is exactly a compiled executable reuse.

    An eager interpret-mode ``pallas_call`` re-traces on every invocation
    (~100 ms for even a tiny fused segment); a warm jitted call costs tens
    of microseconds. Scoped to a :class:`PallasBackend` instance by
    default, so repeated ``run_workload`` calls — the serving engine's
    steady-state traffic, the DSE's warm-up iterations — pay zero
    recompiles; pass one cache to several backends to share it wider.

    ``misses`` counts builds (compiles), ``hits`` compiled-call reuses.
    """

    hits: int = 0
    misses: int = 0
    _fns: Dict[tuple, Callable] = field(default_factory=dict)

    def get(self, key: tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = build()
        else:
            self.hits += 1
        return fn

    def clear(self) -> None:
        """Drop every compiled entry and reset the counters."""
        self._fns.clear()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._fns)

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._fns)}


def apply_vop(op: str, a, b, imm: int):
    """Element-wise KVI semantics shared by the fused kernel body and the
    jnp oracle (wrap-around integer arithmetic like the Klessydra MFU)."""
    if op == "kaddv":
        return a + b
    if op == "ksubv":
        return a - b
    if op == "kvmul":
        return a * b
    if op == "ksvaddsc":
        return a + jnp.asarray(imm, a.dtype)
    if op == "ksvmulsc":
        return a * jnp.asarray(imm, a.dtype)
    if op == "ksrlv":
        u = _UNSIGNED.get(jnp.dtype(a.dtype), jnp.uint32)
        ua = a.astype(u)
        return (ua >> jnp.asarray(imm, u)).astype(a.dtype)
    if op == "ksrav":
        return a >> jnp.asarray(imm, a.dtype)
    if op == "krelu":
        return jnp.maximum(a, jnp.asarray(0, a.dtype))
    if op == "kvslt":
        return (a < b).astype(a.dtype)
    if op == "ksvslt":
        return (a < jnp.asarray(imm, a.dtype)).astype(a.dtype)
    if op == "kvcp":
        return a
    raise ValueError(op)


def _fused_kernel(*refs, program: Tuple[SlotOp, ...], in_slots, out_slots,
                  n_slots: int):
    in_refs = refs[:len(in_slots)]
    out_refs = refs[len(in_slots):]
    slots: List = [None] * n_slots
    for r, s in zip(in_refs, in_slots):
        slots[s] = r[...]
    for op, dst, s1, s2, imm in program:
        a = slots[s1]
        b = slots[s2] if s2 is not None else None
        slots[dst] = apply_vop(op, a, b, imm)
    for r, s in zip(out_refs, out_slots):
        r[...] = slots[s]


def _make_fused_caller(program: Tuple[SlotOp, ...], in_slots: tuple,
                       out_slots: tuple, n_slots: int, N: Optional[int],
                       n: int, bl: int, dt, interp: bool) -> Callable:
    """A callable running the fused slot program as one ``pl.pallas_call``
    over flat ``(n,)`` vectors (``N is None``) or an ``(N, n)`` batch.
    Everything shape- or structure-dependent is closed over, so the
    callable is jit-cacheable by identity (:class:`KernelCache`)."""
    grid = n // bl
    kernel = functools.partial(_fused_kernel, program=program,
                               in_slots=in_slots, out_slots=out_slots,
                               n_slots=n_slots)

    def call(*arrs):
        if N is not None:
            outs = pl.pallas_call(
                kernel,
                grid=(N, grid),
                in_specs=[pl.BlockSpec((1, 1, bl), lambda b, i: (b, i, 0))
                          for _ in arrs],
                out_specs=[pl.BlockSpec((1, 1, bl), lambda b, i: (b, i, 0))
                           for _ in out_slots],
                out_shape=[jax.ShapeDtypeStruct((N, grid, bl), dt)
                           for _ in out_slots],
                interpret=interp,
            )(*[x.reshape(N, grid, bl) for x in arrs])
            return [o.reshape(N, n) for o in outs]
        outs = pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0))
                      for _ in arrs],
            out_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0))
                       for _ in out_slots],
            out_shape=[jax.ShapeDtypeStruct((grid, bl), dt)
                       for _ in out_slots],
            interpret=interp,
        )(*[x.reshape(grid, bl) for x in arrs])
        return [o.reshape(n) for o in outs]

    return call


def fused_elementwise_call(program: Sequence[SlotOp],
                           inputs: Sequence[Tuple[int, jax.Array]],
                           out_slots: Sequence[int],
                           n_slots: Optional[int] = None,
                           block: int = 1024,
                           interpret: Optional[bool] = None,
                           batched: bool = False,
                           cache: Optional[KernelCache] = None,
                           ) -> List[jax.Array]:
    """Run an element-wise slot program as one fused ``pl.pallas_call``.

    ``inputs`` preload (slot, vector) pairs; every entry of ``out_slots``
    comes back as an array of the common vector length. All vectors share
    one length and dtype (one SPM line width per program).

    With ``batched=True`` every input is ``(N, n)`` — N program instances
    — and the call runs over an ``(N, n // block)`` grid: one compile and
    ONE dispatch for the whole batch. Outputs come back ``(N, n)``.

    With a :class:`KernelCache` the call goes through a jitted compiled
    executable cached on the program's structure and shapes — repeated
    calls with the same structure (any data) skip tracing and compilation
    entirely. Two calls only differ in dispatch cost; values are
    identical either way.
    """
    program = tuple(program)
    for op, *_ in program:
        if KviOp(op) not in ELEMWISE_OPS:
            raise ValueError(f"{op} is not an element-wise KVI op")
    if not inputs:
        raise ValueError("fused program needs at least one input vector")
    if n_slots is None:
        n_slots = 1 + max([s for s, _ in inputs] + [o[1] for o in program]
                          + list(out_slots))
    if batched:
        arrs = [x.reshape(x.shape[0], -1) for _, x in inputs]
        N = arrs[0].shape[0]
    else:
        arrs = [jnp.ravel(x) for _, x in inputs]
        N = None
    n = arrs[0].shape[-1]
    dt = arrs[0].dtype
    if any(x.shape[-1] != n for x in arrs):
        raise ValueError("input length mismatch in fused program")
    bl = pick_block(n, block, align=8)
    assert n % bl == 0, (n, bl)

    in_slots = tuple(s for s, _ in inputs)
    out_slots = tuple(out_slots)
    interp = INTERPRET if interpret is None else interpret
    if cache is None:
        return _make_fused_caller(program, in_slots, out_slots, n_slots,
                                  N, n, bl, dt, interp)(*arrs)
    key = ("fused", program, in_slots, out_slots, n_slots, N, n, bl,
           str(dt), interp)
    fn = cache.get(key, lambda: jax.jit(_make_fused_caller(
        program, in_slots, out_slots, n_slots, N, n, bl, dt, interp)))
    return list(fn(*arrs))


# ---------------------------------------------------------------------------
# Whole-program executor: walks a KviProgram, executing the planned
# FusedRegions. The walk is batched: the register file and main memory
# carry a leading batch dimension of N program instances sharing one
# structure.
# ---------------------------------------------------------------------------

# a slot key: one (vreg id, element offset, length) window
_Key = Tuple[int, int, int]


@register_backend("pallas")
class PallasBackend(BackendBase):
    """Executes KVI workloads on fused Pallas kernels (TPU, or CPU with
    ``interpret=True`` — the default off-TPU).

    max_fused_ops / max_fused_inputs bound how much of the element-wise
    subgraph one ``pallas_call`` swallows (VMEM slot-file pressure);
    programs optimized by the default pipeline arrive with a
    :class:`FusionPlan` under the same bounds, which is executed as-is.
    ``fused_calls`` counts issued ``pallas_call``s — a batch of N
    homogeneous instances issues the same number as a single instance.

    Every dispatch goes through an instance-scoped :class:`KernelCache`
    (pass ``kernel_cache=`` to share one across backends): compiled
    executables are keyed on slot-program structure + batch shape +
    dtype, so repeated ``run_workload`` calls over the same program
    structures — serving traffic, warm-up iterations, repeated DSE
    measurement classes — recompile nothing. Per-call hit/miss deltas
    land in the result's ``meta['compile_cache']``."""

    def __init__(self, interpret: Optional[bool] = None, block: int = 1024,
                 max_fused_ops: int = MAX_FUSED_OPS,
                 max_fused_inputs: int = MAX_FUSED_INPUTS,
                 passes=None, verify: bool = False,
                 kernel_cache: Optional[KernelCache] = None, obs=None):
        self.interpret = INTERPRET if interpret is None else interpret
        self.block = block
        self.max_fused_ops = max_fused_ops
        self.max_fused_inputs = max_fused_inputs
        self.passes = passes
        self.verify = verify
        # optional telemetry bundle (repro.kvi.obs.Obs): wall-domain
        # spans per run_workload + compile-cache / dispatch counters
        self.obs = obs
        self.kernel_cache = kernel_cache if kernel_cache is not None \
            else KernelCache()
        self.fused_calls = 0             # observability: pallas_call count
        self.reduce_calls = 0           # vmapped reduction kernel launches

    # -- register-file helpers -------------------------------------------
    # regfile[rid] is (N, length): N batched program instances.
    def _slice(self, regfile, key: _Key):
        rid, off, n = key
        r = regfile[rid]
        return jax.lax.slice(r, (0, off), (r.shape[0], off + n))

    def _set(self, regfile, key: _Key, val):
        rid, off, n = key
        regfile[rid] = regfile[rid].at[:, off:off + n].set(
            val.astype(regfile[rid].dtype))

    # -- fusion plan -------------------------------------------------------
    def _plan(self, program: KviProgram) -> FusionPlan:
        """The program's attached fusion plan, or a fresh one when absent
        (``passes=()``) / planned under different slot-file bounds."""
        plan = program.meta.get(META_KEY)
        if (isinstance(plan, FusionPlan)
                and plan.max_ops == self.max_fused_ops
                and plan.max_inputs == self.max_fused_inputs):
            return plan
        return plan_fusion_regions(program, self.max_fused_ops,
                                   self.max_fused_inputs)

    def _run_region(self, region: FusedRegion, regfile):
        """One planned region = ONE fused ``pallas_call`` over the whole
        batch grid."""
        inputs = [(slot, self._slice(regfile, key))
                  for key, slot in region.inputs]
        outs = fused_elementwise_call(
            region.ops, inputs, [slot for _, slot in region.outputs],
            n_slots=region.n_slots, block=self.block,
            interpret=self.interpret, batched=True,
            cache=self.kernel_cache)
        self.fused_calls += 1
        for (key, _slot), v in zip(region.outputs, outs):
            self._set(regfile, key, v)

    # -- scalar reductions -------------------------------------------------
    def _make_reducer(self, op: KviOp, scalar: int,
                      interp: bool) -> Callable:
        """A jit-cacheable vmapped reduction over the batch dimension
        (scalar immediates are baked in — they are part of the cache
        key)."""
        from repro.kernels import kdotp as _kd
        if op is KviOp.KVRED:
            return jax.vmap(lambda x: _kd.kvred(x, interpret=interp))
        if op is KviOp.KDOTP:
            return jax.vmap(lambda x, y: _kd.kdotp(x, y, interpret=interp))
        if op is KviOp.KDOTPPS:
            return jax.vmap(lambda x, y: _kd.kdotpps(x, y, scalar,
                                                     interpret=interp))
        if op is KviOp.KSVADDRF:
            return jax.vmap(lambda x: _kd.kvred(x, interpret=interp)
                            + jnp.asarray(scalar, jnp.int32))
        if op is KviOp.KSVMULRF:
            # sum(a * s) == s * sum(a)  (mod 2^32 wrap arithmetic)
            return jax.vmap(lambda x: _kd.kvred(x, interpret=interp)
                            * jnp.asarray(scalar, jnp.int32))
        raise ValueError(op)             # pragma: no cover

    def _reduce(self, i: KviInstr, regfile):
        """One vmapped reduction kernel over the whole batch: the batch
        dimension becomes a vmap axis over the Pallas kdotp/kvred kernels
        (one launch for N instances, compiled once per structure via the
        kernel cache)."""
        a = self._slice(regfile, (i.src1.id, i.src1.offset, i.length))
        interp = self.interpret
        key = ("red", i.op.value, i.scalar, a.shape[0], i.length,
               str(a.dtype), interp)
        fn = self.kernel_cache.get(
            key, lambda: jax.jit(self._make_reducer(i.op, i.scalar,
                                                    interp)))
        if i.op in (KviOp.KDOTP, KviOp.KDOTPPS):
            b = self._slice(regfile, (i.src2.id, i.src2.offset, i.length))
            r = fn(a, b)
        else:
            r = fn(a)
        self.reduce_calls += 1
        self._set(regfile, (i.dst.id, i.dst.offset, 1),
                  jnp.reshape(r, (r.shape[0], 1)))

    # -- batched walk ------------------------------------------------------
    def _run_batch(self, programs: Sequence[KviProgram]
                   ) -> List[Dict[str, np.ndarray]]:
        """Execute N structurally identical programs (different data) in
        one batched walk: every planned region is one ``pallas_call``
        over a batch grid, every reduction one vmapped kernel."""
        proto = programs[0]
        N = len(programs)
        regfile = {r.id: jnp.zeros((N, r.length), np_dtype(r.elem_bytes))
                   for r in proto.vregs}
        mem = {m.id: np.stack([np.asarray(p.mem_init[m.id]).reshape(-1)
                               for p in programs])
               for m in proto.mems}
        plan = self._plan(proto)
        region_at = {r.items[0]: r for r in plan.regions}
        fused = plan.member_items()

        for idx, it in enumerate(proto.items):
            if isinstance(it, ScalarBlock):
                continue                 # no timing model here
            region = region_at.get(idx)
            if region is not None:
                self._run_region(region, regfile)
                continue
            if idx in fused:
                continue                 # executed with its region head
            i: KviInstr = it
            if i.op is KviOp.KMEMLD:
                arr = mem[i.src1.id]
                # Mfu semantics: the whole buffer lands in the scratchpad
                self._set(regfile, (i.dst.id, i.dst.offset, arr.shape[1]),
                          jnp.asarray(arr, np_dtype(i.elem_bytes)))
            elif i.op is KviOp.KMEMSTR:
                v = self._slice(regfile,
                                (i.src1.id, i.src1.offset, i.length))
                mem[i.dst.id] = np.asarray(v)
            elif i.op is KviOp.KVCP:
                v = self._slice(regfile,
                                (i.src1.id, i.src1.offset, i.length))
                self._set(regfile, (i.dst.id, i.dst.offset, i.length), v)
            else:
                self._reduce(i, regfile)

        results = []
        for b in range(N):
            outputs = {}
            for m in programs[b].outputs:
                shape = programs[b].mem_init[m.id].shape
                outputs[m.name] = np.asarray(mem[m.id][b]
                                             ).reshape(shape).copy()
            results.append(outputs)
        return results

    def run_workload(self, workload: KviWorkload,
                     verify: Optional[bool] = None) -> WorkloadResult:
        """Group entries by program structure; each group runs as one
        batched walk (one compile + one dispatch per fused segment for the
        whole group). Hart assignments carry no timing meaning here — on
        TPU the batch grid IS the hart-level parallelism.

        ``meta`` reports the run's observability: structural ``groups``,
        issued ``pallas_calls``, this call's kernel-cache hit/miss deltas
        (``compile_cache``) and ``wall_s`` — the real execution walltime
        (outputs are materialized to numpy inside the walk, so the clock
        covers compile + dispatch + compute, not an async handle). The
        DSE walltime axis and the serving engine read these directly."""
        t0 = time.perf_counter()
        workload = self.optimize_workload(workload, verify=verify)
        calls_before = self.fused_calls + self.reduce_calls
        cc_before = (self.kernel_cache.hits, self.kernel_cache.misses)
        groups: Dict[tuple, List[int]] = {}
        for idx, e in enumerate(workload.entries):
            groups.setdefault(structural_signature(e.program),
                              []).append(idx)
        entry_outputs: List[Optional[Dict[str, np.ndarray]]] = \
            [None] * len(workload.entries)
        for idxs in groups.values():
            outs = self._run_batch(
                [workload.entries[i].program for i in idxs])
            for i, out in zip(idxs, outs):
                entry_outputs[i] = out
        results = tuple(BackendResult(self.name, out)
                        for out in entry_outputs)
        calls = self.fused_calls + self.reduce_calls - calls_before
        cc = {"hits": self.kernel_cache.hits - cc_before[0],
              "misses": self.kernel_cache.misses - cc_before[1]}
        wall_s = round(time.perf_counter() - t0, 6)
        if self.obs is not None and self.obs.enabled:
            tr = self.obs.tracer
            start_us = tr.wall_us() - wall_s * 1e6
            tr.span(("pallas", "run_workload"), "run_workload",
                    round(max(0.0, start_us), 3), round(wall_s * 1e6, 3),
                    cat="wall", clock="wall",
                    args={"entries": len(workload.entries),
                          "groups": len(groups), "pallas_calls": calls})
            m = self.obs.metrics
            m.counter("pallas.runs").inc()
            m.counter("pallas.calls").inc(calls)
            m.absorb("pallas.compile_cache", cc)
            m.histogram("pallas.run_wall_s").observe(wall_s)
        return WorkloadResult(
            self.name, workload, results,
            meta={"groups": len(groups),
                  "pallas_calls": calls,
                  "compile_cache": cc,
                  "wall_s": wall_s})
