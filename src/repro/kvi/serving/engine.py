"""Request-driven KVI serving engine: continuous admission onto harts,
signature batching into fused Pallas kernels, warm compiled-kernel reuse.

The engine joins the repo's three serving ingredients into one system:

  * **admission** — :class:`~repro.kvi.scheduler.HartScheduler.admit`
    places each arrived request on the hart that frees earliest
    (continuous admission: no head-of-line blocking — a long matmul on
    one hart never delays convs landing on the others). Latency is
    measured in *virtual cycles*: request arrival to estimated hart
    completion, using the scheduler's solo-simulation profiles.
  * **batching** — every engine step groups the admitted wave by
    :func:`~repro.kvi.workload.structural_signature` (== by template)
    and executes each group through ``PallasBackend.run_workload`` as a
    homogeneous batch: one ``pallas_call`` per fused segment for the
    whole group, regardless of group size.
  * **compiled-kernel reuse** — batch sizes are bucketed to powers of
    two (``max_batch`` cap) so the set of compiled shapes is finite, and
    every bucket is **prewarmed** before traffic: the backend's
    :class:`~repro.kvi.pallas_backend.KernelCache` then serves the whole
    run hit-only — steady-state traffic pays zero recompiles.

Engine time advances in *batching windows*: a step admits everything
that has arrived by ``now``, executes it, and the next step begins when
the earliest hart frees (or at the next arrival when the machine is
idle). Under load, requests accumulate during the window — batch sizes
grow with traffic, which is exactly the throughput-under-occupancy story
the paper tells at kernel granularity.

Everything except wall-clock measurements is deterministic under the
load seed: the report's cycle-domain metrics (latency percentiles,
utilization, batch histograms, cache counters) are byte-stable, which
:func:`canonical_report` exposes for the determinism gates.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.kvi.lowering import TraceCache
# the volatile-key set and scrubber live in the shared obs layer now;
# SERVE_VOLATILE stays importable from here for backwards compatibility
from repro.kvi.obs.scrub import SERVE_VOLATILE, scrub  # noqa: F401
from repro.kvi.scheduler import HartScheduler, Ticket
from repro.kvi.serving.load import KernelTemplate, RequestSpec
from repro.kvi.workload import KviWorkload


def canonical_report(report: Dict[str, object]) -> str:
    """The report serialized with every wall-clock field stripped —
    byte-identical across runs for the same seed, trace and engine
    configuration (the determinism gate compares these)."""
    return json.dumps(scrub(report, SERVE_VOLATILE),
                      indent=2, sort_keys=True)


def bucket_sizes(n: int, max_batch: int) -> List[int]:
    """Greedy power-of-two split of a group of ``n`` requests into
    compiled batch-shape buckets: 13 -> [8, 4, 1] under max_batch=8.
    Bounding the shape set is what makes ahead-of-time prewarming (and
    a 100% steady-state cache hit rate) possible."""
    if n <= 0:
        return []
    sizes = []
    b = 1
    while b * 2 <= max_batch:
        b *= 2
    while n > 0:
        while b > n:
            b //= 2
        sizes.append(b)
        n -= b
    return sizes


def _percentiles(xs: Sequence[int]) -> Dict[str, int]:
    """Deterministic integer latency percentiles (nearest-rank)."""
    if not xs:
        return {"p50": 0, "p95": 0, "p99": 0, "mean": 0, "max": 0}
    arr = np.sort(np.asarray(xs, dtype=np.int64))
    def rank(q: float) -> int:
        return int(arr[min(len(arr) - 1,
                           max(0, int(np.ceil(q * len(arr))) - 1))])
    return {"p50": rank(0.50), "p95": rank(0.95), "p99": rank(0.99),
            "mean": int(np.floor(arr.mean())), "max": int(arr[-1])}


@dataclass
class ServedRequest:
    """One request's lifecycle through the engine."""

    rid: int
    spec: RequestSpec
    template: KernelTemplate
    ticket: Optional[Ticket] = None      # filled at admission
    step: int = -1                       # engine step that executed it

    @property
    def latency_cycles(self) -> int:
        return self.ticket.finish_est - self.spec.t


@dataclass
class StepRecord:
    """Per-step observability: admitted wave and executed buckets."""

    step: int
    now: int
    wave_size: int
    buckets: List[int] = field(default_factory=list)
    cache_misses: int = 0


class ServeEngine:
    """The request-driven serving loop over one fixed set of harts.

    backend   — a ``PallasBackend`` (programs execute for real; wall
                throughput and cache metrics are measured), or ``None``
                for schedule-only runs (tests, trace analysis — all
                cycle-domain metrics still produced).
    batching  — ``False`` degrades every group to one-request-at-a-time
                execution (the baseline the ≥2x benchmark gate compares
                against). The virtual-time schedule is identical either
                way; only wall-clock execution differs.
    max_batch — compiled batch-shape cap (power of two).
    """

    def __init__(self, templates: Dict[str, KernelTemplate],
                 n_harts: int = 3, backend=None, batching: bool = True,
                 max_batch: int = 8, seed: int = 0, prewarm: bool = True,
                 trace_cache: Optional[TraceCache] = None, obs=None):
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(f"max_batch must be a power of two, "
                             f"got {max_batch}")
        self.templates = dict(templates)
        self.backend = backend
        self.batching = batching
        self.max_batch = max_batch
        self.seed = seed
        self.prewarm = prewarm
        # optional telemetry bundle (repro.kvi.obs.Obs): request flows,
        # step/wall spans and latency metrics; shared with the scheduler
        # so ticket spans land in the same trace
        self.obs = obs
        self.scheduler = HartScheduler(
            n_harts=n_harts,
            trace_cache=trace_cache if trace_cache is not None
            else TraceCache(), obs=obs)
        self.requests: List[ServedRequest] = []
        self.steps: List[StepRecord] = []
        self._warm_rids = 0              # prewarm instance counter

    # ------------------------------------------------------------------
    def _execute_group(self, tpl: KernelTemplate,
                       reqs: List[ServedRequest], step: StepRecord
                       ) -> None:
        """Execute one signature group as bucketed homogeneous batches
        (or one-at-a-time with ``batching=False``)."""
        sizes = bucket_sizes(len(reqs), self.max_batch) \
            if self.batching else [1] * len(reqs)
        pos = 0
        for size in sizes:
            chunk = reqs[pos:pos + size]
            pos += size
            step.buckets.append(size)
            if self.backend is None:
                continue
            programs = [r.template.instantiate(self.seed, r.rid)
                        for r in chunk]
            wl = KviWorkload.homogeneous(
                programs, name=f"serve.{tpl.name}.s{step.step}x{size}")
            res = self.backend.run_workload(wl)
            step.cache_misses += res.meta["compile_cache"]["misses"]

    def prewarm_buckets(self) -> float:
        """Ahead-of-time compile: run one throwaway batch per (template,
        bucket size) so every compiled shape the loop can request is
        already in the backend's kernel cache. Returns the wall seconds
        spent (the serving analogue of the DSE's compile/steady split)."""
        if self.backend is None:
            return 0.0
        t0 = time.perf_counter()
        buckets = [1] if not self.batching else \
            [2 ** i for i in range(self.max_batch.bit_length())
             if 2 ** i <= self.max_batch]
        for name in sorted(self.templates):
            tpl = self.templates[name]
            for size in buckets:
                programs = []
                for _ in range(size):
                    # prewarm rids live far above real ones (2**48 + k):
                    # data contents are irrelevant, only shapes compile
                    programs.append(tpl.instantiate(
                        self.seed, 2 ** 48 + self._warm_rids))
                    self._warm_rids += 1
                self.backend.run_workload(KviWorkload.homogeneous(
                    programs, name=f"prewarm.{tpl.name}.x{size}"))
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def run(self, specs: Sequence[RequestSpec]) -> Dict[str, object]:
        """Serve the whole arrival stream; returns the report dict
        (see :meth:`report`)."""
        t_engine = time.perf_counter()
        obs_on = self.obs is not None and self.obs.enabled
        req_base = len(self.requests)    # flow-id offset across runs
        step_base = len(self.steps)
        specs = sorted(specs, key=lambda s: (s.t,))
        reqs = []
        for rid, s in enumerate(specs):
            tpl = self.templates.get(s.template_key)
            if tpl is None:
                raise KeyError(
                    f"request {rid} wants template {s.template_key!r}; "
                    f"engine serves {sorted(self.templates)}")
            reqs.append(ServedRequest(rid, s, tpl))
        pw_start = self.obs.tracer.wall_us() if obs_on else 0.0
        prewarm_s = self.prewarm_buckets() if self.prewarm else 0.0
        if obs_on and prewarm_s:
            self.obs.tracer.wall_span(("serving", "wall"), "prewarm",
                                      pw_start)

        execute_s = 0.0
        i = 0
        now = 0
        step_no = 0
        sched = self.scheduler
        while i < len(reqs):
            if reqs[i].spec.t > now:
                # machine idle until the next arrival
                now = reqs[i].spec.t
            wave = []
            while i < len(reqs) and reqs[i].spec.t <= now:
                wave.append(reqs[i])
                i += 1
            step = StepRecord(step_no, now, len(wave))
            # continuous admission: earliest-finish-first, arrival order
            for r in wave:
                r.ticket = sched.admit(r.template.program, now=now,
                                       est=r.template.est_cycles)
                r.step = step_no
            # signature batching: one homogeneous batch per template
            groups: Dict[str, List[ServedRequest]] = {}
            for r in wave:
                groups.setdefault(r.template.name, []).append(r)
            t0 = time.perf_counter()
            ex_start = self.obs.tracer.wall_us() if obs_on else 0.0
            for name in sorted(groups):
                self._execute_group(self.templates[name], groups[name],
                                    step)
            execute_s += time.perf_counter() - t0
            if obs_on and self.backend is not None and groups:
                self.obs.tracer.wall_span(
                    ("serving", "wall"), f"execute.step{step_no}",
                    ex_start, args={"wave": len(wave)})
            self.steps.append(step)
            step_no += 1
            if i < len(reqs):
                # next batching window opens when the earliest hart
                # frees; arrivals in between accumulate into the wave
                now = max(now, min(sched.hart_free))
        self.requests.extend(reqs)
        report = self.report(prewarm_s=prewarm_s, execute_s=execute_s,
                             engine_s=time.perf_counter() - t_engine)
        if obs_on:
            self._emit_telemetry(reqs, req_base, step_base, report)
        return report

    def _emit_telemetry(self, reqs: List[ServedRequest], req_base: int,
                        step_base: int, report: Dict[str, object]) -> None:
        """One run's worth of cycle-domain telemetry: per-request flow
        arrows (arrival -> hart admission -> estimated completion),
        batching-window spans, and the latency/throughput metrics. The
        flow events alone reconstruct the report's makespan and latency
        percentiles — ``python -m repro.kvi.obs view`` recomputes them
        and the tests cross-check against this report."""
        tr = self.obs.tracer
        for r in reqs:
            fid = req_base + r.rid
            hart_track = ("scheduler", f"hart{r.ticket.hart}")
            tr.flow_start(("serving", "arrivals"), f"req{fid}",
                          r.spec.t, fid,
                          args={"template": r.template.name,
                                "client": r.spec.client})
            tr.flow_step(hart_track, f"req{fid}", r.ticket.start_est, fid)
            tr.flow_end(hart_track, f"req{fid}", r.ticket.finish_est, fid)
        makespan = report["throughput"]["makespan_cycles"]
        new_steps = self.steps[step_base:]
        for j, s in enumerate(new_steps):
            end = new_steps[j + 1].now if j + 1 < len(new_steps) \
                else max(makespan, s.now)
            tr.span(("serving", "steps"), f"step{s.step}", s.now,
                    max(0, end - s.now), cat="step",
                    args={"wave": s.wave_size,
                          "buckets": list(s.buckets)})

        m = self.obs.metrics
        m.counter("serving.requests").inc(len(reqs))
        m.counter("serving.steps").inc(len(new_steps))
        hist = m.histogram("serving.latency_cycles")
        for r in reqs:
            hist.observe(r.latency_cycles)
        m.gauge("serving.makespan_cycles").set(makespan)
        cc = report.get("compile_cache")
        if cc:
            m.absorb("serving.compile_cache",
                     {k: cc[k] for k in ("hits", "misses", "entries",
                                         "loop_misses")})

    # ------------------------------------------------------------------
    def report(self, prewarm_s: float = 0.0, execute_s: float = 0.0,
               engine_s: float = 0.0) -> Dict[str, object]:
        """The serving metrics dict written into ``BENCH_kvi_serve.json``
        (wall fields are the :data:`SERVE_VOLATILE` set; everything else
        is deterministic under the load seed)."""
        reqs = self.requests
        n = len(reqs)
        makespan = max((r.ticket.finish_est for r in reqs), default=0)
        latencies = [r.latency_cycles for r in reqs]

        # per-hart busy/stall/idle attribution from the solo profiles
        n_harts = self.scheduler.n_harts
        busy = [0] * n_harts
        stall = [0] * n_harts
        occupied = [0] * n_harts
        for r in reqs:
            h = r.ticket.hart
            busy[h] += r.template.profile["busy"]
            stall[h] += r.template.profile["stall"]
            occupied[h] += r.ticket.est_cycles
        harts = []
        for h in range(n_harts):
            idle = makespan - busy[h] - stall[h]
            harts.append({
                "busy": busy[h], "stall": stall[h], "idle": idle,
                "total": makespan,
                "utilization": round(busy[h] / makespan, 4)
                if makespan else 0.0,
                "occupancy": round(occupied[h] / makespan, 4)
                if makespan else 0.0})

        per_template: Dict[str, Dict[str, object]] = {}
        for name in sorted(self.templates):
            sub = [r.latency_cycles for r in reqs
                   if r.template.name == name]
            per_template[name] = {
                "n": len(sub),
                "est_cycles": self.templates[name].est_cycles,
                "latency_cycles": _percentiles(sub)}

        wave_hist: Dict[str, int] = {}
        batch_hist: Dict[str, int] = {}
        loop_misses = 0
        last_miss_step = -1
        for s in self.steps:
            wave_hist[str(s.wave_size)] = \
                wave_hist.get(str(s.wave_size), 0) + 1
            for b in s.buckets:
                batch_hist[str(b)] = batch_hist.get(str(b), 0) + 1
            if s.cache_misses:
                loop_misses += s.cache_misses
                last_miss_step = s.step

        compile_cache = None
        if self.backend is not None:
            stats = self.backend.kernel_cache.stats
            served = stats["hits"] + stats["misses"]
            compile_cache = {
                "hits": stats["hits"], "misses": stats["misses"],
                "entries": stats["entries"],
                "loop_misses": loop_misses,
                "last_miss_step": last_miss_step,
                # the acceptance gate: with prewarming, the serving loop
                # itself never compiles — hit rate 1.0 in steady state
                "steady_hit_rate": 1.0 if loop_misses == 0 else round(
                    1.0 - loop_misses / max(served, 1), 4)}

        throughput = {
            "requests": n,
            "makespan_cycles": makespan,
            "req_per_kcycle": round(1000.0 * n / makespan, 4)
            if makespan else 0.0,
        }
        if self.backend is not None:
            throughput["execute_s"] = round(execute_s, 4)
            throughput["prewarm_s"] = round(prewarm_s, 4)
            throughput["req_per_s"] = round(n / execute_s, 2) \
                if execute_s > 0 else 0.0

        report = {
            "engine": {
                "n_harts": n_harts,
                "batching": self.batching,
                "max_batch": self.max_batch,
                "prewarm": self.prewarm,
                "backend": getattr(self.backend, "name", None),
                "seed": self.seed,
                "templates": {name: self.templates[name].as_dict()
                              for name in sorted(self.templates)},
            },
            "n_steps": len(self.steps),
            "throughput": throughput,
            "latency_cycles": _percentiles(latencies),
            "per_template": per_template,
            "hart_utilization": harts,
            "wave_sizes": wave_hist,
            "batch_sizes": batch_hist,
            "engine_s": round(engine_s, 4),
        }
        if compile_cache is not None:
            report["compile_cache"] = compile_cache
        if n:
            report["clients"] = len({r.spec.client for r in reqs})
        return report
