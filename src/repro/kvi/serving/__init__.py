"""Request-driven serving for KVI programs: load generation, continuous
hart admission, signature batching and warm compiled-kernel reuse.

Quick start::

    from repro.kvi.serving import (ServeEngine, make_templates,
                                   poisson_arrivals, SMOKE_MIX)
    templates = make_templates(SMOKE_MIX, smoke=True, seed=0)
    specs = poisson_arrivals(templates, n_requests=64,
                             mean_interarrival_cycles=40.0, seed=0)
    engine = ServeEngine(templates, n_harts=3, backend=None)
    report = engine.run(specs)          # schedule-only (no jax needed)

Attach a ``PallasBackend`` to execute the batched programs for real and
measure wall throughput plus compile-cache behaviour; run
``python -m repro.kvi.serving --smoke`` for the CLI.
"""
from repro.kvi.serving.engine import (SERVE_VOLATILE, ServedRequest,
                                      ServeEngine, StepRecord,
                                      bucket_sizes, canonical_report)
from repro.kvi.serving.load import (DEFAULT_MIX, SMOKE_MIX, KernelTemplate,
                                    RequestSpec, load_trace, make_templates,
                                    poisson_arrivals, save_trace,
                                    template_key)

__all__ = [
    "DEFAULT_MIX",
    "SMOKE_MIX",
    "SERVE_VOLATILE",
    "KernelTemplate",
    "RequestSpec",
    "ServeEngine",
    "ServedRequest",
    "StepRecord",
    "bucket_sizes",
    "canonical_report",
    "load_trace",
    "make_templates",
    "poisson_arrivals",
    "save_trace",
    "template_key",
]
