"""CLI for the KVI serving engine.

    python -m repro.kvi.serving --smoke
    python -m repro.kvi.serving --requests 200 --interarrival 30 \\
        --harts 3 --max-batch 8 --out serve.json
    python -m repro.kvi.serving --trace arrivals.json --no-backend

``--no-backend`` runs schedule-only (no jax import): all cycle-domain
metrics, no wall-clock execution. ``--save-trace`` persists the generated
Poisson arrivals for replay with ``--trace``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.kvi.serving.engine import ServeEngine, canonical_report
from repro.kvi.serving.load import (DEFAULT_MIX, SMOKE_MIX, load_trace,
                                    make_templates, poisson_arrivals,
                                    save_trace)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kvi.serving",
        description="Serve a mixed KVI kernel request stream.")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels, small stream (CI-sized)")
    ap.add_argument("--requests", type=int, default=None,
                    help="number of Poisson requests (default 64 smoke, "
                         "256 full)")
    ap.add_argument("--interarrival", type=float, default=None,
                    help="mean inter-arrival gap in virtual cycles")
    ap.add_argument("--clients", type=int, default=1000,
                    help="simulated client population")
    ap.add_argument("--harts", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-batching", action="store_true",
                    help="execute one request at a time (baseline)")
    ap.add_argument("--no-prewarm", action="store_true",
                    help="skip ahead-of-time bucket compilation")
    ap.add_argument("--no-backend", action="store_true",
                    help="schedule-only: no jax, no execution")
    ap.add_argument("--trace", default=None,
                    help="replay arrivals from a JSON trace file")
    ap.add_argument("--save-trace", default=None,
                    help="write the generated arrivals to this path")
    ap.add_argument("--out", default=None,
                    help="write the report JSON here (default stdout)")
    ap.add_argument("--canonical", action="store_true",
                    help="emit the wall-clock-scrubbed canonical report")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "run (request flows, hart lanes, step windows)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    mix = SMOKE_MIX if args.smoke else DEFAULT_MIX
    templates = make_templates(mix, smoke=args.smoke, seed=args.seed)

    if args.trace:
        specs = load_trace(args.trace)
    else:
        n = args.requests if args.requests is not None else \
            (64 if args.smoke else 256)
        gap = args.interarrival if args.interarrival is not None else 40.0
        specs = poisson_arrivals(templates, n, gap,
                                 n_clients=args.clients, seed=args.seed)
    if args.save_trace:
        save_trace(specs, args.save_trace)

    obs = None
    if args.trace_out or args.metrics_out:
        from repro.kvi.obs import Obs
        obs = Obs.on()

    backend = None
    if not args.no_backend:
        from repro.kvi.backend import get_backend
        backend = get_backend("pallas", passes=(), obs=obs)

    engine = ServeEngine(templates, n_harts=args.harts, backend=backend,
                         batching=not args.no_batching,
                         max_batch=args.max_batch, seed=args.seed,
                         prewarm=not args.no_prewarm, obs=obs)
    report = engine.run(specs)
    if obs is not None:
        obs.save(trace_path=args.trace_out,
                 metrics_path=args.metrics_out)
        for path in (args.trace_out, args.metrics_out):
            if path:
                print(f"telemetry -> {path}", file=sys.stderr)
    text = canonical_report(report) if args.canonical else \
        json.dumps(report, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        lat = report["latency_cycles"]
        cc = report.get("compile_cache") or {}
        print(f"served {report['throughput']['requests']} requests in "
              f"{report['throughput']['makespan_cycles']} cycles "
              f"(p50={lat['p50']} p99={lat['p99']}; "
              f"cache hits={cc.get('hits', '-')} "
              f"misses={cc.get('misses', '-')}) -> {args.out}")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
