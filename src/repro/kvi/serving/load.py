"""Load generation for the KVI serving engine.

A *template* is one request structure the service offers: a kernel
(conv / fft / matmul) at one sub-word precision, built once, optimized
once through the pass pipeline (so every request arrives with its fusion
plan attached and the backend runs ``passes=()``), and profiled once on
the scheduler's estimator machine. A *request* is a data instance of a
template: same instruction stream, fresh input buffers — which is what
lets the engine batch requests by :func:`structural_signature` into one
compiled kernel and the :class:`~repro.kvi.pallas_backend.KernelCache`
serve steady-state traffic with zero recompiles.

Weights are immediates: the conv filter and (resident) matmul A-matrix
are baked into the instruction stream at template build, exactly the
one-model / N-inputs inference shape — requests randomize only the data
buffers (conv image, fft signal, matmul B). FFT twiddle buffers are
shared constants.

Arrivals come from a Poisson process over *virtual cycles* (thousands of
clients submitting independently aggregate to one Poisson stream) or
from a JSON trace file, both fully deterministic under a seed.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.kvi.ir import KviProgram
from repro.kvi.lowering import TraceCache
from repro.kvi.scheduler import simulated_profile
from repro.kvi.workload import structural_signature

#: buffers never randomized per request: FFT twiddle tables (wre*/wim*)
#: are part of the kernel, not of a request's data
_CONST_PREFIXES = ("wre", "wim")


@dataclass(frozen=True)
class RequestSpec:
    """One trace row: a request of ``kernel`` at ``elem_bytes`` precision
    arriving at virtual cycle ``t`` from ``client``."""

    t: int
    kernel: str
    elem_bytes: int
    client: int = 0

    @property
    def template_key(self) -> str:
        return template_key(self.kernel, self.elem_bytes)


def template_key(kernel: str, elem_bytes: int) -> str:
    """The (kernel, precision) naming convention: ``conv@32`` etc."""
    return f"{kernel}@{8 * elem_bytes}"


@dataclass
class KernelTemplate:
    """One request structure: an optimized prototype program plus its
    solo-run cost profile. ``instantiate`` mints data instances."""

    name: str                    # template_key(kernel, elem_bytes)
    kernel: str                  # "conv" | "fft" | "matmul"
    elem_bytes: int
    program: KviProgram          # optimized; fusion plan in meta
    data_mems: frozenset         # buffer names randomized per request
    profile: Dict[str, int]     # solo cycles/busy/stall/idle (estimator)
    data_limit: int = 64         # request data drawn from [-limit, limit)

    @property
    def est_cycles(self) -> int:
        return self.profile["cycles"]

    @property
    def signature(self) -> tuple:
        return structural_signature(self.program)

    def instantiate(self, seed: int, rid: int) -> KviProgram:
        """A data instance for request ``rid``: fresh inputs drawn from
        ``(seed, rid)`` — deterministic and independent of the order the
        engine materializes requests in. Structure (items, vregs, mems,
        attached fusion plan) is shared with the prototype, so identity-
        and signature-keyed caches downstream stay warm."""
        rng = np.random.default_rng((seed, rid))
        mem_init = {}
        for m in self.program.mems:
            proto = self.program.mem_init[m.id]
            if m.is_output:
                mem_init[m.id] = np.zeros_like(proto)
            elif m.name in self.data_mems:
                mem_init[m.id] = rng.integers(
                    -self.data_limit, self.data_limit, proto.shape
                ).astype(proto.dtype)
            else:
                mem_init[m.id] = proto            # shared constant
        return self.program.replace(
            name=f"{self.name}#{rid}", mem_init=mem_init)

    def as_dict(self) -> Dict[str, object]:
        return {"name": self.name, "kernel": self.kernel,
                "elem_bytes": self.elem_bytes,
                "n_instructions": self.program.n_instructions,
                "profile": dict(self.profile)}


def _build_program(kernel: str, elem_bytes: int, smoke: bool,
                   seed: int) -> KviProgram:
    from repro.kvi.programs import (conv2d_program, fft_program,
                                    matmul_program)
    S, n_fft, m = (8, 32, 8) if smoke else (16, 64, 16)
    # stable per-kernel stream id (str hash is process-randomized)
    kid = {"conv": 1, "fft": 2, "matmul": 3}.get(kernel, 0)
    rng = np.random.default_rng((seed, kid, elem_bytes))
    lim = {1: 8, 2: 64, 4: 128}[elem_bytes]
    if kernel == "conv":
        img = rng.integers(-lim, lim, (S, S)).astype(np.int32)
        filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
        return conv2d_program(img, filt, shift=4, elem_bytes=elem_bytes)
    if kernel == "fft":
        re = rng.integers(-lim, lim, n_fft).astype(np.int32)
        im = rng.integers(-lim, lim, n_fft).astype(np.int32)
        return fft_program(re, im, elem_bytes=elem_bytes)
    if kernel == "matmul":
        A = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        B = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        return matmul_program(A, B, shift=2, resident=True,
                              elem_bytes=elem_bytes)
    raise ValueError(f"unknown kernel {kernel!r}; "
                     f"expected conv / fft / matmul")


def make_templates(mix: Sequence[Tuple[str, int]],
                   smoke: bool = True, seed: int = 0,
                   passes=None,
                   est_config: Optional[KlessydraConfig] = None,
                   trace_cache: Optional[TraceCache] = None,
                   ) -> Dict[str, KernelTemplate]:
    """Build, optimize and profile one template per ``(kernel,
    elem_bytes)`` pair of ``mix``. One :class:`TraceCache` threads
    through profiling so the SPM allocator runs once per template."""
    from repro.kvi.passes import PassPipeline
    pipe = PassPipeline.from_spec(passes)
    cache = trace_cache if trace_cache is not None else TraceCache()
    templates: Dict[str, KernelTemplate] = {}
    for kernel, eb in mix:
        key = template_key(kernel, eb)
        if key in templates:
            raise ValueError(f"duplicate template {key!r} in mix")
        prog = _build_program(kernel, eb, smoke, seed)
        if pipe:
            prog = pipe.run(prog)
        data_mems = frozenset(
            m.name for m in prog.mems
            if not m.is_output and not m.name.startswith(_CONST_PREFIXES))
        profile = simulated_profile(prog, est_config, trace_cache=cache)
        lim = {1: 8, 2: 64, 4: 128}[eb]
        templates[key] = KernelTemplate(key, kernel, eb, prog, data_mems,
                                        profile, data_limit=lim)
    return templates


DEFAULT_MIX: Tuple[Tuple[str, int], ...] = (
    ("conv", 4), ("conv", 1), ("fft", 4), ("matmul", 2))

SMOKE_MIX: Tuple[Tuple[str, int], ...] = (
    ("conv", 4), ("matmul", 2))


def poisson_arrivals(templates: Dict[str, KernelTemplate],
                     n_requests: int,
                     mean_interarrival_cycles: float,
                     n_clients: int = 1000,
                     seed: int = 0,
                     weights: Optional[Dict[str, float]] = None,
                     ) -> List[RequestSpec]:
    """A Poisson request stream over virtual cycles: exponential
    inter-arrival gaps at the aggregate rate (the superposition of
    ``n_clients`` independent client processes), template picked per
    request by ``weights`` (uniform over templates by default)."""
    if n_requests <= 0:
        raise ValueError("n_requests must be > 0")
    if mean_interarrival_cycles <= 0:
        raise ValueError("mean_interarrival_cycles must be > 0")
    names = sorted(templates)
    if weights:
        p = np.asarray([float(weights.get(n, 0.0)) for n in names])
        if p.sum() <= 0:
            raise ValueError("weights select no template")
        p = p / p.sum()
    else:
        p = np.full(len(names), 1.0 / len(names))
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival_cycles, n_requests)
    times = np.floor(np.cumsum(gaps)).astype(np.int64)
    picks = rng.choice(len(names), n_requests, p=p)
    clients = rng.integers(0, n_clients, n_requests)
    specs = []
    for t, k, c in zip(times, picks, clients):
        tpl = templates[names[int(k)]]
        specs.append(RequestSpec(int(t), tpl.kernel, tpl.elem_bytes,
                                 int(c)))
    return specs


def save_trace(specs: Sequence[RequestSpec], path: str) -> None:
    """Persist an arrival trace as JSON (the ``--trace`` file format)."""
    with open(path, "w") as f:
        json.dump({"requests": [
            {"t": s.t, "kernel": s.kernel, "elem_bytes": s.elem_bytes,
             "client": s.client} for s in specs]}, f, indent=2)


def load_trace(path: str) -> List[RequestSpec]:
    """Read an arrival trace written by :func:`save_trace` (requests are
    re-sorted by arrival time — the engine requires monotone arrivals)."""
    with open(path) as f:
        data = json.load(f)
    specs = [RequestSpec(int(r["t"]), str(r["kernel"]),
                         int(r["elem_bytes"]), int(r.get("client", 0)))
             for r in data["requests"]]
    return sorted(specs, key=lambda s: s.t)
