"""Continuous-admission hart scheduler: pack queued programs onto free
harts, then execute the packed workload on any backend.

The slot/free-list policy mirrors ``repro.serving.engine.ServingEngine``
at coprocessor granularity: the scheduler's "slots" are harts, a hart is
*free* when its accumulated estimated cycles is the minimum of all harts,
and admission is continuous — each queued program is dispatched to the
hart that will free up first (earliest-finish-first), in submission
order. There is no head-of-line blocking: a long matmul on one hart does
not delay conv instances landing on the other two.

Estimates come from a solo cycle simulation of each distinct program
(cached by structure), so packing reflects real kernel latencies rather
than instruction counts; the *final* timing of the packed workload — with
true inter-hart contention per scheme — comes from running it through
``CycleSimBackend.run_workload``.

    sched = HartScheduler(n_harts=3)
    for p in programs:
        sched.submit(p)
    result = sched.run(get_backend("cyclesim"))   # dispatch + execute
    # or, to inspect the packing first:
    #   workload = sched.dispatch()               # drains the queue
    #   result = backend.run_workload(workload)
    # or, request-driven (the serving engine's protocol):
    #   ticket = sched.admit(program, now=arrival_cycle)
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.configs.base import KlessydraConfig
from repro.kvi.ir import KviProgram
from repro.kvi.lowering import TraceCache, lower
from repro.kvi.workload import (HartAssignment, KviWorkload, WorkloadEntry,
                                WorkloadResult, structural_signature)

# the estimator's machine model: one representative scheme (heterogeneous
# MIMD — per-hart SPMI — because packing decisions are per-hart)
_EST_CFG = KlessydraConfig("sched_est", M=3, F=1, D=4, spm_kbytes=64)


def simulated_profile(program: KviProgram,
                      cfg: Optional[KlessydraConfig] = None,
                      trace_cache: Optional[TraceCache] = None,
                      ) -> Dict[str, int]:
    """Solo cycle profile of one program on one hart (no contention):
    ``{"cycles", "busy", "stall", "idle"}`` — the per-request cost the
    scheduler packs with and the serving engine attributes to harts.

    The lower is timing-only (no ``mem_init`` copies — simulation never
    reads buffer contents), and a :class:`TraceCache` shares the SPM
    allocation with every other estimator/profiler call on the same
    program object, so admission never repeats the linear scan per
    request wave."""
    from repro.core.simulator import simulate
    cfg = cfg or _EST_CFG
    if trace_cache is not None:
        trace = trace_cache.lower(program, cfg, functional=False)
    else:
        trace = lower(program, cfg, functional=False)
    sim = simulate(cfg, [trace.items])
    h = sim.per_hart[0]
    return {"cycles": sim.cycles, "busy": h.busy_cycles,
            "stall": h.stall_cycles, "idle": h.idle_cycles}


def simulated_cycles(program: KviProgram,
                     cfg: Optional[KlessydraConfig] = None,
                     trace_cache: Optional[TraceCache] = None) -> int:
    """Solo cycle count of one program on one hart (no contention) — the
    scheduler's latency estimate."""
    return simulated_profile(program, cfg, trace_cache)["cycles"]


@dataclass
class Ticket:
    """One queued program and where it ended up."""

    tid: int
    program: KviProgram
    est_cycles: int = 0
    hart: Optional[int] = None           # assigned at dispatch
    start_est: int = 0                   # estimated admission cycle

    @property
    def finish_est(self) -> int:
        """Estimated completion cycle (admission + solo latency)."""
        return self.start_est + self.est_cycles


class HartScheduler:
    """Earliest-finish-first packer over ``n_harts`` hart streams.

    Two admission protocols share the estimator and the ticket log:

      * batch drain — ``submit()`` programs, then ``dispatch()`` packs
        the whole queue onto harts at once (the original protocol).
      * continuous  — ``admit(program, now)`` places one program
        immediately on the hart that frees earliest, keeping persistent
        per-hart clocks (``hart_free``) across calls. This is the
        serving engine's path: requests stream in over virtual time and
        each lands on a hart the moment it is admitted.
    """

    def __init__(self, n_harts: int = 3,
                 estimator: Optional[Callable[[KviProgram], int]] = None,
                 est_config: Optional[KlessydraConfig] = None,
                 trace_cache: Optional[TraceCache] = None, obs=None):
        self.n_harts = n_harts
        self._estimator = estimator
        self._est_cfg = est_config or _EST_CFG
        self.trace_cache = trace_cache
        # optional telemetry bundle (repro.kvi.obs.Obs): ticket spans on
        # per-hart scheduler lanes + admission counters / queue gauge
        self.obs = obs
        self._est_cache: Dict[tuple, int] = {}   # structure -> cycles
        self._tids = itertools.count()
        self.queue: List[Ticket] = []
        self.dispatched: List[Ticket] = []
        # persistent per-hart busy-until clocks for admit(); dispatch()
        # keeps its own fresh heap (batch packing starts from an empty
        # machine, matching the original semantics)
        self.hart_free: List[int] = [0] * n_harts

    # ------------------------------------------------------------------
    def estimate(self, program: KviProgram) -> int:
        """Estimated solo cycles (cached per program structure)."""
        if self._estimator is not None:
            return int(self._estimator(program))
        key = structural_signature(program)
        if key not in self._est_cache:
            self._est_cache[key] = simulated_cycles(
                program, self._est_cfg, trace_cache=self.trace_cache)
        return self._est_cache[key]

    def submit(self, program: KviProgram) -> Ticket:
        """Queue one program; returns its ticket."""
        t = Ticket(next(self._tids), program, self.estimate(program))
        self.queue.append(t)
        if self.obs is not None and self.obs.enabled:
            self.obs.metrics.counter("scheduler.submitted").inc()
            self.obs.metrics.gauge("scheduler.queue_depth").set(
                len(self.queue))
        return t

    def admit(self, program: KviProgram, now: int = 0,
              est: Optional[int] = None) -> Ticket:
        """Continuous admission: place ``program`` immediately on the
        hart that frees earliest, starting no earlier than ``now`` (the
        arrival / engine-step cycle). ``est`` overrides the estimator
        (callers that profiled the structure once pass it to skip the
        per-request signature lookup). Ties break on the lowest hart
        index — deterministic for a fixed submission order."""
        est = self.estimate(program) if est is None else int(est)
        h = min(range(self.n_harts),
                key=lambda i: (self.hart_free[i], i))
        start = max(int(now), self.hart_free[h])
        t = Ticket(next(self._tids), program, est, hart=h, start_est=start)
        self.hart_free[h] = start + est
        self.dispatched.append(t)
        self._record_ticket(t)
        return t

    # ------------------------------------------------------------------
    def dispatch(self, name: str = "scheduled") -> KviWorkload:
        """Drain the queue onto harts (continuous admission): each program
        goes to the hart with the earliest estimated finish time, in
        submission order. Returns the packed workload; per-ticket ``hart``
        and ``start_est`` record the placement."""
        if not self.queue:
            raise ValueError("nothing queued")
        # (accumulated_cycles, seq, hart) min-heap = the free list ordered
        # by when each hart frees up. ``seq`` is a monotonic push counter:
        # under EQUAL finish times the hart that became free earliest (in
        # submission order of the work that freed it) wins — a stable,
        # deterministic tie-break instead of an arbitrary hart-index race.
        # Initially seq == hart index, so an empty machine fills 0,1,2,...
        loads = [(0, h, h) for h in range(self.n_harts)]
        heapq.heapify(loads)
        seq = itertools.count(self.n_harts)
        entries = []
        for t in self.queue:
            load, _, h = heapq.heappop(loads)
            t.hart, t.start_est = h, load
            heapq.heappush(loads, (load + t.est_cycles, next(seq), h))
            entries.append(WorkloadEntry(t.program, HartAssignment(h)))
            self._record_ticket(t)
        self.dispatched.extend(self.queue)
        self.queue = []
        return KviWorkload(name, tuple(entries),
                           meta={"scheduler": "earliest_finish",
                                 "n_harts": self.n_harts})

    def _record_ticket(self, t: Ticket) -> None:
        """Telemetry for one placed ticket: an estimated-occupancy span
        on the ticket's hart lane plus admission counters."""
        if self.obs is None or not self.obs.enabled:
            return
        self.obs.tracer.span(
            ("scheduler", f"hart{t.hart}"),
            getattr(t.program, "name", None) or f"ticket{t.tid}",
            t.start_est, t.est_cycles, cat="ticket",
            args={"tid": t.tid})
        self.obs.metrics.counter("scheduler.admitted").inc()
        self.obs.metrics.histogram("scheduler.est_cycles").observe(
            t.est_cycles)

    def run(self, backend, name: str = "scheduled") -> WorkloadResult:
        """Dispatch whatever is queued and execute it on ``backend``."""
        return backend.run_workload(self.dispatch(name))

    # ------------------------------------------------------------------
    @property
    def hart_loads(self) -> List[int]:
        """Estimated accumulated cycles per hart over all dispatched work."""
        loads = [0] * self.n_harts
        for t in self.dispatched:
            if t.hart is not None:
                loads[t.hart] += t.est_cycles
        return loads
