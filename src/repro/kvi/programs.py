"""The paper's three computation kernels, authored ONCE as KVI programs.

Each builder returns a backend-neutral :class:`~repro.kvi.ir.KviProgram`;
run it on any registered backend::

    prog = conv2d_program(img, filt, shift=4)
    get_backend("oracle").run(prog)      # numpy ground truth
    get_backend("cyclesim").run(prog)    # values + per-scheme cycles
    get_backend("pallas").run(prog)      # fused Pallas kernels

Instruction traces (including the scalar-bookkeeping counts that feed the
cycle model) match the legacy ``repro.core.programs`` builders item for
item — the Table 2/3 reproductions are unchanged by the IR port.

Kernels (paper §PERFORMANCE RESULTS): 2D convolution (3x3..11x11 filters,
zero padding, fixed-point post-scaling), radix-2 DIF FFT-256 (Q15
twiddles, contiguous-half butterflies, final bit-reversal), MatMul 64x64
(row-vector accumulation resident / kdotp-streamed). 32-bit fixed point
throughout, as in the paper.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.kvi.backend import BackendResult
from repro.kvi.ir import KviProgram, KviProgramBuilder, np_dtype

# ---------------------------------------------------------------------------
# 2D convolution, FxF filter, zero padding, fixed-point post-scale
# ---------------------------------------------------------------------------


def conv2d_program(img: np.ndarray, filt: np.ndarray,
                   shift: int = 0, elem_bytes: int = 4) -> KviProgram:
    """``elem_bytes`` selects the sub-word precision (4/2/1 for
    32/16/8-bit fixed point); narrow elements pack more SIMD lanes per
    SPM bank on hardware with sub-word support (config.subword_bits)."""
    S = img.shape[0]
    F = filt.shape[0]
    pad = F // 2
    Sp = S + 2 * pad
    padded = np.zeros((Sp, Sp), np_dtype(elem_bytes))
    padded[pad:pad + S, pad:pad + S] = img
    b = KviProgramBuilder(f"conv{S}x{S}_f{F}")
    hin = b.mem_in("img", padded, elem_bytes=elem_bytes)
    rin = b.vreg("in", Sp * Sp, elem_bytes=elem_bytes)
    acc = b.vreg("acc", S, elem_bytes=elem_bytes)
    tmp = b.vreg("tmp", S, elem_bytes=elem_bytes)
    b.scalar(40)                                  # kernel prologue
    b.kmemld(rin, hin)
    for i in range(S):
        b.scalar(6)                               # row loop bookkeeping
        first = True
        for fr in range(F):
            for fc in range(F):
                w = int(filt[fr, fc])
                src = rin.view((i + fr) * Sp + fc, S)
                b.scalar(3)
                if first:
                    b.ksvmulsc(acc, src, scalar=w)
                    first = False
                else:
                    b.ksvmulsc(tmp, src, scalar=w)
                    b.kaddv(acc, acc, tmp)
        if shift:
            b.ksrav(acc, acc, scalar=shift)
        hrow = b.mem_out(f"row{i}", S, elem_bytes=elem_bytes)
        b.kmemstr(hrow, acc)
    return b.build(alg_ops=2 * S * S * F * F, kind="conv2d", S=S, F=F,
                   shift=shift, elem_bytes=elem_bytes)


def conv2d_result(res: BackendResult, S: Optional[int] = None) -> np.ndarray:
    rows = sorted(((k, v) for k, v in res.outputs.items()
                   if k.startswith("row")),
                  key=lambda kv: int(kv[0][3:]))
    return np.stack([v for _, v in rows], axis=0)


# ---------------------------------------------------------------------------
# MatMul. Two code paths, chosen by SPM capacity exactly as a programmer
# would (paper: a 64x64 int32 B [16 KiB] does NOT fit the 3x4 KiB
# scratchpads and must be streamed):
#   * resident: B held in SPM, row-vector accumulation (ksvmulsc + kaddv)
#   * streamed: A rows resident, B^T columns streamed per output element,
#     kdotp per element (vector MAC through the multiplier + adder tree)
# ---------------------------------------------------------------------------


def matmul_program(A: np.ndarray, B: np.ndarray, shift: int = 0,
                   resident: Optional[bool] = None,
                   spm_bytes: Optional[int] = None,
                   elem_bytes: int = 4) -> KviProgram:
    n, m = A.shape
    _, p = B.shape
    dt = np_dtype(elem_bytes)
    if resident is None:
        cap = spm_bytes if spm_bytes is not None else 4 * 4 * 1024
        resident = (m * p + 2 * p + n) * elem_bytes <= cap
    b = KviProgramBuilder(f"matmul{n}x{p}")

    if resident:
        hB = b.mem_in("B", B.astype(dt), elem_bytes=elem_bytes)
        rB = b.vreg("B", m * p, elem_bytes=elem_bytes)
        acc = b.vreg("acc", p, elem_bytes=elem_bytes)
        tmp = b.vreg("tmp", p, elem_bytes=elem_bytes)
        b.scalar(40)                              # kernel prologue
        b.kmemld(rB, hB)
        for i in range(n):
            b.scalar(3)                           # row loop bookkeeping
            for k in range(m):
                b.scalar(2)                       # a-scalar load + addr bump
                aik = int(A[i, k])
                row = rB.view(p * k, p)
                if k == 0:
                    b.ksvmulsc(acc, row, scalar=aik)
                else:
                    b.ksvmulsc(tmp, row, scalar=aik)
                    b.kaddv(acc, acc, tmp)
            if shift:
                b.ksrav(acc, acc, scalar=shift)
            hrow = b.mem_out(f"row{i}", p, elem_bytes=elem_bytes)
            b.kmemstr(hrow, acc)
        return b.build(alg_ops=2 * n * m * p, kind="matmul", n=n, p=p,
                       shift=shift, resident=True, elem_bytes=elem_bytes)

    # streamed path: per output element, kdotp(A_row, B_col)
    Bt = np.ascontiguousarray(B.astype(dt).T)
    arow = b.vreg("arow", m, elem_bytes=elem_bytes)
    bcol = b.vreg("bcol", m, elem_bytes=elem_bytes)
    acc = b.vreg("acc", p, elem_bytes=elem_bytes)
    b.scalar(40)                                  # kernel prologue
    for i in range(n):
        b.scalar(3)
        hA = b.mem_in(f"arow{i}", A[i].astype(dt), elem_bytes=elem_bytes)
        b.kmemld(arow, hA)
        for j in range(p):
            b.scalar(3)                           # col pointer, loop, store rd
            hcol = b.mem_in(f"bcol{i}_{j}", Bt[j], elem_bytes=elem_bytes)
            b.kmemld(bcol, hcol)
            if shift:
                b.kdotpps(acc[j], arow, bcol, shift)
            else:
                b.kdotp(acc[j], arow, bcol)
            # register-file result written to acc[j]: one scalar store
            b.scalar(1)
        hrow = b.mem_out(f"row{i}", p, elem_bytes=elem_bytes)
        b.kmemstr(hrow, acc)
    return b.build(alg_ops=2 * n * m * p, kind="matmul", n=n, p=p,
                   shift=shift, resident=False, elem_bytes=elem_bytes)


def matmul_result(res: BackendResult, n: Optional[int] = None) -> np.ndarray:
    return conv2d_result(res)


# ---------------------------------------------------------------------------
# FFT-256: radix-2 DIF, contiguous-half butterflies, Q15 twiddles,
# final bit-reversal (element copies — deliberately DLP-unfriendly,
# matching the paper's observation that FFT gains come from TLP).
# ---------------------------------------------------------------------------

Q = 15

# twiddle Q-format per element width: Q15 products fit int32; narrower
# fixed-point uses a correspondingly narrower fraction (Q7/Q3) so the
# sub-word sweep's programs stay executable end to end
_Q_BY_WIDTH = {4: 15, 2: 7, 1: 3}


def _twiddles(m: int, q: int = Q, dtype=np.int32) -> tuple:
    k = np.arange(m // 2)
    w = np.exp(-2j * np.pi * k / m)
    return ((w.real * (1 << q)).astype(dtype),
            (w.imag * (1 << q)).astype(dtype))


def fft_program(x_re: np.ndarray, x_im: np.ndarray,
                elem_bytes: int = 4) -> KviProgram:
    n = len(x_re)
    assert n & (n - 1) == 0
    dt = np_dtype(elem_bytes)
    q = _Q_BY_WIDTH[elem_bytes]
    b = KviProgramBuilder(f"fft{n}")
    hre = b.mem_in("x_re", x_re.astype(dt), elem_bytes=elem_bytes)
    him = b.mem_in("x_im", x_im.astype(dt), elem_bytes=elem_bytes)

    def vreg(name, length):
        return b.vreg(name, length, elem_bytes=elem_bytes)

    are = vreg("re", n)
    aim = vreg("im", n)
    t1 = vreg("t1", n // 2)
    t2 = vreg("t2", n // 2)
    dre = vreg("dre", n // 2)
    dim = vreg("dim", n // 2)
    # per-size twiddle vectors, loaded once
    tw = {}
    m = n
    while m >= 2:
        wre, wim = _twiddles(m, q, dt)
        rr = vreg(f"wre{m}", m // 2)
        ri = vreg(f"wim{m}", m // 2)
        b.kmemld(rr, b.mem_in(f"wre{m}", wre, elem_bytes=elem_bytes))
        b.kmemld(ri, b.mem_in(f"wim{m}", wim, elem_bytes=elem_bytes))
        tw[m] = (rr, ri)
        m //= 2
    b.scalar(40)                                  # kernel prologue
    b.kmemld(are, hre)
    b.kmemld(aim, him)

    def butterfly(base: int, m: int):
        """DIF butterfly on the contiguous block [base, base+m)."""
        h = m // 2
        lo_re, hi_re = are.view(base, h), are.view(base + h, h)
        lo_im, hi_im = aim.view(base, h), aim.view(base + h, h)
        wre, wim = tw[m]
        th1, th2 = t1[:h], t2[:h]
        vdre, vdim = dre[:h], dim[:h]
        b.scalar(6)
        # d = lo - hi (complex), top = lo + hi
        b.ksubv(vdre, lo_re, hi_re)
        b.ksubv(vdim, lo_im, hi_im)
        b.kaddv(lo_re, lo_re, hi_re)
        b.kaddv(lo_im, lo_im, hi_im)
        # hi = d * w  (Q-format fixed point)
        b.kvmul(th1, vdre, wre)
        b.ksrav(th1, th1, scalar=q)
        b.kvmul(th2, vdim, wim)
        b.ksrav(th2, th2, scalar=q)
        b.ksubv(hi_re, th1, th2)
        b.kvmul(th1, vdre, wim)
        b.ksrav(th1, th1, scalar=q)
        b.kvmul(th2, vdim, wre)
        b.ksrav(th2, th2, scalar=q)
        b.kaddv(hi_im, th1, th2)

    m = n
    while m >= 2:
        for base in range(0, n, m):
            butterfly(base, m)
        m //= 2

    # bit-reversal reorder via element copies (vector length 1)
    nb = int(np.log2(n))
    out_re = vreg("out_re", n)
    out_im = vreg("out_im", n)
    for i in range(n):
        j = int(f"{i:0{nb}b}"[::-1], 2)
        b.scalar(2)
        b.kvcp(out_re[j], are[i])
        b.kvcp(out_im[j], aim[i])
    ore = b.mem_out("out_re", n, elem_bytes=elem_bytes)
    oim = b.mem_out("out_im", n, elem_bytes=elem_bytes)
    b.kmemstr(ore, out_re)
    b.kmemstr(oim, out_im)
    return b.build(alg_ops=10 * (n // 2) * nb, kind="fft", n=n,
                   elem_bytes=elem_bytes)


def fft_result(res: BackendResult) -> np.ndarray:
    return (res.outputs["out_re"].astype(np.float64) +
            1j * res.outputs["out_im"].astype(np.float64))


# ---------------------------------------------------------------------------
# Pipeline stress kernel: the shape of naively-generated code — staged
# element-wise chains stitched with whole-register kvcp moves (fusion
# breakers) and speculative products nothing consumes (dead code). The
# optimizing pass pipeline collapses it to one fused chain; used by
# benchmarks/bench_kvi_passes.py and the pass tests.
# ---------------------------------------------------------------------------


def pipeline_demo_program(x: np.ndarray, stages: int = 4) -> KviProgram:
    """``stages`` rounds of ``t = relu(3 * (v + v)); v = copy(t)`` plus a
    dead ``t * t`` per round. Unoptimized: every ``kvcp`` cuts the
    element-wise chain (one extra fused kernel launch per stage on the
    Pallas backend, an SPM copy on the hardware model) and the dead
    products burn MFU cycles. Optimized: one fused region, no copies, no
    dead work — bit-identical outputs."""
    n = int(x.size)
    b = KviProgramBuilder(f"pipeline_demo{n}x{stages}")
    hx = b.mem_in("x", x.astype(np.int32))
    v = b.vreg("v0", n)
    b.scalar(10)                                  # kernel prologue
    b.kmemld(v, hx)
    for s in range(stages):
        b.scalar(4)                               # stage bookkeeping
        t = b.vreg(f"t{s}", n)
        b.kaddv(t, v, v)
        b.ksvmulsc(t, t, scalar=3)
        b.krelu(t, t)
        dead = b.vreg(f"dead{s}", n)
        b.kvmul(dead, t, t)                       # never observed
        nv = b.vreg(f"v{s + 1}", n)
        b.kvcp(nv, t)                             # full-register move
        v = nv
    hy = b.mem_out("y", n)
    b.kmemstr(hy, v)
    return b.build(alg_ops=3 * n * stages, kind="pipeline_demo",
                   n=n, stages=stages)


def pipeline_demo_oracle(x: np.ndarray, stages: int = 4) -> np.ndarray:
    v = x.astype(np.int64)
    for _ in range(stages):
        v = np.maximum((v + v) * 3, 0)
    return v.astype(np.int32)
