"""CycleSimBackend — functional values + cycle timing for the paper's
three coprocessor schemes (repro.core.simulator).

The unit of execution is a :class:`~repro.kvi.workload.KviWorkload`:
entries lower to per-hart Instr/Scalar traces (entries pinned to the same
hart run back-to-back in entry order), so the paper's composite protocol —
conv on hart 0, FFT on hart 1, matmul on hart 2 — runs natively through
the IR. ``run_workload()`` returns both:

  * per-entry outputs — bit-identical to the oracle backend (same Mfu
                        execution of the same lowered trace), and
  * timing           — scheme name -> SimResult for shared (M=1,F=1),
                       symmetric MIMD (M=3,F=3) and heterogeneous MIMD
                       (M=3,F=1), for the WHOLE workload with inter-hart
                       contention.

The single-program ``run()`` keeps the paper's homogeneous protocol: the
program is replicated on all harts (``replicate_harts=True``).

Paper invariant (validated in tests, homogeneous AND composite):
    sym-MIMD cycles <= het-MIMD cycles <= shared cycles.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.configs.base import KlessydraConfig
from repro.core.simulator import (SimRecorder, SimResult, _merge_intervals,
                                  simulate)
from repro.kvi.backend import (BackendBase, BackendResult, register_backend)
from repro.kvi.ir import KviProgram
from repro.kvi.lowering import TraceCache, lower
from repro.kvi.workload import (KviWorkload, WorkloadResult,
                                dedup_entry_outputs)

#: Version token of the cycle-accurate timing semantics (lowering cost
#: annotations + :func:`repro.core.simulator.simulate` event model),
#: part of every persistent sweep cache key
#: (:mod:`repro.kvi.dse.pointcache`). Bump it whenever a change alters
#: simulated cycles, utilization or busy/stall accounting for an
#: unchanged program — cached sweep records keyed to the old token then
#: miss instead of serving stale timings. Explicit by design (not a
#: source hash): refactors that provably preserve timing keep caches
#: warm.
TIMING_VERSION = 1


def _subtract(intervals: List[Tuple[int, int]],
              cover: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Pieces of sorted merged half-open ``intervals`` not overlapped by
    sorted merged ``cover`` — interval-list counterpart of the
    simulator's ``_length_outside`` (used so the emitted stall/idle spans
    sum to exactly the ``HartStats`` breakdown)."""
    out: List[Tuple[int, int]] = []
    ci = 0
    for s, e in intervals:
        cur = s
        while cur < e:
            while ci < len(cover) and cover[ci][1] <= cur:
                ci += 1
            if ci == len(cover) or cover[ci][0] >= e:
                out.append((cur, e))
                break
            cs, ce = cover[ci]
            if cs > cur:
                out.append((cur, cs))
            cur = max(cur, min(ce, e))
    return out


def emit_sim_trace(obs, scheme: str, rec: SimRecorder,
                   res: SimResult) -> None:
    """Render one scheme's :class:`SimRecorder` capture onto the obs
    bundle: per-hart instruction/fused/scalar occupancy spans, stall
    spans (issue waits minus the hart's own in-flight work, matching the
    ``HartStats`` convention), explicit idle spans, FU-hold lanes for
    contended resource instances, and cycle metrics.

    Emitted invariant (pinned by the trace-integrity tests): per hart,
    the stall spans sum to ``stall_cycles``, the idle spans sum to
    ``idle_cycles``, and busy/stall/idle tile ``[0, cycles)``."""
    tr = obs.tracer
    m = obs.metrics
    proc = f"cyclesim:{scheme}"
    H = len(res.per_hart)
    total = res.cycles
    hist = m.histogram(f"cyclesim.{scheme}.instr_cycles")

    # exact per-hart activity cover — scalar blocks decompose into their
    # owned 1-cycle issue slots, mirroring the simulator's accounting
    act: List[List[Tuple[int, int]]] = [[] for _ in range(H)]
    for h, op, engine, s, e, chained in rec.instrs:
        act[h].append((s, e))
        tr.span((proc, f"hart{h}"), op, s, e - s,
                cat="fused" if chained else "instr",
                args={"engine": engine})
        hist.observe(e - s)
    for h, s, e, count in rec.scalars:
        act[h].extend((s + k * H, s + k * H + 1) for k in range(count))
        tr.span((proc, f"hart{h}"), f"scalar x{count}", s, e - s,
                cat="scalar", args={"count": count})

    covers = [_merge_intervals(iv) for iv in act]
    stall_cover: List[List[Tuple[int, int]]] = [[] for _ in range(H)]
    for h, op, s, e in rec.waits:
        for ps, pe in _subtract([(s, e)], covers[h]):
            tr.span((proc, f"hart{h}"), f"wait:{op}", ps, pe - ps,
                    cat="stall")
            stall_cover[h].append((ps, pe))
    for h in range(H):
        occupied = _merge_intervals(covers[h] + stall_cover[h])
        for s, e in _subtract([(0, total)], occupied):
            tr.span((proc, f"hart{h}"), "idle", s, e - s, cat="idle")

    # FU-hold lanes: which resource instance each op pinned, and when —
    # het-MIMD's per-internal-unit contention becomes visible here
    for key, s, e in rec.holds:
        lane = "fu:" + "-".join(str(p) for p in key)
        tr.span((proc, lane), lane[3:], s, e - s, cat="hold")

    st = res.per_hart
    m.counter(f"cyclesim.{scheme}.instructions").inc(
        sum(h.instructions for h in st))
    m.counter(f"cyclesim.{scheme}.vector_ops").inc(
        sum(h.vector_ops for h in st))
    m.counter(f"cyclesim.{scheme}.lsu_ops").inc(
        sum(h.lsu_ops for h in st))
    m.counter(f"cyclesim.{scheme}.stall_cycles").inc(
        sum(h.stall_cycles for h in st))
    m.gauge(f"cyclesim.{scheme}.cycles").set(total)


def default_schemes(D: int = 4, spm_kbytes: int = 64,
                    ) -> Dict[str, KlessydraConfig]:
    """The paper's three coprocessor schemes at one DLP width.

    Scheme construction lives on the design-space subsystem
    (:func:`repro.kvi.dse.space.scheme_config`) — this is the
    D-parameterized slice of that space the single-config callers use."""
    from repro.kvi.dse.space import SCHEMES, scheme_config
    return {s: scheme_config(s, D=D, spm_kbytes=spm_kbytes)
            for s in SCHEMES}


@register_backend("cyclesim")
class CycleSimBackend(BackendBase):
    """Values + per-scheme cycle counts from the event-driven simulator."""

    def __init__(self,
                 schemes: Optional[Dict[str, KlessydraConfig]] = None,
                 replicate_harts: bool = True,
                 passes=None, chaining: bool = False,
                 trace_cache: Optional[TraceCache] = None,
                 verify: bool = False, obs=None):
        self.schemes = schemes or default_schemes()
        self.replicate_harts = replicate_harts
        self.passes = passes
        self.verify = verify
        # optional telemetry bundle (repro.kvi.obs.Obs): when enabled,
        # every simulate() call records per-event timelines and emits
        # them as per-scheme Perfetto tracks + cycle metrics
        self.obs = obs
        # FU chaining: ops inside a planned FusedRegion (after the head)
        # skip their startup latency — the paper's back-to-back SPM-
        # resident op streams. Off by default so the Table 2/3 numbers
        # stay the legacy ones; needs the fuse_regions pass to plan the
        # regions (no effect with passes=()).
        self.chaining = chaining
        # shared LoweredTrace cache: callers running one program set
        # through several workloads (the DSE sweep's preflight +
        # homogeneous + composite protocols) pass a TraceCache so the
        # SPM allocator runs once per (program, config), not per run
        self.trace_cache = trace_cache

    def run(self, program: KviProgram) -> BackendResult:
        """Single-program protocol: replicate on all harts (the paper's
        homogeneous measurement) unless ``replicate_harts=False``. With
        schemes of unequal hart counts the SMALLEST count is replicated,
        so every scheme times the same workload (the paper's schemes all
        have 3 harts, where this is exactly the legacy per-scheme
        replication)."""
        if self.replicate_harts:
            n = min(cfg.harts for cfg in self.schemes.values())
            wl = KviWorkload.replicate(program, n)
        else:
            wl = KviWorkload.single(program)
        return self.run_workload(wl).entry_result(0)

    def run_workload(self, workload: KviWorkload,
                     functional: bool = True,
                     verify: Optional[bool] = None) -> WorkloadResult:
        """Timing for the whole workload per scheme, plus (with
        ``functional=True``) per-entry outputs. Timing-only callers (the
        Table-2 sweeps) pass ``functional=False`` to skip the Mfu replay."""
        workload = self.optimize_workload(workload, verify=verify)
        timing: Dict[str, SimResult] = {}
        entry_outputs = None if functional else \
            [{} for _ in workload.entries]
        lower_fn = self.trace_cache.lower if self.trace_cache is not None \
            else lower
        for scheme, cfg in self.schemes.items():
            # lower each distinct program once per scheme (entries often
            # share program objects, e.g. the replicated protocol);
            # timing-only runs skip the mem_init buffer copies, and a
            # TraceCache shares the whole trace across run protocols
            traces = {}
            for e in workload.entries:
                if id(e.program) not in traces:
                    traces[id(e.program)] = lower_fn(
                        e.program, cfg, chaining=self.chaining,
                        functional=functional)
            if entry_outputs is None:
                # functional values: same trace + Mfu path as the oracle
                # (shared dedup/copy semantics in dedup_entry_outputs),
                # so Oracle == CycleSim bit-for-bit by construction
                entry_outputs = dedup_entry_outputs(
                    workload.entries,
                    lambda p, traces=traces: traces[id(p)].execute())
            per_hart = workload.assign_harts(cfg.harts)
            progs = [
                [it for i in idxs
                 for it in traces[id(workload.entries[i].program)].items]
                for idxs in per_hart]
            if self.obs is not None and self.obs.enabled:
                rec = SimRecorder()
                timing[scheme] = simulate(cfg, progs, recorder=rec)
                emit_sim_trace(self.obs, scheme, rec, timing[scheme])
            else:
                timing[scheme] = simulate(cfg, progs)
        results = tuple(BackendResult(self.name, out)
                        for out in entry_outputs)
        return WorkloadResult(self.name, workload, results, timing)
