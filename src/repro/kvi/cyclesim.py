"""CycleSimBackend — functional values + cycle timing for the paper's
three coprocessor schemes (repro.core.simulator).

One ``run()`` returns both:
  * outputs  — bit-identical to the oracle backend (same Mfu execution of
               the same lowered trace), and
  * timing   — scheme name -> SimResult for shared (M=1,F=1),
               symmetric MIMD (M=3,F=3) and heterogeneous MIMD (M=3,F=1),
               each with the program replicated on all harts (the paper's
               homogeneous-workload protocol).

Paper invariant (validated in tests):
    sym-MIMD cycles <= het-MIMD cycles <= shared cycles.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import KlessydraConfig
from repro.core.simulator import SimResult, simulate
from repro.kvi.backend import BackendResult, register_backend
from repro.kvi.ir import KviProgram
from repro.kvi.lowering import lower


def default_schemes(D: int = 4, spm_kbytes: int = 64,
                    ) -> Dict[str, KlessydraConfig]:
    """The paper's three coprocessor schemes at one DLP width."""
    return {
        "shared": KlessydraConfig("shared", M=1, F=1, D=D,
                                  spm_kbytes=spm_kbytes),
        "sym_mimd": KlessydraConfig("sym_mimd", M=3, F=3, D=D,
                                    spm_kbytes=spm_kbytes),
        "het_mimd": KlessydraConfig("het_mimd", M=3, F=1, D=D,
                                    spm_kbytes=spm_kbytes),
    }


@register_backend("cyclesim")
class CycleSimBackend:
    """Values + per-scheme cycle counts from the event-driven simulator."""

    def __init__(self,
                 schemes: Optional[Dict[str, KlessydraConfig]] = None,
                 replicate_harts: bool = True):
        self.schemes = schemes or default_schemes()
        self.replicate_harts = replicate_harts

    def run(self, program: KviProgram) -> BackendResult:
        timing: Dict[str, SimResult] = {}
        outputs = None
        for scheme, cfg in self.schemes.items():
            trace = lower(program, cfg)
            if outputs is None:
                # functional values: same trace + Mfu path as the oracle,
                # so Oracle == CycleSim bit-for-bit by construction
                outputs = trace.execute()
            n = cfg.harts if self.replicate_harts else 1
            timing[scheme] = simulate(cfg, [trace.items] * n)
        return BackendResult(self.name, outputs or {}, timing)
