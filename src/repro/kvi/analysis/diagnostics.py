"""Typed diagnostics for the KVI static-analysis layer.

Every check in :mod:`repro.kvi.analysis` reports through a
:class:`Diagnostic`: a stable code (``KVI1xx`` structural, ``KVI2xx``
hazard, ``KVI3xx`` resource), a severity, a human message and the
instruction/operand provenance needed to act on it. A
:class:`DiagnosticReport` is the ordered collection one analysis run
produced, renderable as text or JSON and gateable by severity
(``raise_if`` / the CLI's ``--fail-on``).

Codes are API: tests, the pass-pipeline attribution and external
frontends key on them, so a code's meaning never changes — retired
checks retire their code rather than recycling it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum
from typing import Dict, Iterable, Iterator, List, Optional, Tuple


class Severity(IntEnum):
    """Ordered so gates can compare (``ERROR > WARNING > INFO``)."""

    INFO = 10
    WARNING = 20
    ERROR = 30

    def __str__(self) -> str:          # "error", not "Severity.ERROR"
        return self.name.lower()


#: the stable code table — code -> (default severity, one-line meaning).
#: Rendered into the README's diagnostic table; keep the two in sync.
CODES: Dict[str, Tuple[Severity, str]] = {
    # structural (KVI1xx)
    "KVI100": (Severity.ERROR, "required operand missing"),
    "KVI101": (Severity.ERROR, "unknown or unclassified opcode"),
    "KVI102": (Severity.ERROR, "degenerate length (instruction, vreg or "
                               "scalar block <= 0)"),
    "KVI103": (Severity.ERROR, "operand references an undeclared vreg or "
                               "memory buffer"),
    "KVI104": (Severity.ERROR, "operand in the wrong space for its "
                               "position (vreg where mem expected or "
                               "vice versa)"),
    "KVI105": (Severity.ERROR, "operand window outside its vreg "
                               "(offset/extent vs. declared length)"),
    "KVI106": (Severity.ERROR, "elem_bytes disagreement between an "
                               "instruction and its operands"),
    "KVI107": (Severity.ERROR, "memory transfer extent inconsistent with "
                               "the buffer's declared length"),
    "KVI108": (Severity.ERROR, "mem_init shape/dtype inconsistent with "
                               "the MemRef declaration"),
    "KVI109": (Severity.WARNING, "vreg elements read before any write "
                                 "(defined zeros, almost always a bug)"),
    "KVI110": (Severity.ERROR, "output buffer never written by any "
                               "kmemstr"),
    "KVI111": (Severity.ERROR, "duplicate vreg or memory buffer name"),
    "KVI112": (Severity.ERROR, "vreg/mem id disagrees with its position "
                               "(id-indexed lookups would alias)"),
    "KVI113": (Severity.WARNING, "nonzero offset on a memory operand "
                                 "(the MFU transfers whole buffers; "
                                 "the offset is silently ignored)"),
    "KVI114": (Severity.ERROR, "invalid elem_bytes (must be 1/2/4)"),
    # hazard (KVI2xx)
    "KVI201": (Severity.ERROR, "fusion region welds a non-element-wise "
                               "item (mem/reduction/kvcp)"),
    "KVI202": (Severity.ERROR, "fusion region mixes vector lengths or "
                               "element widths"),
    "KVI203": (Severity.ERROR, "fusion region violates a window hazard "
                               "(stale read or overlapping write-back)"),
    "KVI204": (Severity.ERROR, "fusion plan item indices invalid "
                               "(out of range, unordered or duplicated)"),
    "KVI210": (Severity.ERROR, "cross-hart write/write race on one "
                               "logical memory buffer (shared scheme)"),
    "KVI211": (Severity.WARNING, "cross-hart read/write sharing of one "
                                 "logical memory buffer"),
    # resource (KVI3xx)
    "KVI301": (Severity.ERROR, "static SPM pressure exceeds capacity "
                               "(predicts SpmOverflowError)"),
    "KVI302": (Severity.ERROR, "workload entry pinned beyond the "
                               "machine's hart count"),
    "KVI303": (Severity.ERROR, "fusion region exceeds its plan's "
                               "slot-file bounds"),
}


@dataclass(frozen=True)
class Diagnostic:
    """One finding: stable code + severity + provenance.

    ``item`` is the index into ``program.items`` (None for program- or
    workload-level findings); ``subject`` is a stable name (vreg, buffer
    or region) used as the identity key for pass-to-pass attribution —
    item indices shift as passes delete instructions, names do not.
    """

    code: str
    message: str
    program: str
    severity: Optional[Severity] = None
    item: Optional[int] = None
    op: Optional[str] = None
    subject: Optional[str] = None

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")
        if self.severity is None:
            object.__setattr__(self, "severity", CODES[self.code][0])

    @property
    def key(self) -> Tuple[str, str, Optional[str]]:
        """Pass-stable identity: (code, program, subject)."""
        return (self.code, self.program, self.subject)

    def render(self) -> str:
        where = self.program
        if self.item is not None:
            where += f" @item {self.item}"
        if self.op:
            where += f" ({self.op})"
        return f"{self.code} {self.severity}: [{where}] {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return {"code": self.code, "severity": str(self.severity),
                "program": self.program, "item": self.item,
                "op": self.op, "subject": self.subject,
                "message": self.message}


@dataclass
class DiagnosticReport:
    """The ordered findings of one analysis run."""

    diagnostics: List[Diagnostic] = field(default_factory=list)

    def add(self, code: str, message: str, program: str, *,
            item: Optional[int] = None, op: Optional[str] = None,
            subject: Optional[str] = None,
            severity: Optional[Severity] = None) -> Diagnostic:
        d = Diagnostic(code, message, program, severity, item, op, subject)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "DiagnosticReport") -> "DiagnosticReport":
        self.diagnostics.extend(other.diagnostics)
        return self

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __bool__(self) -> bool:
        return bool(self.diagnostics)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity >= Severity.ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == Severity.WARNING]

    @property
    def ok(self) -> bool:
        """No error-severity findings (warnings allowed)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """No findings at all."""
        return not self.diagnostics

    @property
    def codes(self) -> List[str]:
        return [d.code for d in self.diagnostics]

    def by_code(self, code: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def keys(self) -> set:
        return {d.key for d in self.diagnostics}

    def at_least(self, severity: Severity) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity >= severity]

    def raise_if(self, severity: Severity = Severity.ERROR) -> None:
        """Raise :class:`KviVerificationError` when any finding is at or
        above ``severity``."""
        hits = self.at_least(severity)
        if hits:
            raise KviVerificationError(DiagnosticReport(hits))

    def render_text(self) -> str:
        if not self.diagnostics:
            return "clean: no diagnostics"
        return "\n".join(d.render() for d in self.diagnostics)

    def as_dicts(self) -> List[Dict[str, object]]:
        return [d.as_dict() for d in self.diagnostics]


def merge_reports(reports: Iterable[DiagnosticReport]) -> DiagnosticReport:
    out = DiagnosticReport()
    for r in reports:
        out.extend(r)
    return out


class KviVerificationError(ValueError):
    """A program or workload failed static verification. Carries the
    offending :class:`DiagnosticReport` so callers can inspect codes."""

    def __init__(self, report: DiagnosticReport,
                 context: Optional[str] = None):
        self.report = report
        head = f"{context}: " if context else ""
        n = len(report)
        super().__init__(
            f"{head}static verification failed with {n} "
            f"diagnostic{'s' if n != 1 else ''}:\n" + report.render_text())
