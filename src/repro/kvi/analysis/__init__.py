"""KVI static analysis: program verifier, hazard analyzer, lint CLI.

The sanitizer layer of the KVI stack — checks programs and workloads
**without executing them** and reports typed
:class:`~repro.kvi.analysis.diagnostics.Diagnostic` records with stable
codes (``KVI1xx`` structural, ``KVI2xx`` hazard, ``KVI3xx`` resource):

    from repro.kvi.analysis import analyze_program
    report = analyze_program(prog, config=cfg)
    if not report.ok:
        print(report.render_text())

Integration points:

  * ``PassPipeline.from_spec(spec, verify=True)`` re-verifies after
    every pass and attributes the first new diagnostic to the pass
    that introduced it,
  * every backend takes ``verify=True`` (ctor or ``run_workload``) to
    reject bad workloads with a :class:`KviVerificationError` instead
    of a backend traceback,
  * the DSE preflight rejects over-pressure points from the static
    :func:`spm_pressure` estimate before touching the allocator,
  * ``python -m repro.kvi.analysis --all`` lints every registered
    program/workload (``--format text|json``, ``--fail-on
    error|warning``).
"""
from repro.kvi.analysis.diagnostics import (CODES, Diagnostic,
                                            DiagnosticReport,
                                            KviVerificationError,
                                            Severity, merge_reports)
from repro.kvi.analysis.hazards import (DepEdge, DependenceGraph,
                                        SpmPressure, analyze_program,
                                        analyze_workload,
                                        audit_fusion_plan,
                                        check_spm_pressure,
                                        check_workload, dependence_graph,
                                        spm_pressure, windows_overlap)
from repro.kvi.analysis.verifier import instr_effects, verify_program

__all__ = [
    "CODES", "Diagnostic", "DiagnosticReport", "KviVerificationError",
    "Severity", "merge_reports",
    "DepEdge", "DependenceGraph", "SpmPressure",
    "analyze_program", "analyze_workload", "audit_fusion_plan",
    "check_spm_pressure", "check_workload", "dependence_graph",
    "spm_pressure", "windows_overlap",
    "instr_effects", "verify_program",
]
