"""The lintable-target registry: every stock program and workload the
``python -m repro.kvi.analysis`` CLI (and the CI ``kvi-lint`` step) can
check by name.

Targets are zero-argument factories so nothing is built until asked
for; data is drawn from a fixed seed so lint findings are reproducible.
Paper-scale sizes (conv 32x32, FFT-256, matmul 64x64) — static analysis
never executes anything, so full-size programs lint in milliseconds.
"""
from __future__ import annotations

from typing import Callable, Dict, Union

import numpy as np

from repro.kvi.ir import KviProgram
from repro.kvi.workload import KviWorkload

Target = Union[KviProgram, KviWorkload]

_SEED = 0


def _rng():
    return np.random.default_rng(_SEED)


def _conv(elem_bytes: int = 4) -> KviProgram:
    from repro.kvi.programs import conv2d_program
    rng = _rng()
    lim = {1: 8, 2: 64, 4: 128}[elem_bytes]
    img = rng.integers(-lim, lim, (32, 32)).astype(np.int32)
    filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
    return conv2d_program(img, filt, shift=4, elem_bytes=elem_bytes)


def _fft(elem_bytes: int = 4) -> KviProgram:
    from repro.kvi.programs import fft_program
    rng = _rng()
    lim = {1: 8, 2: 64, 4: 128}[elem_bytes]
    re = rng.integers(-lim, lim, 256).astype(np.int32)
    im = rng.integers(-lim, lim, 256).astype(np.int32)
    return fft_program(re, im, elem_bytes=elem_bytes)


def _matmul(resident: bool = True, elem_bytes: int = 4) -> KviProgram:
    from repro.kvi.programs import matmul_program
    rng = _rng()
    lim = {1: 4, 2: 32, 4: 64}[elem_bytes]
    A = rng.integers(-lim, lim, (64, 64)).astype(np.int32)
    B = rng.integers(-lim, lim, (64, 64)).astype(np.int32)
    return matmul_program(A, B, shift=2, resident=resident,
                          elem_bytes=elem_bytes)


def _pipeline_demo() -> KviProgram:
    from repro.kvi.programs import pipeline_demo_program
    return pipeline_demo_program(
        _rng().integers(-64, 64, 256).astype(np.int32), stages=4)


def _composite() -> KviWorkload:
    """The paper's composite protocol: conv / FFT / matmul pinned to
    harts 0 / 1 / 2 — the benchmark workload the sweep times."""
    return KviWorkload.composite(
        {0: [_conv()], 1: [_fft()], 2: [_matmul()]},
        name="composite_paper")


def _homogeneous() -> KviWorkload:
    """The homogeneous protocol: one conv replicated on three harts."""
    return KviWorkload.replicate(_conv(), 3)


#: name -> factory; the CLI's ``--all`` iterates this in order
REGISTERED_TARGETS: Dict[str, Callable[[], Target]] = {
    "conv32": _conv,
    "conv32_b16": lambda: _conv(elem_bytes=2),
    "conv32_b8": lambda: _conv(elem_bytes=1),
    "fft256": _fft,
    "matmul64": _matmul,
    "matmul64_streamed": lambda: _matmul(resident=False),
    "pipeline_demo": _pipeline_demo,
    "composite_paper": _composite,
    "conv32x3": _homogeneous,
}


def registered_targets() -> Dict[str, Callable[[], Target]]:
    return dict(REGISTERED_TARGETS)


def build_target(name: str) -> Target:
    try:
        return REGISTERED_TARGETS[name]()
    except KeyError:
        raise KeyError(
            f"unknown lint target {name!r}; available: "
            f"{sorted(REGISTERED_TARGETS)}") from None
