"""CLI: ``python -m repro.kvi.analysis [TARGET...] [options]``

Lints registered KVI programs/workloads (see
:mod:`repro.kvi.analysis.registry`) through the static verifier and
hazard analyzer — no backend ever executes.

    python -m repro.kvi.analysis --all --fail-on error     # the CI gate
    python -m repro.kvi.analysis conv32 fft256 --format json
    python -m repro.kvi.analysis --list

Exit status: 0 when no target reaches the ``--fail-on`` severity,
1 otherwise, 2 on usage errors. ``--optimize`` lints the program as
the default pass pipeline would actually execute it (fusion plan
attached); ``--D`` / ``--spm-kbytes`` select the machine configuration
for the static SPM-pressure check.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.kvi.analysis.diagnostics import (DiagnosticReport, Severity,
                                            merge_reports)
from repro.kvi.analysis.hazards import analyze_program, analyze_workload
from repro.kvi.analysis.registry import build_target, registered_targets
from repro.kvi.ir import KviProgram


def lint_target(name: str, optimize: bool = False,
                config=None) -> DiagnosticReport:
    """Build one registered target and analyze it."""
    target = build_target(name)
    if isinstance(target, KviProgram):
        if optimize:
            from repro.kvi.passes import optimize_program
            target = optimize_program(target)
        return analyze_program(target, config=config)
    if optimize:
        from repro.kvi.passes import PassPipeline
        target = target.map_programs(PassPipeline.from_spec(None).run)
    return analyze_workload(target, config=config)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.kvi.analysis")
    ap.add_argument("targets", nargs="*",
                    help="registered program/workload names to lint")
    ap.add_argument("--all", action="store_true",
                    help="lint every registered target")
    ap.add_argument("--list", action="store_true",
                    help="list registered targets and exit")
    ap.add_argument("--optimize", action="store_true",
                    help="lint the optimized program (default pass "
                         "pipeline, fusion plan audited)")
    ap.add_argument("--format", default="text", choices=("text", "json"),
                    help="diagnostic output format")
    ap.add_argument("--fail-on", default="error",
                    choices=("error", "warning", "never"),
                    help="lowest severity that fails the lint (exit 1)")
    ap.add_argument("--D", type=int, default=4,
                    help="lane count of the SPM-pressure config")
    ap.add_argument("--spm-kbytes", type=int, default=64,
                    help="per-bank SPM KiB of the SPM-pressure config")
    args = ap.parse_args(argv)

    names = sorted(registered_targets())
    if args.list:
        for n in names:
            print(n)
        return 0
    if args.all:
        targets = names
    elif args.targets:
        unknown = [t for t in args.targets if t not in names]
        if unknown:
            ap.error(f"unknown target(s) {unknown}; see --list")
        targets = args.targets
    else:
        ap.error("name at least one target, or pass --all / --list")

    from repro.kvi.dse.space import scheme_config
    config = scheme_config("shared", D=args.D,
                           spm_kbytes=args.spm_kbytes, name="lint")

    reports = {}
    for name in targets:
        reports[name] = lint_target(name, optimize=args.optimize,
                                    config=config)
    merged = merge_reports(reports.values())

    if args.format == "json":
        print(json.dumps(
            {"targets": {n: r.as_dicts() for n, r in reports.items()},
             "n_errors": len(merged.errors),
             "n_warnings": len(merged.warnings)},
            indent=2, sort_keys=True))
    else:
        for name, rep in reports.items():
            status = ("clean" if rep.clean else
                      f"{len(rep.errors)} error(s), "
                      f"{len(rep.warnings)} warning(s)")
            print(f"{name:20s} {status}")
            for d in rep:
                print(f"  {d.render()}")
        print(f"# linted {len(targets)} target(s): "
              f"{len(merged.errors)} error(s), "
              f"{len(merged.warnings)} warning(s)")

    if args.fail_on == "never":
        return 0
    gate = Severity.ERROR if args.fail_on == "error" else Severity.WARNING
    return 1 if merged.at_least(gate) else 0


if __name__ == "__main__":
    sys.exit(main())
