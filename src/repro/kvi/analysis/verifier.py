"""Structural verification of a :class:`~repro.kvi.ir.KviProgram`.

:func:`verify_program` checks a program **without executing it**: every
operand window against its register's declared length, every element
width against its operands, every memory transfer against its buffer,
def-before-use at element granularity, declared outputs actually
written, and the registry invariants (unique names, position-consistent
ids) the id-indexed lookups rely on. It deliberately re-checks
conditions the builders already enforce — the verifier is the sanitizer
for programs that arrive from *outside* the builders (future serving /
model-lowering frontends, hand-built IR, buggy passes) and trusts
nothing.

Findings are :class:`~repro.kvi.analysis.diagnostics.Diagnostic`
records with stable ``KVI1xx`` codes; see ``diagnostics.CODES``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.kvi.analysis.diagnostics import DiagnosticReport
from repro.kvi.ir import (ELEMWISE_OPS, MEM_OPS, REDUCTION_OPS,
                          TWO_SOURCE_OPS, KviInstr, KviOp, KviProgram,
                          Ref, ScalarBlock, np_dtype)

_VALID_ELEM_BYTES = (1, 2, 4)


def instr_effects(program: KviProgram, instr: KviInstr
                  ) -> Tuple[List[Tuple[Ref, int]], List[Tuple[Ref, int]]]:
    """(reads, writes) as lists of ``(ref, element extent)`` — the exact
    windows an instruction touches under MFU semantics:

      * ``kmemld`` writes the WHOLE source buffer's extent into the
        destination window (the MFU transfers complete buffers),
      * reductions write a single element (the register-file result
        spilled to the dst view),
      * everything else reads/writes ``instr.length`` elements.

    Shared by the verifier, the dependence graph and the fusion audit,
    so every analysis agrees on what an instruction touches. Operands
    whose refs are malformed (wrong space, dangling id) are skipped —
    the verifier reports those separately.
    """
    reads: List[Tuple[Ref, int]] = []
    writes: List[Tuple[Ref, int]] = []
    op = instr.op
    if op is KviOp.KMEMLD:
        width = instr.length
        src = instr.src1
        if (isinstance(src, Ref) and src.space == "mem"
                and 0 <= src.id < len(program.mems)):
            width = program.mem_by_id(src.id).length
        if isinstance(instr.dst, Ref) and instr.dst.space == "vreg":
            writes.append((instr.dst, width))
        return reads, writes
    if op is KviOp.KMEMSTR:
        if isinstance(instr.src1, Ref) and instr.src1.space == "vreg":
            reads.append((instr.src1, instr.length))
        return reads, writes
    for src in (instr.src1, instr.src2):
        if isinstance(src, Ref) and src.space == "vreg":
            reads.append((src, instr.length))
    if isinstance(instr.dst, Ref) and instr.dst.space == "vreg":
        writes.append((instr.dst, 1 if op in REDUCTION_OPS
                       else instr.length))
    return reads, writes


def _check_registries(program: KviProgram, rep: DiagnosticReport) -> None:
    """Unique names + position-consistent ids for vregs and mems."""
    for kind, seq in (("vreg", program.vregs), ("mem", program.mems)):
        seen: Dict[str, int] = {}
        for idx, r in enumerate(seq):
            if r.name in seen:
                rep.add("KVI111",
                        f"{kind} name {r.name!r} declared at positions "
                        f"{seen[r.name]} and {idx}",
                        program.name, subject=f"{kind}:{r.name}")
            else:
                seen[r.name] = idx
            if r.id != idx:
                rep.add("KVI112",
                        f"{kind} {r.name!r} has id {r.id} at position "
                        f"{idx}; id-indexed lookups would alias",
                        program.name, subject=f"{kind}:{r.name}")
            if r.elem_bytes not in _VALID_ELEM_BYTES:
                rep.add("KVI114",
                        f"{kind} {r.name!r} has elem_bytes "
                        f"{r.elem_bytes}; must be 1/2/4",
                        program.name, subject=f"{kind}:{r.name}")
            if r.length <= 0:
                rep.add("KVI102",
                        f"{kind} {r.name!r} has degenerate length "
                        f"{r.length}",
                        program.name, subject=f"{kind}:{r.name}")


def _check_mem_init(program: KviProgram, rep: DiagnosticReport) -> None:
    for m in program.mems:
        arr = program.mem_init.get(m.id)
        if arr is None:
            rep.add("KVI108",
                    f"buffer {m.name!r} has no mem_init entry",
                    program.name, subject=f"mem:{m.name}")
            continue
        if int(np.size(arr)) != m.length:
            rep.add("KVI108",
                    f"buffer {m.name!r} declares {m.length} elements but "
                    f"mem_init holds {int(np.size(arr))}",
                    program.name, subject=f"mem:{m.name}")
        if (m.elem_bytes in _VALID_ELEM_BYTES
                and np.asarray(arr).dtype != np_dtype(m.elem_bytes)):
            rep.add("KVI108",
                    f"buffer {m.name!r} declares elem_bytes "
                    f"{m.elem_bytes} but mem_init dtype is "
                    f"{np.asarray(arr).dtype}",
                    program.name, subject=f"mem:{m.name}")


def _operand_roles(op: KviOp):
    """(role, expected space, required) triples for one opcode."""
    if op is KviOp.KMEMLD:
        return (("dst", "vreg", True), ("src1", "mem", True),
                ("src2", None, False))
    if op is KviOp.KMEMSTR:
        return (("dst", "mem", True), ("src1", "vreg", True),
                ("src2", None, False))
    return (("dst", "vreg", True), ("src1", "vreg", True),
            ("src2", "vreg", op in TWO_SOURCE_OPS))


def _resolve(program: KviProgram, ref: Ref):
    """The VReg/MemRef a ref names, or None when the id dangles."""
    pool = program.vregs if ref.space == "vreg" else program.mems
    if 0 <= ref.id < len(pool):
        return pool[ref.id]
    return None


def verify_program(program: KviProgram) -> DiagnosticReport:
    """Run every structural check; returns the (possibly empty) report."""
    rep = DiagnosticReport()
    _check_registries(program, rep)
    _check_mem_init(program, rep)

    # defined-element tracking for use-before-def (KVI109): element
    # granularity, so per-element writers like the FFT's bit-reversal
    # kvcp loop are recognized as covering their register
    defined: Dict[int, np.ndarray] = {
        r.id: np.zeros(max(r.length, 1), dtype=bool)
        for r in program.vregs}
    warned_uninit: set = set()
    stored_mems: set = set()

    for idx, it in enumerate(program.items):
        if isinstance(it, ScalarBlock):
            if it.count <= 0:
                rep.add("KVI102",
                        f"scalar block with degenerate count {it.count}",
                        program.name, item=idx, subject=f"item{idx}")
            continue
        if not isinstance(it, KviInstr):
            rep.add("KVI101",
                    f"item of unknown type {type(it).__name__}",
                    program.name, item=idx, subject=f"item{idx}")
            continue
        op = it.op
        opname = op.value if isinstance(op, KviOp) else repr(op)
        if (not isinstance(op, KviOp)
                or op not in MEM_OPS | REDUCTION_OPS | ELEMWISE_OPS):
            rep.add("KVI101", f"unknown/unclassified op {opname!r}",
                    program.name, item=idx, op=opname,
                    subject=f"item{idx}")
            continue
        if it.length <= 0:
            rep.add("KVI102", f"instruction length {it.length} <= 0",
                    program.name, item=idx, op=opname,
                    subject=f"item{idx}")
            continue
        if it.elem_bytes not in _VALID_ELEM_BYTES:
            rep.add("KVI114",
                    f"instruction elem_bytes {it.elem_bytes}; must be "
                    f"1/2/4", program.name, item=idx, op=opname,
                    subject=f"item{idx}")

        # operand presence / space / id resolution
        operands: Dict[str, Optional[Ref]] = {
            "dst": it.dst, "src1": it.src1, "src2": it.src2}
        bad_ref = False
        for role, space, required in _operand_roles(op):
            ref = operands[role]
            if ref is None:
                if required:
                    rep.add("KVI100",
                            f"{opname} requires a {role} operand",
                            program.name, item=idx, op=opname,
                            subject=f"item{idx}:{role}")
                    bad_ref = True
                continue
            if space is None:
                continue              # tolerated extra operand
            if ref.space != space:
                rep.add("KVI104",
                        f"{opname} {role} must be a {space} reference, "
                        f"got {ref.space!r}",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:{role}")
                bad_ref = True
                continue
            if _resolve(program, ref) is None:
                rep.add("KVI103",
                        f"{opname} {role} references {ref.space} "
                        f"#{ref.id}, but the program declares only "
                        f"{len(program.vregs) if ref.space == 'vreg' else len(program.mems)}",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:{role}")
                bad_ref = True
        if bad_ref:
            continue

        # elem_bytes agreement across instruction + every operand
        for role in ("dst", "src1", "src2"):
            ref = operands[role]
            if ref is None:
                continue
            tgt = _resolve(program, ref)
            if tgt is not None and tgt.elem_bytes != it.elem_bytes:
                rep.add("KVI106",
                        f"{opname} {role} ({'vreg' if ref.space == 'vreg' else 'buffer'} "
                        f"{tgt.name!r}, elem_bytes {tgt.elem_bytes}) "
                        f"disagrees with instruction elem_bytes "
                        f"{it.elem_bytes}",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:{role}")

        # memory transfer extents vs. the buffer; the MFU transfers
        # whole buffers, so a nonzero mem-operand offset is silently
        # ignored by every backend — flag it (KVI113)
        for role in ("dst", "src1", "src2"):
            ref = operands[role]
            if ref is not None and ref.space == "mem" and ref.offset != 0:
                rep.add("KVI113",
                        f"{opname} {role} carries offset {ref.offset} "
                        f"into buffer "
                        f"{_resolve(program, ref).name!r}, which the "
                        f"MFU ignores (whole-buffer transfers)",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:{role}")
        if op is KviOp.KMEMLD:
            mem = _resolve(program, it.src1)
            if it.length != mem.length:
                rep.add("KVI107",
                        f"kmemld declares {it.length} elements but the "
                        f"MFU transfers buffer {mem.name!r} whole "
                        f"({mem.length} elements)",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:src1")
        elif op is KviOp.KMEMSTR:
            mem = _resolve(program, it.dst)
            if it.length > mem.length:
                rep.add("KVI107",
                        f"kmemstr of {it.length} elements overruns "
                        f"buffer {mem.name!r} ({mem.length} elements)",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:dst")
            stored_mems.add(it.dst.id)

        # window bounds + use-before-def over the touched extents
        reads, writes = instr_effects(program, it)
        for ref, width in reads + writes:
            reg = _resolve(program, ref)
            if ref.offset < 0 or ref.offset + width > reg.length:
                rep.add("KVI105",
                        f"{opname} window "
                        f"[{ref.offset}:{ref.offset + width}) outside "
                        f"vreg {reg.name!r} of length {reg.length}",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:vreg:{reg.name}")
        for ref, width in reads:
            reg = _resolve(program, ref)
            lo = max(ref.offset, 0)
            hi = min(ref.offset + width, reg.length)
            if (hi > lo and not defined[ref.id][lo:hi].all()
                    and (idx, ref.id) not in warned_uninit):
                first = lo + int(np.argmin(defined[ref.id][lo:hi]))
                rep.add("KVI109",
                        f"{opname} reads vreg {reg.name!r} element "
                        f"{first} before any write (reads as zero)",
                        program.name, item=idx, op=opname,
                        subject=f"item{idx}:vreg:{reg.name}")
                warned_uninit.add((idx, ref.id))
        for ref, width in writes:
            reg = _resolve(program, ref)
            lo = max(ref.offset, 0)
            hi = min(ref.offset + width, reg.length)
            if hi > lo:
                defined[ref.id][lo:hi] = True

    # declared outputs must be produced by some store
    for m in program.outputs:
        if m.id not in stored_mems:
            rep.add("KVI110",
                    f"output buffer {m.name!r} is never written by any "
                    f"kmemstr", program.name, subject=f"mem:{m.name}")
    return rep
