"""Hazard and resource analysis over KVI programs and workloads.

Four analyses, all static (no backend ever runs):

  * :func:`dependence_graph` — RAW/WAR/WAW edges between instructions,
    at vreg-*window* granularity (two writes to disjoint halves of one
    register are independent; overlapping windows are not). This is the
    paper's SPM interlock discipline lifted to the IR.
  * :func:`audit_fusion_plan` — legality of a planned
    :class:`~repro.kvi.passes.fusion.FusionPlan`: regions may weld only
    element-wise ops of one (length, elem_bytes), must respect the
    stale-read / overlapping-write-back hazards the planner cuts on,
    and must fit their declared slot-file bounds.
  * :func:`spm_pressure` — the static scratchpad requirement of a
    program on one machine configuration: peak-live bytes under the
    exact liveness + alignment rules the linear-scan allocator uses, so
    an over-capacity program is reported (``KVI301``) *before* lowering
    raises :class:`~repro.kvi.lowering.SpmOverflowError`.
  * :func:`check_workload` — cross-hart races: two structurally
    different programs on different harts writing the same logical
    buffer under the shared scheme. MemRefs are program-local, so the
    logical identity of a buffer across programs is its
    ``(name, length, elem_bytes)`` signature — the convention external
    frontends use for shared tensors. Data instances of one program
    structure are exempt: the workload model gives each entry its own
    output slot (``dedup_entry_outputs`` / the Pallas batch grid), so
    same-named outputs across a homogeneous batch are per-instance by
    construction.

:func:`analyze_program` / :func:`analyze_workload` bundle the
structural verifier with these checks — the entry points the CLI, the
pass pipeline and the backend ``verify=`` gates call.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.configs.base import KlessydraConfig
from repro.kvi.analysis.diagnostics import DiagnosticReport
from repro.kvi.analysis.verifier import instr_effects, verify_program
from repro.kvi.ir import (ELEMWISE_OPS, KviInstr, KviOp, KviProgram,
                          ScalarBlock)
from repro.kvi.passes.fusion import META_KEY, FusionPlan
from repro.kvi.passes.liveness import peak_live_bytes

#: one vreg window: (vreg id, element offset, element extent)
Window = Tuple[int, int, int]


def windows_overlap(a: Window, b: Window) -> bool:
    """Do two (vreg, offset, extent) windows touch common elements?"""
    return (a[0] == b[0]
            and a[1] < b[1] + b[2] and b[1] < a[1] + a[2])


@dataclass(frozen=True)
class DepEdge:
    """One dependence between two instructions (item indices)."""

    src: int                          # earlier instruction
    dst: int                          # later, dependent instruction
    kind: str                         # "RAW" | "WAR" | "WAW"
    reg: int                          # vreg id the windows live in
    src_window: Window
    dst_window: Window


@dataclass(frozen=True)
class DependenceGraph:
    """All window-granular dependences of one program."""

    edges: Tuple[DepEdge, ...]

    def by_kind(self, kind: str) -> Tuple[DepEdge, ...]:
        return tuple(e for e in self.edges if e.kind == kind)

    @property
    def counts(self) -> Dict[str, int]:
        out = {"RAW": 0, "WAR": 0, "WAW": 0}
        for e in self.edges:
            out[e.kind] += 1
        return out

    def predecessors(self, item: int) -> Tuple[int, ...]:
        return tuple(sorted({e.src for e in self.edges
                             if e.dst == item}))


def _covers(a: Window, b: Window) -> bool:
    """Window ``a`` fully contains window ``b`` (same vreg)."""
    return (a[0] == b[0] and a[1] <= b[1]
            and a[1] + a[2] >= b[1] + b[2])


def dependence_graph(program: KviProgram) -> DependenceGraph:
    """RAW/WAR/WAW edges over vreg windows — the *immediate*
    dependences: a write kills every history entry it fully covers, so
    each edge links an access to the latest frontier access it
    conflicts with. Any access ordered before a killed entry is ordered
    before the covering write too, so the full dependence order is the
    transitive closure of these edges — same ordering constraints,
    near-linear size (the exhaustive all-pairs graph is quadratic on
    in-place update chains like the FFT butterflies).
    """
    edges: List[DepEdge] = []
    # per vreg, the frontier in chronological order, split by kind so a
    # read never scans the (conflict-free) read history
    past_reads: Dict[int, List[Tuple[int, Window]]] = {}
    past_writes: Dict[int, List[Tuple[int, Window]]] = {}

    def scan(hist, win, idx, kind):
        for prev_idx, prev_win in hist:
            if prev_idx != idx and windows_overlap(win, prev_win):
                edges.append(DepEdge(prev_idx, idx, kind, win[0],
                                     prev_win, win))

    for idx, it in enumerate(program.items):
        if not isinstance(it, KviInstr):
            continue
        reads, writes = instr_effects(program, it)
        for ref, width in reads:
            win: Window = (ref.id, ref.offset, width)
            scan(past_writes.get(ref.id, ()), win, idx, "RAW")
            past_reads.setdefault(ref.id, []).append((idx, win))
        for ref, width in writes:
            win = (ref.id, ref.offset, width)
            scan(past_writes.get(ref.id, ()), win, idx, "WAW")
            scan(past_reads.get(ref.id, ()), win, idx, "WAR")
            # this write dominates everything it fully covers: later
            # conflicts with a covered entry conflict with this write
            # too, so dropping covered entries loses no ordering (only
            # redundant transitive edges)
            for hist in (past_reads.setdefault(ref.id, []),
                         past_writes.setdefault(ref.id, [])):
                hist[:] = [h for h in hist if not _covers(win, h[1])]
            past_writes[ref.id].append((idx, win))
    return DependenceGraph(tuple(edges))


# ---------------------------------------------------------------------------
# Fusion-plan legality
# ---------------------------------------------------------------------------


def audit_fusion_plan(program: KviProgram,
                      plan: Optional[FusionPlan] = None
                      ) -> DiagnosticReport:
    """Check a fusion plan (``plan`` or ``program.meta['fused_regions']``)
    against the weld-legality rules the planner promises; an empty
    report when the program carries no plan."""
    rep = DiagnosticReport()
    if plan is None:
        plan = program.meta.get(META_KEY)
    if plan is None:
        return rep
    if not isinstance(plan, FusionPlan):
        rep.add("KVI204",
                f"meta[{META_KEY!r}] is {type(plan).__name__}, not a "
                f"FusionPlan", program.name, subject="plan")
        return rep
    claimed: Set[int] = set()
    for rno, region in enumerate(plan.regions):
        subj = f"region{rno}"
        prev = None
        members: List[KviInstr] = []
        bad = False
        for item in region.items:
            if not (0 <= item < len(program.items)):
                rep.add("KVI204",
                        f"region {rno} references item {item}, program "
                        f"has {len(program.items)}",
                        program.name, item=item, subject=subj)
                bad = True
                continue
            if prev is not None and item <= prev:
                rep.add("KVI204",
                        f"region {rno} items not strictly ascending at "
                        f"{item}", program.name, item=item, subject=subj)
                bad = True
            prev = item
            if item in claimed:
                rep.add("KVI204",
                        f"item {item} welded into more than one region",
                        program.name, item=item, subject=subj)
                bad = True
            claimed.add(item)
            it = program.items[item]
            if (not isinstance(it, KviInstr)
                    or it.op not in ELEMWISE_OPS
                    or it.op is KviOp.KVCP):
                what = (it.op.value if isinstance(it, KviInstr)
                        else type(it).__name__)
                rep.add("KVI201",
                        f"region {rno} welds non-element-wise item "
                        f"{item} ({what})",
                        program.name, item=item,
                        op=what if isinstance(it, KviInstr) else None,
                        subject=subj)
                bad = True
                continue
            members.append(it)
            if (it.length != region.length
                    or it.elem_bytes != region.elem_bytes):
                rep.add("KVI202",
                        f"region {rno} planned for length "
                        f"{region.length}/eb{region.elem_bytes} welds "
                        f"item {item} with length {it.length}/"
                        f"eb{it.elem_bytes}",
                        program.name, item=item, op=it.op.value,
                        subject=subj)
                bad = True
        if bad:
            continue
        # replay the slot-file walk: stale reads and overlapping
        # write-backs are exactly what the planner must have cut on
        written: List[Window] = []
        slots: Set[Window] = set()
        inputs = 0
        for item, it in zip(region.items, members):
            for src in (it.src1, it.src2):
                if src is None:
                    continue
                key: Window = (src.id, src.offset, it.length)
                if key not in written and any(
                        windows_overlap(key, w) for w in written):
                    rep.add("KVI203",
                            f"region {rno} item {item} reads window "
                            f"{key} overlapping a pending region write "
                            f"(stale read across the weld)",
                            program.name, item=item, op=it.op.value,
                            subject=subj)
                if key not in slots:
                    slots.add(key)
                    if key not in written:
                        inputs += 1
            dkey: Window = (it.dst.id, it.dst.offset, it.length)
            if any(windows_overlap(dkey, w) for w in written
                   if w != dkey):
                rep.add("KVI203",
                        f"region {rno} item {item} writes window {dkey} "
                        f"overlapping a distinct pending write "
                        f"(write-back order hazard)",
                        program.name, item=item, op=it.op.value,
                        subject=subj)
            slots.add(dkey)
            if dkey not in written:
                written.append(dkey)
        if len(region.items) > plan.max_ops:
            rep.add("KVI303",
                    f"region {rno} welds {len(region.items)} ops; plan "
                    f"bound is {plan.max_ops}",
                    program.name, subject=subj)
        if inputs > plan.max_inputs:
            rep.add("KVI303",
                    f"region {rno} gathers {inputs} inputs; plan bound "
                    f"is {plan.max_inputs}",
                    program.name, subject=subj)
    return rep


# ---------------------------------------------------------------------------
# Static SPM pressure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SpmPressure:
    """The static scratchpad requirement of one (program, config)."""

    program: str
    peak_live_bytes: int              # liveness-exact requirement
    total_vreg_bytes: int             # sum of all vregs (no reuse)
    capacity_bytes: int
    line_bytes: int                   # allocation granule (D lanes)

    @property
    def fits(self) -> bool:
        return self.peak_live_bytes <= self.capacity_bytes

    @property
    def utilization(self) -> float:
        return self.peak_live_bytes / self.capacity_bytes

    def as_dict(self) -> Dict[str, object]:
        return {"peak_live_bytes": self.peak_live_bytes,
                "total_vreg_bytes": self.total_vreg_bytes,
                "capacity_bytes": self.capacity_bytes,
                "fits": self.fits}


def spm_pressure(program: KviProgram,
                 config: KlessydraConfig) -> SpmPressure:
    """Peak-live SPM bytes under the allocator's exact rules (line
    alignment from the config's lane count, uninitialized registers
    pinned live-from-start) — what
    :func:`repro.kvi.lowering.allocate_vregs` will demand, computed
    without running it."""
    from repro.kvi.passes.liveness import total_vreg_bytes
    line = max(config.D * 4, 4)
    return SpmPressure(
        program.name,
        peak_live_bytes(program, line, pin_uninitialized=True),
        total_vreg_bytes(program, line),
        config.spm_capacity_bytes, line)


def check_spm_pressure(program: KviProgram, config: KlessydraConfig
                       ) -> DiagnosticReport:
    rep = DiagnosticReport()
    p = spm_pressure(program, config)
    if not p.fits:
        rep.add("KVI301",
                f"peak-live vreg footprint {p.peak_live_bytes} B exceeds "
                f"SPM capacity {p.capacity_bytes} B on config "
                f"{config.name!r}; lowering would raise SpmOverflowError",
                program.name, subject=f"spm:{config.name}")
    return rep


# ---------------------------------------------------------------------------
# Workload-level checks
# ---------------------------------------------------------------------------


def _logical_buffers(program: KviProgram) -> Tuple[Set[tuple], Set[tuple]]:
    """(written, read) logical buffer identities of one program. A
    buffer's cross-program identity is (name, length, elem_bytes)."""
    written: Set[tuple] = set()
    read: Set[tuple] = set()
    for it in program.items:
        if not isinstance(it, KviInstr):
            continue
        if it.op is KviOp.KMEMSTR and it.dst is not None \
                and it.dst.space == "mem" \
                and 0 <= it.dst.id < len(program.mems):
            m = program.mem_by_id(it.dst.id)
            written.add((m.name, m.length, m.elem_bytes))
        elif it.op is KviOp.KMEMLD and it.src1 is not None \
                and it.src1.space == "mem" \
                and 0 <= it.src1.id < len(program.mems):
            m = program.mem_by_id(it.src1.id)
            read.add((m.name, m.length, m.elem_bytes))
    return written, read


def check_workload(workload, config: Optional[KlessydraConfig] = None,
                   shared_scheme: bool = True) -> DiagnosticReport:
    """Workload-level hazards: hart pinning vs. the machine, and
    cross-hart buffer races between structurally different programs
    (write/write is an error under the shared scheme, read/write a
    warning)."""
    from repro.kvi.workload import structural_signature
    rep = DiagnosticReport()
    if config is not None:
        for i, e in enumerate(workload.entries):
            if e.hart is not None and e.hart >= config.harts:
                rep.add("KVI302",
                        f"entry {i} ({e.program.name!r}) pinned to hart "
                        f"{e.hart}; config {config.name!r} has "
                        f"{config.harts} harts",
                        e.program.name, subject=f"entry{i}")

    sigs = [structural_signature(e.program) for e in workload.entries]
    bufs = {}
    for e in workload.entries:
        if id(e.program) not in bufs:
            bufs[id(e.program)] = _logical_buffers(e.program)
    flagged: Set[tuple] = set()
    for i, a in enumerate(workload.entries):
        for j in range(i + 1, len(workload.entries)):
            b = workload.entries[j]
            if sigs[i] == sigs[j]:
                continue              # data instances: per-entry outputs
            if (a.hart is not None and b.hart is not None
                    and a.hart == b.hart):
                continue              # same hart: sequential, no race
            wa, ra = bufs[id(a.program)]
            wb, rb = bufs[id(b.program)]
            for name, length, eb in sorted(wa & wb):
                k = ("ww", name, length, eb)
                if k in flagged or not shared_scheme:
                    continue
                flagged.add(k)
                rep.add("KVI210",
                        f"programs {a.program.name!r} (entry {i}) and "
                        f"{b.program.name!r} (entry {j}) on different "
                        f"harts both write buffer {name!r} "
                        f"({length} x {eb} B) — write/write race under "
                        f"the shared scheme",
                        workload.name, subject=f"mem:{name}")
            for name, length, eb in sorted((wa & rb) | (wb & ra)):
                k = ("rw", name, length, eb)
                if k in flagged:
                    continue
                flagged.add(k)
                rep.add("KVI211",
                        f"buffer {name!r} ({length} x {eb} B) is "
                        f"written by one hart and read by another "
                        f"({a.program.name!r} entry {i} / "
                        f"{b.program.name!r} entry {j}) with no "
                        f"ordering between harts",
                        workload.name, subject=f"mem:{name}")
    return rep


# ---------------------------------------------------------------------------
# Bundled entry points
# ---------------------------------------------------------------------------


def analyze_program(program: KviProgram,
                    config: Optional[KlessydraConfig] = None
                    ) -> DiagnosticReport:
    """Structural verification + fusion-plan audit (+ static SPM
    pressure when a machine ``config`` is given)."""
    rep = verify_program(program)
    rep.extend(audit_fusion_plan(program))
    if config is not None:
        rep.extend(check_spm_pressure(program, config))
    return rep


def analyze_workload(workload,
                     config: Optional[KlessydraConfig] = None,
                     shared_scheme: bool = True) -> DiagnosticReport:
    """Every distinct program analyzed once, plus the workload-level
    hazard checks."""
    rep = DiagnosticReport()
    seen: Set[int] = set()
    for e in workload.entries:
        if id(e.program) in seen:
            continue
        seen.add(id(e.program))
        rep.extend(analyze_program(e.program, config=config))
    rep.extend(check_workload(workload, config=config,
                              shared_scheme=shared_scheme))
    return rep
