"""Non-dominated (Pareto) front extraction over swept design points.

All objectives are minimized (cycles, area, energy). ``a`` dominates
``b`` when a is <= b in every objective and strictly < in at least one —
so metric-identical points never dominate each other, which makes the
front's *metric set* invariant under point duplication and permutation
(the property the hypothesis tests pin down).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True when metric vector ``a`` Pareto-dominates ``b`` (minimize)."""
    if len(a) != len(b):
        raise ValueError(f"metric arity mismatch: {len(a)} vs {len(b)}")
    return all(x <= y for x, y in zip(a, b)) and \
        any(x < y for x, y in zip(a, b))


def pareto_front(items: Sequence, key: Optional[Callable] = None) -> List:
    """The items whose metric vector no other item dominates, in input
    order. ``key`` maps an item to its metric tuple (identity when
    omitted). Duplicates of a front point are all kept — they are
    mutually non-dominated by the strictness rule."""
    key = key or (lambda x: x)
    metrics = [tuple(key(it)) for it in items]
    out = []
    for i, it in enumerate(items):
        if not any(dominates(metrics[j], metrics[i])
                   for j in range(len(items)) if j != i):
            out.append(it)
    return out


def front_metrics(items: Sequence,
                  key: Optional[Callable] = None) -> List[Tuple]:
    """The front as a sorted, de-duplicated list of metric tuples — the
    canonical representation (invariant under duplication/permutation
    of the input)."""
    key = key or (lambda x: x)
    return sorted(set(tuple(key(it)) for it in pareto_front(items, key)))
