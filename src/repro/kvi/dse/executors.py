"""Pluggable sweep executors: how design points fan out over compute.

The sweep driver (:mod:`repro.kvi.dse.sweep`) hands every executor the
same list of :class:`PointJob` units — a design point plus the
pre-optimized kernel programs it should run — and expects the matching
:class:`~repro.kvi.dse.sweep.PointRecord` list back **in job order**.
Because each job is independent and the merge is order-preserving, every
executor produces identical results; ``SweepResult.canonical_json()``
byte-equality across executors is pinned by tests.

  * :class:`SerialExecutor`  — in-process, one job at a time. The
    reference semantics everything else must match.
  * :class:`ThreadExecutor`  — in-process thread pool. Cheap to start,
    shares the optimized-program cache by reference, but the cyclesim
    inner loop is pure Python so the GIL caps real speedup.
  * :class:`ProcessExecutor` — a ``spawn`` process pool. Jobs (points +
    programs — all plain dataclasses and numpy buffers) are pickled to
    the workers and records pickled back; each worker builds its own
    per-point :class:`~repro.kvi.lowering.TraceCache`, so cache counters
    are deterministic and identical to serial execution. This is the
    executor that actually scales the paper-sized space on multi-core
    hosts.

``spawn`` (not ``fork``) is used deliberately: the parent may have jax
initialized (the Pallas walltime stage, the benchmark harness), and
forking a jax-bearing process is a documented deadlock hazard. Workers
never import jax — the Pallas stage runs in the parent after the
fan-out.
"""
from __future__ import annotations

import multiprocessing
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass
from typing import (TYPE_CHECKING, Dict, Iterator, List, Sequence, Union)

from repro.kvi.dse.space import DesignPoint
from repro.kvi.ir import KviProgram

if TYPE_CHECKING:                      # pragma: no cover - typing only
    from repro.kvi.dse.sweep import PointRecord


@dataclass(frozen=True)
class PointJob:
    """One unit of sweep work: a design point plus the kernel programs
    (already run through the point's pass pipeline) it executes. Fully
    picklable — the :class:`ProcessExecutor` serializes jobs verbatim."""

    point: DesignPoint
    kernels: Dict[str, KviProgram]
    composite: bool = True


def run_job(job: PointJob) -> "PointRecord":
    """Execute one job. Module-level so process pools can pickle it by
    reference; the import is deferred to dodge the sweep<->executor
    module cycle."""
    from repro.kvi.dse.sweep import run_point
    return run_point(job.point, job.kernels, composite=job.composite,
                     preoptimized=True)


class SweepExecutor:
    """Protocol: map jobs to records, order-preserving.

    ``imap_jobs`` is the primitive — a generator yielding records in job
    order as they complete, which is what lets the sweep driver report
    live progress (points/s, ETA) mid-fan-out. ``map_jobs`` is the
    drain-everything convenience every executor inherits."""

    name = "base"

    def imap_jobs(self, jobs: Sequence[PointJob]
                  ) -> Iterator["PointRecord"]:
        raise NotImplementedError

    def map_jobs(self, jobs: Sequence[PointJob]) -> List["PointRecord"]:
        return list(self.imap_jobs(jobs))

    def close(self) -> None:
        """Release any held worker pool. A no-op for per-call executors;
        persistent executors (see :class:`ProcessExecutor`) shut their
        long-lived pool down here. Idempotent."""


class SerialExecutor(SweepExecutor):
    """One job at a time in the calling thread — the reference order."""

    name = "serial"

    def __init__(self, max_workers: int = 1):
        del max_workers                  # uniform ctor across executors

    def imap_jobs(self, jobs: Sequence[PointJob]
                  ) -> Iterator["PointRecord"]:
        for j in jobs:
            yield run_job(j)


class ThreadExecutor(SweepExecutor):
    """In-process thread pool (the pre-executor sweep behavior)."""

    name = "thread"

    def __init__(self, max_workers: int = 4):
        self.max_workers = max(1, max_workers)

    def imap_jobs(self, jobs: Sequence[PointJob]
                  ) -> Iterator["PointRecord"]:
        with ThreadPoolExecutor(max_workers=self.max_workers) as ex:
            yield from ex.map(run_job, jobs)


class ProcessExecutor(SweepExecutor):
    """``spawn`` process pool: real multi-core speedup past the GIL.

    ``ex.map`` yields results in submission order, so the merged record
    list is deterministic and identical to :class:`SerialExecutor` —
    per-point trace-cache counters included, since every worker runs the
    same per-point ``run_point`` code on the same pickled programs.

    ``persistent=True`` keeps the spawn pool alive across ``imap_jobs``
    calls instead of paying interpreter start-up per call — built for
    multi-round drivers (the search tuner confirms a small survivor
    batch per rung) where a fresh pool per rung would cost more than
    the rung's simulation. Persistent instances must be :meth:`close`\\
    d (or used as a context manager) by whoever constructed them."""

    name = "process"

    def __init__(self, max_workers: int = 4, persistent: bool = False):
        self.max_workers = max(1, max_workers)
        self.persistent = persistent
        self._pool = None

    def _make_pool(self) -> ProcessPoolExecutor:
        ctx = multiprocessing.get_context("spawn")
        return ProcessPoolExecutor(max_workers=self.max_workers,
                                   mp_context=ctx)

    def imap_jobs(self, jobs: Sequence[PointJob]
                  ) -> Iterator["PointRecord"]:
        # chunk so each worker amortizes its interpreter start over
        # several points instead of one round-trip per point
        chunk = max(1, len(jobs) // (self.max_workers * 4))
        if self.persistent:
            if self._pool is None:
                self._pool = self._make_pool()
            yield from self._pool.map(run_job, jobs, chunksize=chunk)
            return
        with self._make_pool() as ex:
            yield from ex.map(run_job, jobs, chunksize=chunk)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ProcessExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


EXECUTORS = {cls.name: cls
             for cls in (SerialExecutor, ThreadExecutor, ProcessExecutor)}

#: ``"auto"`` fan-outs below this many *uncached* jobs run serially —
#: a spawn pool's interpreter start-up costs more than it saves on a
#: handful of points (exactly the warm-re-sweep case, where the
#: persistent point cache resolves most jobs in the parent and the
#: executor sees only the delta).
AUTO_SERIAL_MAX = 8


def resolve_auto(spec: Union[str, SweepExecutor, None],
                 n_jobs: int) -> Union[str, SweepExecutor, None]:
    """Resolve the ``"auto"`` executor spec against the number of jobs
    that will actually dispatch (cache hits already excluded): serial
    below :data:`AUTO_SERIAL_MAX`, the process pool otherwise. Every
    other spec — an explicit name, an instance, ``None`` — passes
    through untouched: explicit flags stay authoritative."""
    if spec != "auto":
        return spec
    return "serial" if n_jobs < AUTO_SERIAL_MAX else "process"


def make_executor(spec: Union[str, SweepExecutor, None],
                  max_workers: int = 4) -> SweepExecutor:
    """Resolve an executor: an instance passes through, a name
    instantiates from the registry, ``None`` keeps the legacy behavior
    (threads when ``max_workers > 1``, else serial). ``"auto"`` must be
    resolved by the caller first (:func:`resolve_auto` — it needs the
    uncached-job count, which only the sweep driver knows)."""
    if isinstance(spec, SweepExecutor):
        return spec
    if spec is None:
        spec = "thread" if max_workers and max_workers > 1 else "serial"
    try:
        cls = EXECUTORS[spec]
    except KeyError:
        raise ValueError(f"unknown sweep executor {spec!r}; available: "
                         f"{sorted(EXECUTORS)} (or 'auto' at the sweep "
                         f"level)") from None
    return cls(max_workers=max_workers)
