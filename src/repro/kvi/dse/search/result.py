"""Search reports: what the tuner found and what it cost to find.

:class:`SearchResult` follows the same persistence contract as
:class:`~repro.kvi.dse.sweep.SweepResult`: ``to_json`` carries
everything (timings included), ``canonical_json`` strips the shared
volatile-key set (:data:`repro.kvi.obs.scrub.DSE_VOLATILE` — which
includes ``fresh_evals``, the cold-vs-warm simulation count) so two
seeded runs of the same search compare byte-identical regardless of
executor choice or cache temperature. The CI gate diffs those bytes.

:func:`front_recovery` is the acceptance metric: the fraction of an
exhaustive-sweep Pareto front a search's confirmed front covers,
tie-tolerant — a front member counts as recovered when some confirmed
point matches its ``(cycles, area, energy)`` within a relative
tolerance, because distinct configs can land on identical metrics.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.kvi.dse.sweep import PointRecord, scrub_volatile


def front_recovery(found: Sequence[Tuple[float, float, float]],
                   reference: Sequence[Tuple[float, float, float]],
                   rel_tol: float = 1e-6) -> float:
    """Fraction of ``reference`` front metric tuples matched by some
    ``found`` tuple, coordinate-wise within ``rel_tol`` relative
    tolerance (ties between distinct configs with equal metrics count
    once — compare *metric tuples*, not point names). 1.0 for an empty
    reference."""
    ref = sorted(set(tuple(map(float, t)) for t in reference))
    if not ref:
        return 1.0
    got = [tuple(map(float, t)) for t in found]

    def close(a, b):
        return all(abs(x - y) <= rel_tol * max(abs(x), abs(y), 1.0)
                   for x, y in zip(a, b))

    hit = sum(1 for r in ref if any(close(g, r) for g in got))
    return hit / len(ref)


@dataclass
class SearchResult:
    """One search run, JSON-persistable.

    ``best`` / ``front`` hold confirmed :class:`PointRecord` objects
    (full cycle-accurate measurements — a search never reports
    estimates as results). ``evaluations`` separates the deterministic
    budget accounting (``low_evals`` / ``high_evals`` / per-rung
    ``rungs``) from the volatile ``fresh_evals``; ``meta`` carries the
    run shape (strategy, seed, budget, space size, walltime)."""

    strategy: str
    seed: int
    best: Optional[PointRecord]
    front: List[PointRecord]
    trajectory: List[dict] = field(default_factory=list)
    rungs: List[dict] = field(default_factory=list)
    evaluations: Dict[str, object] = field(default_factory=dict)
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def exhaustive_fraction(self) -> Optional[float]:
        """high-fidelity evaluations as a fraction of the full grid —
        the headline "searched, didn't enumerate" number."""
        grid = self.meta.get("grid_size")
        if not grid:
            return None
        return float(self.evaluations.get("high_evals", 0)) / grid

    def front_metrics(self, objectives) -> List[Tuple[float, float, float]]:
        return [objectives(r) for r in self.front]

    def to_json(self) -> Dict[str, object]:
        frac = self.exhaustive_fraction
        return {
            "strategy": self.strategy,
            "seed": self.seed,
            "best": self.best.as_dict() if self.best else None,
            "front": [r.as_dict() for r in self.front],
            "trajectory": list(self.trajectory),
            "rungs": list(self.rungs),
            "evaluations": dict(
                self.evaluations,
                exhaustive_fraction=round(frac, 6)
                if frac is not None else None),
            "meta": dict(self.meta),
        }

    def canonical_json(self) -> str:
        """The search serialized with every volatile field stripped —
        byte-identical for the same (space, strategy, seed, budget)
        across executors and cache temperatures."""
        return json.dumps(scrub_volatile(self.to_json()), indent=2,
                          sort_keys=True)

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    def to_markdown(self) -> str:
        """Human summary for ``dse_search.md``."""
        lines = [
            "# KVI design-space search",
            "",
            f"- strategy: `{self.strategy}` (seed {self.seed})",
            f"- space: {self.meta.get('grid_size', '?')} points "
            f"({self.meta.get('space', 'custom')})",
        ]
        ev = self.evaluations
        frac = self.exhaustive_fraction
        lines.append(
            f"- evaluations: {ev.get('low_evals', 0)} analytic, "
            f"{ev.get('high_evals', 0)} cycle-accurate"
            + (f" ({frac:.1%} of exhaustive)" if frac is not None
               else ""))
        if self.best is not None:
            lines.append(f"- best: `{self.best.point.name}`")
        lines += ["", "## Confirmed Pareto front", "",
                  "| point | mix cycles | area (LUTeq) | mix energy (nJ) |",
                  "|---|---|---|---|"]
        for r in self.front:
            row = self.meta.get("front_metrics", {}).get(r.point.name)
            if row:
                lines.append(f"| `{r.point.name}` | {row[0]:.1f} | "
                             f"{row[1]:.0f} | {row[2]:.1f} |")
            else:
                lines.append(f"| `{r.point.name}` | | | |")
        lines += ["", "## Trajectory", "",
                  "| high-fid evals | best point | best mix cycles | front size |",
                  "|---|---|---|---|"]
        for t in self.trajectory:
            lines.append(f"| {t['high_evals']} | "
                         f"`{t.get('best_point')}` | "
                         f"{t.get('best_mix_cycles')} | "
                         f"{t.get('front_size')} |")
        lines.append("")
        lines.append("![search trajectory](dse_search_trajectory.svg)")
        lines.append("")
        return "\n".join(lines)
