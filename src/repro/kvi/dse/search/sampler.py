"""Feasible-candidate sampling without materializing the grid.

:class:`CandidateSampler` is the search tuner's only source of design
points. It draws uniform flat indices into the
:class:`~repro.kvi.dse.space.DesignSpace` mixed-radix grid
(``point_at`` decodes them in O(1)) and keeps only points the
:class:`~repro.kvi.dse.space.SpaceConstraints` accept — so a
5000-point synthetic space with a tight area budget costs rejection
checks (closed-form cost model, microseconds each), never an
enumeration. When rejection sampling stalls (tiny feasible region or
the sampler has already seen most of the grid) it falls back to one
deterministic shuffled scan of the remaining indices, so ``draw``
terminates on any space.

The evolutionary strategy's variation operators live here too —
:meth:`mutate` re-draws one axis of a point (scheme moves re-draw the
scheme-coupled ``(M, F)`` pair and ``fu_counts`` with it) and
:meth:`crossover` mixes two parents axis-wise — because the sampler is
the one object that knows the space's axes *and* the feasibility
predicate. All randomness flows from the one ``random.Random`` handed
in by the driver: no module-level RNG anywhere in the search stack.
"""
from __future__ import annotations

import random
from typing import List, Optional, Set

from repro.kvi.dse.space import (DesignPoint, DesignSpace,
                                 SpaceConstraints)

#: rejection-sampling attempts per requested point before falling back
#: to the deterministic shuffled scan of all unseen indices.
ATTEMPTS_PER_DRAW = 64


class CandidateSampler:
    """Draw distinct feasible points; mutate/cross them for evolution.

    ``seen`` persists across :meth:`draw` calls — a sampler never
    returns the same point twice, which is what lets strategies treat
    successive draws as a growing candidate pool."""

    def __init__(self, space: DesignSpace,
                 constraints: Optional[SpaceConstraints] = None,
                 rng: Optional[random.Random] = None):
        self.space = space
        self.constraints = constraints
        self.rng = rng if rng is not None else random.Random(0)
        self.attempts = 0            # indices drawn (incl. rejected)
        self.rejections = 0          # infeasible / duplicate draws
        self._seen_idx: Set[int] = set()
        self._seen_names: Set[str] = set()

    # -- feasibility ------------------------------------------------------

    def feasible(self, point: DesignPoint) -> bool:
        return self.constraints is None \
            or self.constraints.feasible(point)

    @property
    def grid_size(self) -> int:
        return self.space.grid_size

    # -- drawing ----------------------------------------------------------

    def _admit(self, point: DesignPoint) -> bool:
        if point.name in self._seen_names or not self.feasible(point):
            self.rejections += 1
            return False
        self._seen_names.add(point.name)
        return True

    def draw(self, n: int) -> List[DesignPoint]:
        """Up to ``n`` new distinct feasible points (fewer only when
        the feasible region is exhausted). Uniform over the unseen
        feasible grid in the rejection phase; the shuffled-scan
        fallback preserves determinism but not uniformity."""
        out: List[DesignPoint] = []
        grid = self.space.grid_size
        budget = ATTEMPTS_PER_DRAW * max(n, 1)
        while len(out) < n and budget > 0 \
                and len(self._seen_idx) < grid:
            budget -= 1
            self.attempts += 1
            idx = self.rng.randrange(grid)
            if idx in self._seen_idx:
                self.rejections += 1
                continue
            self._seen_idx.add(idx)
            pt = self.space.point_at(idx)
            if self._admit(pt):
                out.append(pt)
        if len(out) < n and len(self._seen_idx) < grid:
            # deterministic fallback: scan the unseen remainder once,
            # in rng-shuffled order
            rest = [i for i in range(grid) if i not in self._seen_idx]
            self.rng.shuffle(rest)
            for idx in rest:
                self._seen_idx.add(idx)
                pt = self.space.point_at(idx)
                if self._admit(pt):
                    out.append(pt)
                    if len(out) >= n:
                        break
        return out

    # -- variation operators (evolutionary strategy) ----------------------

    def _axis_choices(self, point: DesignPoint) -> List[str]:
        """Axes that have somewhere to move for this point."""
        sp = self.space
        axes: List[str] = []
        if len(sp.schemes) > 1:
            axes.append("scheme")
        if len(sp._mf_pairs(point.scheme)) > 1:
            axes.append("mf")
        if len(sp.lanes) > 1:
            axes.append("lanes")
        if len(sp.precisions) > 1:
            axes.append("precision")
        if len(sp.spm_kbytes) > 1:
            axes.append("spm")
        if len(sp.chaining) > 1:
            axes.append("chaining")
        if len(sp.pipelines) > 1:
            axes.append("pipeline")
        if len(sp._scheme_fus(point.scheme)) > 1:
            axes.append("fu")
        return axes

    def _rebuild(self, **kw) -> Optional[DesignPoint]:
        try:
            return DesignPoint(**kw)
        except ValueError:
            return None

    def _as_kwargs(self, point: DesignPoint) -> dict:
        return {"scheme": point.scheme, "M": point.M, "F": point.F,
                "D": point.D, "precision_bits": point.precision_bits,
                "spm_kbytes": point.spm_kbytes,
                "chaining": point.chaining,
                "fu_counts": point.fu_counts, "passes": point.passes}

    def _other(self, options, current):
        options = [o for o in options if o != current]
        return self.rng.choice(options) if options else current

    def mutate(self, point: DesignPoint,
               max_tries: int = 8) -> Optional[DesignPoint]:
        """A feasible neighbor differing from ``point`` in one axis
        (scheme moves also re-draw the coupled ``(M, F)`` pair and
        ``fu_counts``), or ``None`` when ``max_tries`` mutations all
        land infeasible. Already-seen names are allowed — the
        strategy's confirmed-set dedup handles revisits (they are free
        through the evaluator's memo anyway)."""
        sp = self.space
        axes = self._axis_choices(point)
        if not axes:
            return None
        for _ in range(max_tries):
            kw = self._as_kwargs(point)
            axis = self.rng.choice(axes)
            if axis == "scheme":
                scheme = self._other(list(sp.schemes), point.scheme)
                m, f = self.rng.choice(sp._mf_pairs(scheme))
                kw.update(scheme=scheme, M=m, F=f,
                          fu_counts=self.rng.choice(
                              sp._scheme_fus(scheme)))
            elif axis == "mf":
                m, f = self._other(sp._mf_pairs(point.scheme),
                                   (point.M, point.F))
                kw.update(M=m, F=f)
            elif axis == "lanes":
                kw["D"] = self._other(list(sp.lanes), point.D)
            elif axis == "precision":
                kw["precision_bits"] = self._other(
                    list(sp.precisions), point.precision_bits)
            elif axis == "spm":
                kw["spm_kbytes"] = self._other(
                    list(sp.spm_kbytes), point.spm_kbytes)
            elif axis == "chaining":
                kw["chaining"] = not point.chaining
            elif axis == "pipeline":
                kw["passes"] = self._other(
                    list(sp.pipelines), point.passes)
            else:                                      # fu
                kw["fu_counts"] = self._other(
                    list(sp._scheme_fus(point.scheme)), point.fu_counts)
            child = self._rebuild(**kw)
            if child is not None and child.name != point.name \
                    and self.feasible(child):
                return child
        return None

    def crossover(self, a: DesignPoint, b: DesignPoint,
                  max_tries: int = 8) -> Optional[DesignPoint]:
        """A feasible axis-wise mix of two parents: each independent
        axis comes from a coin-flipped parent; the scheme-coupled
        fields (``M``/``F``/``fu_counts``) follow whichever parent
        donated the scheme. ``None`` when every try is infeasible or
        collapses onto a parent."""
        for _ in range(max_tries):
            donor = a if self.rng.random() < 0.5 else b
            kw = {"scheme": donor.scheme, "M": donor.M, "F": donor.F,
                  "fu_counts": donor.fu_counts}
            for axis, attr in (("D", "D"),
                               ("precision_bits", "precision_bits"),
                               ("spm_kbytes", "spm_kbytes"),
                               ("chaining", "chaining"),
                               ("passes", "passes")):
                kw[axis] = getattr(
                    a if self.rng.random() < 0.5 else b, attr)
            child = self._rebuild(**kw)
            if child is not None and child.name not in (a.name, b.name) \
                    and self.feasible(child):
                return child
        return None

    @property
    def stats(self) -> dict:
        return {"attempts": self.attempts,
                "rejections": self.rejections,
                "distinct_points": len(self._seen_names),
                "grid_size": self.space.grid_size}
