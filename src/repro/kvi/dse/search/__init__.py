"""Budget-constrained auto-tuner: *search* the design space instead of
enumerating it.

The exhaustive sweep (:mod:`repro.kvi.dse.sweep`) reproduces the
paper's 96-point comparison, but enumeration stops scaling exactly
where the ROADMAP goes next — mesh axes, fu_counts and precision
multiply the grid into thousands of points. This package inverts the
sweep into a design *question*: given an area/energy budget and a
workload mix, find the best configuration while running the
cycle-accurate simulator on as few points as possible.

The pieces:

  * :class:`~repro.kvi.dse.search.sampler.CandidateSampler` — draws
    feasible points from constraint predicates
    (:class:`~repro.kvi.dse.space.SpaceConstraints`) by decoding random
    flat indices (``DesignSpace.point_at``) — the grid is never
    materialized. Also the mutation/crossover operators the
    evolutionary strategy uses.
  * :class:`~repro.kvi.dse.search.evaluator.TwoFidelityEvaluator` —
    the **low-fidelity** rung scores candidates purely from the
    analytic cost model (:func:`repro.kvi.dse.cost.estimate_kernel`)
    plus the static SPM preflight — no lowering, no simulation,
    thousands of points per second. The **high-fidelity** rung batch-
    confirms survivors on :class:`~repro.kvi.cyclesim.CycleSimBackend`
    through the existing sweep executors, persistent
    :class:`~repro.kvi.dse.pointcache.PointCache` and shared
    ``TraceCache`` — revisited candidates are free across rounds.
  * :mod:`~repro.kvi.dse.search.strategies` — pluggable seed-
    deterministic strategies (``random``, ``successive_halving``,
    ``evolutionary``), all emitting best-so-far trajectories.
  * :class:`~repro.kvi.dse.search.result.SearchResult` — the report:
    best config, trajectory, evaluations-vs-exhaustive fraction, with
    the same canonical-JSON / volatile-scrub determinism contract as
    the sweep.
  * :func:`~repro.kvi.dse.search.driver.run_search` — the driver the
    ``python -m repro.kvi.dse search`` CLI and the bench harness call.
"""
from __future__ import annotations

from repro.kvi.dse.search.driver import run_search  # noqa: F401
from repro.kvi.dse.search.evaluator import (LowFidScore,  # noqa: F401
                                            TwoFidelityEvaluator)
from repro.kvi.dse.search.result import (SearchResult,  # noqa: F401
                                         front_recovery)
from repro.kvi.dse.search.sampler import CandidateSampler  # noqa: F401
from repro.kvi.dse.search.strategies import (STRATEGIES,  # noqa: F401
                                             SearchBudget, StrategyRun)

__all__ = ["CandidateSampler", "TwoFidelityEvaluator", "LowFidScore",
           "SearchBudget", "StrategyRun", "STRATEGIES", "SearchResult",
           "front_recovery", "run_search"]
