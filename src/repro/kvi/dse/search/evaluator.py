"""The two-fidelity evaluator: cheap analytic scores, targeted sims.

**Low fidelity** (:meth:`TwoFidelityEvaluator.low_fid`) scores a batch
of candidates without lowering or simulating anything: per-kernel
closed-form cycle/energy estimates
(:func:`repro.kvi.dse.cost.estimate_kernel` over a
:class:`~repro.kvi.dse.cost.KernelProfile` built once per
``(precision, passes)`` pair), the exact analytic area, and the static
SPM preflight (:func:`repro.kvi.passes.liveness.peak_live_bytes` with
the allocator's own line rounding, cached per ``(precision, passes,
D)`` since the liveness peak depends on nothing else) — thousands of
points per second.

**High fidelity** (:meth:`TwoFidelityEvaluator.high_fid`) batch-
confirms an explicit point list through the existing
:func:`repro.kvi.dse.sweep.sweep` driver: the same executors, the same
persistent :class:`~repro.kvi.dse.pointcache.PointCache`, the same
per-point ``TraceCache`` — so a candidate revisited in a later round
(or a later *search*) costs nothing.

Evaluation accounting draws a deliberate line:

  * ``high_evals`` — distinct points *requested* for confirmation.
    Deterministic (persistent-cache hits still count: they would be
    simulations without the store), part of the canonical report, and
    the number the "<= 50% of exhaustive" acceptance gate reads.
  * ``fresh_evals`` — points that actually ran the simulator this
    process. Volatile by definition (cold vs warm), scrubbed from
    canonical output, and the number the "warm re-search does zero
    cyclesim work" test reads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.kvi.dse.cost import (KernelProfile, estimate_kernel,
                                hardware_cost, kernel_profile)
from repro.kvi.dse.space import DesignPoint
from repro.kvi.dse.sweep import KernelFactory, PointRecord, sweep


@dataclass(frozen=True)
class LowFidScore:
    """One candidate's analytic scorecard. ``objectives`` mirrors the
    high-fidelity metric tuple ``(workload-mix cycles, area LUTeq,
    workload-mix energy nJ)`` — minimized, directly comparable between
    candidates (NOT between fidelities). ``None`` when the static SPM
    preflight rejected the point."""

    point: DesignPoint
    feasible: bool
    reason: Optional[str] = None
    objectives: Optional[Tuple[float, float, float]] = None
    kernels: Optional[Dict[str, Dict[str, float]]] = None


class TwoFidelityEvaluator:
    """Score cheaply, simulate rarely, remember everything.

    ``weights`` is the workload mix — kernel name -> weight in the
    scalar/mix objectives (missing kernels weigh 1.0). ``cache`` is the
    persistent point cache shared with the exhaustive sweep;
    ``executor`` / ``max_workers`` fan the confirmation batches out
    (pass a persistent :class:`~repro.kvi.dse.executors.
    ProcessExecutor` to amortize pool spawn across rounds)."""

    def __init__(self, kernel_factory: KernelFactory,
                 weights: Optional[Dict[str, float]] = None,
                 composite: bool = True,
                 cache=None, executor=None, max_workers: int = 4,
                 emit=None, obs=None):
        self.kernel_factory = kernel_factory
        self.weights = dict(weights or {})
        self.composite = composite
        self.cache = cache
        self.executor = executor
        self.max_workers = max_workers
        self.emit = emit
        self.obs = obs
        self.low_evals = 0
        self.high_evals = 0
        self.fresh_evals = 0
        self._records: Dict[str, PointRecord] = {}
        self._profiles: Dict[tuple, Dict[str, KernelProfile]] = {}
        self._spm_peaks: Dict[tuple, int] = {}
        self._low_seen: set = set()
        # program/fingerprint reuse across every high-fid round
        self._shared_opt: dict = {}

    # -- shared program/profile caches ------------------------------------

    def _programs_for(self, precision_bits: int, passes) -> Dict[str, object]:
        """The optimized programs of one (precision, passes) class —
        the exact objects ``sweep`` would build, via the same shared
        cache, so profiles and simulations agree."""
        from repro.kvi.dse.sweep import optimize_kernels
        raw = self._shared_opt.setdefault("raw", {})
        if precision_bits not in raw:
            raw[precision_bits] = self.kernel_factory(precision_bits)
        opt = self._shared_opt.setdefault("opt", {})
        key = (precision_bits, passes)
        if key not in opt:
            opt[key] = optimize_kernels(raw[precision_bits], passes)
        return opt[key]

    def _profiles_for(self, precision_bits: int,
                      passes) -> Dict[str, KernelProfile]:
        key = (precision_bits, passes)
        if key not in self._profiles:
            self._profiles[key] = {
                name: kernel_profile(p)
                for name, p in self._programs_for(precision_bits,
                                                  passes).items()}
        return self._profiles[key]

    def _spm_peak(self, precision_bits: int, passes, D: int) -> int:
        """Max over kernels of the allocator's liveness peak — depends
        only on the programs and the line width (D), never on SPM
        capacity, so one number serves every capacity on the axis."""
        key = (precision_bits, passes, D)
        if key not in self._spm_peaks:
            from repro.kvi.passes.liveness import peak_live_bytes
            line = max(D * 4, 4)
            self._spm_peaks[key] = max(
                peak_live_bytes(p, line, pin_uninitialized=True)
                for p in self._programs_for(precision_bits,
                                            passes).values())
        return self._spm_peaks[key]

    # -- objectives --------------------------------------------------------

    def _mix(self, per_kernel: Dict[str, Dict[str, float]],
             cycles_key: str, energy_key: str) -> Tuple[float, float]:
        c = sum(self.weights.get(k, 1.0) * float(v[cycles_key])
                for k, v in per_kernel.items())
        e = sum(self.weights.get(k, 1.0) * float(v[energy_key])
                for k, v in per_kernel.items())
        return c, e

    def objectives(self, rec: PointRecord
                   ) -> Tuple[float, float, float]:
        """High-fidelity metric tuple of a confirmed record:
        (mix cycles, area LUTeq, mix energy nJ), minimized."""
        c, e = self._mix(rec.kernels, "cycles", "energy_nj")
        return (c, rec.area.area_luteq, e)

    # -- low fidelity ------------------------------------------------------

    def low_fid(self, points: Sequence[DesignPoint]
                ) -> List[LowFidScore]:
        """Analytic scores for a candidate batch (order-preserving).
        Pure closed-form: cost-model estimates + static SPM preflight.
        First-time points count toward ``low_evals``."""
        out: List[LowFidScore] = []
        for pt in points:
            if pt.name not in self._low_seen:
                self._low_seen.add(pt.name)
                self.low_evals += 1
            cfg = pt.config()
            peak = self._spm_peak(pt.precision_bits, pt.passes, pt.D)
            if peak > cfg.spm_capacity_bytes:
                out.append(LowFidScore(
                    pt, False,
                    reason=f"static SPM overflow: peak-live {peak} B > "
                           f"capacity {cfg.spm_capacity_bytes} B"))
                continue
            profiles = self._profiles_for(pt.precision_bits, pt.passes)
            per = {name: estimate_kernel(prof, cfg,
                                         chaining=pt.chaining)
                   for name, prof in profiles.items()}
            c, e = self._mix(per, "est_cycles", "est_energy_nj")
            out.append(LowFidScore(
                pt, True,
                objectives=(c, hardware_cost(cfg).area_luteq, e),
                kernels=per))
        return out

    # -- high fidelity -----------------------------------------------------

    def high_fid(self, points: Sequence[DesignPoint],
                 label: str = "confirm") -> List[PointRecord]:
        """Cycle-accurate confirmation of ``points`` (order-preserving;
        duplicates and previously-confirmed points served from the
        in-run memo for free). ``label`` names the round in the point
        cache's per-round accounting."""
        todo, seen_batch = [], set()
        for pt in points:
            if pt.name in self._records or pt.name in seen_batch:
                continue
            seen_batch.add(pt.name)
            todo.append(pt)
        if todo:
            self.high_evals += len(todo)
            if self.cache is not None:
                self.cache.begin_round(label)
            result = sweep(todo, self.kernel_factory,
                           composite=self.composite,
                           max_workers=self.max_workers,
                           executor=self.executor, cache=self.cache,
                           emit=None, obs=self.obs,
                           shared_opt_cache=self._shared_opt)
            for rec in result.records:
                self._records[rec.point.name] = rec
                if not rec.cached:
                    self.fresh_evals += 1
            if self.emit:
                n_fresh = sum(not r.cached for r in result.records)
                self.emit(f"search[{label}] confirmed {len(todo)} "
                          f"points ({n_fresh} fresh sims)")
        return [self._records[pt.name] for pt in points
                if pt.name in self._records]

    def record(self, name: str) -> Optional[PointRecord]:
        return self._records.get(name)

    @property
    def confirmed(self) -> Dict[str, PointRecord]:
        """Every confirmed record so far (name -> record)."""
        return dict(self._records)

    @property
    def stats(self) -> Dict[str, int]:
        return {"low_evals": self.low_evals,
                "high_evals": self.high_evals,
                "fresh_evals": self.fresh_evals}
