"""Pluggable search strategies: random screening, successive halving,
evolutionary. All seed-deterministic — every random decision flows from
the one ``random.Random`` the driver seeds — and all two-fidelity:
candidates are scored by the analytic model first and only survivors
spend cycle-accurate simulations, so each strategy operates under a
hard ``max_high_evals`` budget.

The shared geometry: the low-fidelity objective tuple ``(mix cycles,
area, mix energy)`` carries *exact* area (same closed form as high
fidelity) but *estimated* cycles/energy, so survivor selection uses
**ε-relaxed dominance** — a candidate is culled only when another
candidate beats it by more than the estimator's error margin in the
estimated coordinates (and outright in exact area). Layer-peeling this
relaxed dominance gives the successive-halving rungs; the ε=0 special
case is ordinary non-dominated sorting.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.kvi.dse.pareto import pareto_front
from repro.kvi.dse.search.evaluator import (LowFidScore,
                                            TwoFidelityEvaluator)
from repro.kvi.dse.search.sampler import CandidateSampler
from repro.kvi.dse.sweep import PointRecord

#: default ε of the relaxed low-fidelity dominance: the estimator's
#: observed per-scheme error band is ~7% (see the calibration note in
#: :data:`repro.kvi.dse.cost.CALIBRATION`); 2% on top of layer peeling
#: keeps every true front member in the first rung on the smoke space
#: while culling ~60% of candidates before any simulation.
DEFAULT_EPS = 0.02


@dataclass(frozen=True)
class SearchBudget:
    """What a search may spend. ``max_high_evals`` is the hard
    cycle-accurate budget (the scarce resource); ``pool`` bounds the
    candidate set strategies screen analytically (default
    ``min(grid, 8 * max_high_evals)``); ``eps`` relaxes low-fidelity
    dominance; ``population`` / ``generations`` shape the evolutionary
    loop."""

    max_high_evals: int
    pool: Optional[int] = None
    eps: float = DEFAULT_EPS
    population: int = 12
    generations: int = 8

    def pool_size(self, grid: int) -> int:
        if self.pool is not None:
            return min(self.pool, grid)
        return min(grid, 8 * max(self.max_high_evals, 1))

    def as_dict(self) -> dict:
        return {"max_high_evals": self.max_high_evals,
                "pool": self.pool, "eps": self.eps,
                "population": self.population,
                "generations": self.generations}


@dataclass
class StrategyRun:
    """What a strategy hands back: confirmed records in confirmation
    order, the best-so-far trajectory (one entry per confirmation
    round) and per-rung evaluation accounting."""

    confirmed: List[PointRecord] = field(default_factory=list)
    trajectory: List[dict] = field(default_factory=list)
    rungs: List[dict] = field(default_factory=list)

    def best(self, evaluator: TwoFidelityEvaluator
             ) -> Optional[PointRecord]:
        """The budget-feasible best config: minimal workload-mix
        cycles among confirmed points (ties to smaller area, then
        name — fully deterministic)."""
        ok = [r for r in self.confirmed if r.ok]
        if not ok:
            return None
        return min(ok, key=lambda r: (*evaluator.objectives(r)[:2],
                                      r.point.name))

    def front(self, evaluator: TwoFidelityEvaluator
              ) -> List[PointRecord]:
        ok = [r for r in self.confirmed if r.ok]
        return pareto_front(ok, key=evaluator.objectives)


# ---------------------------------------------------------------------------
# ε-relaxed dominance over low-fidelity scores
# ---------------------------------------------------------------------------


def _eps_dominates(a, b, eps: float) -> bool:
    """``a`` ε-dominates ``b``: at least as good everywhere even after
    handicapping a's *estimated* coordinates by (1+eps) — area (index
    1) is exact and compares directly — and strictly better somewhere
    at face value."""
    return (a[1] <= b[1]
            and a[0] * (1.0 + eps) <= b[0]
            and a[2] * (1.0 + eps) <= b[2]
            and (a[0] < b[0] or a[1] < b[1] or a[2] < b[2]))


def eps_peel(scores: Sequence[LowFidScore],
             eps: float) -> List[List[LowFidScore]]:
    """Layer-peel feasible scores by ε-relaxed dominance: layer 0 is
    everything not ε-dominated (a superset of the est-Pareto front that
    absorbs the estimator's error band), layer 1 the same after
    removing layer 0, and so on. Infeasible scores are dropped. Each
    layer is sorted by (mix cycles, area, name) so downstream
    truncation is deterministic."""
    remaining = [s for s in scores if s.feasible]
    layers: List[List[LowFidScore]] = []
    while remaining:
        layer = [s for s in remaining
                 if not any(_eps_dominates(o.objectives, s.objectives,
                                           eps)
                            for o in remaining if o is not s)]
        if not layer:                    # cannot happen (minima stay);
            layer = list(remaining)      # guard against degeneracy
        key = {id(s) for s in layer}
        remaining = [s for s in remaining if id(s) not in key]
        layer.sort(key=lambda s: (s.objectives[0], s.objectives[1],
                                  s.point.name))
        layers.append(layer)
    return layers


# ---------------------------------------------------------------------------
# The strategy loop harness
# ---------------------------------------------------------------------------


class _Harness:
    """Budget bookkeeping + trajectory recording shared by all
    strategies."""

    def __init__(self, evaluator: TwoFidelityEvaluator,
                 budget: SearchBudget, obs=None):
        self.ev = evaluator
        self.budget = budget
        self.obs = obs
        self.run = StrategyRun()
        self._confirmed_names: set = set()

    @property
    def remaining(self) -> int:
        return self.budget.max_high_evals - self.ev.high_evals

    def confirm(self, points, label: str) -> List[PointRecord]:
        """Confirm up to ``remaining`` new points; record the rung and
        the best-so-far trajectory sample."""
        new = [p for p in points if p.name not in self._confirmed_names]
        new = new[:max(self.remaining, 0)]
        if not new:
            return []
        recs = self.ev.high_fid(new, label=label)
        fresh_recs = [r for r in recs
                      if r.point.name not in self._confirmed_names]
        for r in fresh_recs:
            self._confirmed_names.add(r.point.name)
        self.run.confirmed.extend(fresh_recs)
        self.run.rungs.append({"rung": label,
                               "requested": len(new),
                               "high_evals": self.ev.high_evals,
                               "low_evals": self.ev.low_evals})
        best = self.run.best(self.ev)
        entry = {"high_evals": self.ev.high_evals,
                 "best_point": best.point.name if best else None,
                 "best_mix_cycles": round(
                     self.ev.objectives(best)[0], 3) if best else None,
                 "front_size": len(self.run.front(self.ev))}
        self.run.trajectory.append(entry)
        if self.obs is not None and self.obs.enabled:
            m = self.obs.metrics
            m.counter("dse.search.confirmations").inc(len(new))
            if best is not None:
                m.gauge("dse.search.best_mix_cycles").set(
                    entry["best_mix_cycles"])
        return fresh_recs

    def front_names(self) -> set:
        return {r.point.name for r in self.run.front(self.ev)}


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


def _screen(sampler: CandidateSampler, evaluator: TwoFidelityEvaluator,
            budget: SearchBudget) -> List[List[LowFidScore]]:
    """Draw the candidate pool and ε-peel its analytic scores."""
    pool = sampler.draw(budget.pool_size(sampler.grid_size))
    scores = evaluator.low_fid(pool)
    return eps_peel(scores, budget.eps)


def random_search(sampler: CandidateSampler,
                  evaluator: TwoFidelityEvaluator,
                  budget: SearchBudget, rng: random.Random,
                  obs=None) -> StrategyRun:
    """One-shot screened random search: a uniform feasible pool,
    analytically scored, and the single most promising slice (the
    ε-relaxed front, then following layers) confirmed up to budget.
    The baseline every adaptive strategy must beat."""
    h = _Harness(evaluator, budget, obs=obs)
    layers = _screen(sampler, evaluator, budget)
    flat = [s.point for layer in layers for s in layer]
    h.confirm(flat[:budget.max_high_evals], label="screen")
    return h.run


def successive_halving(sampler: CandidateSampler,
                       evaluator: TwoFidelityEvaluator,
                       budget: SearchBudget, rng: random.Random,
                       obs=None) -> StrategyRun:
    """Rung-by-rung confirmation of the ε-peeled layers: rung 0 is the
    relaxed analytic front (cheap rank → expensive confirmation of
    survivors only), each further rung the next layer. Stops when the
    budget is spent or a whole rung fails to move the confirmed Pareto
    front (deeper layers are est-dominated by *two* margins — they
    cannot plausibly improve it)."""
    h = _Harness(evaluator, budget, obs=obs)
    layers = _screen(sampler, evaluator, budget)
    for i, layer in enumerate(layers):
        if h.remaining <= 0:
            break
        before = h.front_names()
        added = h.confirm([s.point for s in layer], label=f"rung{i}")
        if i > 0 and added and h.front_names() == before:
            break
    return h.run


def evolutionary(sampler: CandidateSampler,
                 evaluator: TwoFidelityEvaluator,
                 budget: SearchBudget, rng: random.Random,
                 obs=None) -> StrategyRun:
    """A (μ+λ) loop over the confirmed front: the initial population
    seeds from the analytic ε-front (plus best-estimate fill), and each
    generation mutates/crosses parents drawn from the confirmed Pareto
    front, screening children analytically before spending sims.
    Revisited children are free (evaluator memo + point cache)."""
    h = _Harness(evaluator, budget, obs=obs)
    layers = _screen(sampler, evaluator, budget)
    flat = [s for layer in layers for s in layer]
    # seed with the whole relaxed analytic front (every candidate the
    # estimator can't rule out), topped up to `population` from the
    # next layers; confirm() truncates to the budget
    n_init = max(budget.population,
                 len(layers[0]) if layers else 0)
    h.confirm([s.point for s in flat[:n_init]], label="init")

    for gen in range(budget.generations):
        if h.remaining <= 0:
            break
        parents = [r.point for r in h.run.front(evaluator)]
        if not parents:
            break
        children: List = []
        child_names = set()
        # λ = population offspring attempts per generation
        for _ in range(budget.population):
            if len(parents) >= 2 and rng.random() < 0.5:
                p1, p2 = rng.sample(parents, 2)
                child = sampler.crossover(p1, p2)
            else:
                child = sampler.mutate(rng.choice(parents))
            if child is None or child.name in child_names \
                    or child.name in h._confirmed_names:
                continue
            child_names.add(child.name)
            children.append(child)
        if not children:
            break
        scored = evaluator.low_fid(children)
        viable = sorted((s for s in scored if s.feasible),
                        key=lambda s: (s.objectives[0],
                                       s.objectives[1], s.point.name))
        if not viable:
            continue
        added = h.confirm([s.point for s in viable],
                          label=f"gen{gen}")
        if not added:
            break
    return h.run


STRATEGIES: Dict[str, object] = {
    "random": random_search,
    "successive_halving": successive_halving,
    "evolutionary": evolutionary,
}
