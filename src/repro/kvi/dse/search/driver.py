"""The search driver: wire sampler + evaluator + strategy, report.

:func:`run_search` is what the ``python -m repro.kvi.dse search`` CLI
and the bench harness call. It owns the run-level policy the pieces
deliberately don't:

  * **seeding** — one ``random.Random(seed)`` feeds the sampler and
    the strategy; nothing else in the stack touches randomness, so a
    (space, strategy, seed, budget) tuple fully determines the search.
  * **executor lifecycle** — confirmation batches are small and
    repeated, so ``auto`` resolves once for the whole search (serial
    under :data:`~repro.kvi.dse.executors.AUTO_SERIAL_MAX` budgeted
    sims, a *persistent* process pool above it — one spawn amortized
    over every rung) instead of per-batch like the exhaustive sweep.
  * **the exhaustive yardstick** — in smoke/validation runs it
    confirms the remaining grid afterwards (through the same evaluator,
    so the shared point cache makes the overlap free) and scores the
    search's front-recovery fraction against the true Pareto front.

Artifacts (with ``out_dir``): ``dse_search.json`` (full),
``dse_search_canonical.json`` (volatile-scrubbed bytes — what the CI
determinism gate diffs), ``dse_search.md``,
``dse_search_trajectory.svg`` and ``BENCH_kvi_search.json``.
"""
from __future__ import annotations

import json
import os
import random
import time
from typing import Callable, Dict, Optional

from repro.kvi.dse.executors import (AUTO_SERIAL_MAX, ProcessExecutor,
                                     SerialExecutor, SweepExecutor,
                                     ThreadExecutor)
from repro.kvi.dse.pareto import pareto_front
from repro.kvi.dse.search.evaluator import TwoFidelityEvaluator
from repro.kvi.dse.search.result import SearchResult, front_recovery
from repro.kvi.dse.search.sampler import CandidateSampler
from repro.kvi.dse.search.strategies import (DEFAULT_EPS, STRATEGIES,
                                             SearchBudget)
from repro.kvi.dse.space import DesignSpace, SpaceConstraints

#: default high-fidelity budget: half the grid (the acceptance bar the
#: strategies must beat), floored for tiny spaces and capped so big
#: synthetic spaces don't turn "auto-tune" back into "enumerate".
MAX_DEFAULT_BUDGET = 64


def default_budget(grid: int) -> int:
    return min(MAX_DEFAULT_BUDGET, max(8, (grid + 1) // 2))


def _resolve_executor(spec, budget: int, max_workers: int):
    """(executor instance or None, owned) — resolved once per search.
    Strings mirror the sweep CLI's choices; ``auto`` keys off the
    *total* sim budget, and the process choice is persistent so rung
    after rung reuses one worker pool."""
    if isinstance(spec, SweepExecutor):
        return spec, False
    if spec in (None, "auto"):
        if budget < AUTO_SERIAL_MAX:
            return SerialExecutor(), True
        return ProcessExecutor(max_workers=max_workers,
                               persistent=True), True
    if spec == "process":
        return ProcessExecutor(max_workers=max_workers,
                               persistent=True), True
    if spec == "thread":
        return ThreadExecutor(max_workers=max_workers), True
    if spec == "serial":
        return SerialExecutor(), True
    raise ValueError(f"unknown executor {spec!r}")


def run_search(strategy: str = "successive_halving",
               smoke: bool = False, seed: int = 0,
               budget: Optional[int] = None,
               pool: Optional[int] = None,
               eps: float = DEFAULT_EPS,
               population: int = 12, generations: int = 8,
               space: Optional[DesignSpace] = None,
               constraints: Optional[SpaceConstraints] = None,
               weights: Optional[Dict[str, float]] = None,
               kernel_factory=None,
               compare_exhaustive: Optional[bool] = None,
               emit: Optional[Callable[[str], None]] = None,
               out_dir: Optional[str] = None,
               max_workers: int = 4,
               executor=None, cache=None, obs=None) -> SearchResult:
    """Search ``space`` for the best design under ``budget``
    cycle-accurate evaluations; returns a :class:`SearchResult`.

    ``compare_exhaustive`` (default: on for smoke runs, off otherwise)
    additionally confirms the full grid afterwards and records the
    front-recovery fraction + walltime-vs-exhaustive in the result —
    the numbers CI gates on. ``cache`` / ``executor`` / ``obs`` follow
    the exhaustive sweep's conventions."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"choose from {sorted(STRATEGIES)}")
    from repro.kvi.dse.report import (full_space, paper_kernel_factory,
                                      smoke_space)
    space_label = "custom" if space is not None \
        else ("smoke" if smoke else "full")
    space = space or (smoke_space() if smoke else full_space())
    if kernel_factory is None:
        kernel_factory = paper_kernel_factory(smoke=smoke, seed=seed)
    if compare_exhaustive is None:
        compare_exhaustive = smoke
    grid = space.grid_size
    sbudget = SearchBudget(
        max_high_evals=budget if budget is not None
        else default_budget(grid),
        pool=pool, eps=eps, population=population,
        generations=generations)

    rng = random.Random(seed)
    sampler = CandidateSampler(space, constraints=constraints, rng=rng)
    ex, owned = _resolve_executor(executor, sbudget.max_high_evals,
                                  max_workers)
    evaluator = TwoFidelityEvaluator(
        kernel_factory, weights=weights, cache=cache, executor=ex,
        max_workers=max_workers, emit=emit, obs=obs)
    try:
        t0 = time.perf_counter()
        run = STRATEGIES[strategy](sampler, evaluator, sbudget, rng,
                                   obs=obs)
        search_wall = time.perf_counter() - t0
        # snapshot before the (optional) exhaustive yardstick inflates
        # the counters — these are the search's own numbers
        evaluations: Dict[str, object] = dict(evaluator.stats)
        evaluations["sampler"] = sampler.stats

        best = run.best(evaluator)
        front = run.front(evaluator)
        meta: Dict[str, object] = {
            "space": space_label,
            "smoke": smoke,
            "grid_size": grid,
            "budget": sbudget.as_dict(),
            "walltime_s": round(search_wall, 3),
            "executor": type(ex).__name__ if ex is not None else "auto",
        }
        if weights:
            meta["weights"] = dict(weights)
        if constraints is not None:
            meta["constraints"] = constraints.as_dict()
        meta["front_metrics"] = {
            r.point.name: [round(v, 3)
                           for v in evaluator.objectives(r)]
            for r in front}

        if compare_exhaustive:
            t1 = time.perf_counter()
            evaluator.high_fid(list(space.points()),
                               label="exhaustive")
            exhaustive_wall = time.perf_counter() - t1
            ok = [r for r in evaluator.confirmed.values() if r.ok]
            true_front = pareto_front(ok, key=evaluator.objectives)
            recovery = front_recovery(
                [evaluator.objectives(r) for r in front],
                [evaluator.objectives(r) for r in true_front])
            meta["recovery"] = {
                "front_recovery": round(recovery, 6),
                "exhaustive_front_size": len(true_front),
                "search_front_size": len(front),
                "walltime_s": round(exhaustive_wall, 3),
            }
            if emit:
                emit(f"search[{strategy}] recovered {recovery:.0%} of "
                     f"the exhaustive front with "
                     f"{evaluations['high_evals']}/{grid} sims")

        if cache is not None:
            meta["point_cache"] = cache.stats
        result = SearchResult(strategy=strategy, seed=seed, best=best,
                              front=front, trajectory=run.trajectory,
                              rungs=run.rungs,
                              evaluations=evaluations, meta=meta)
        if obs is not None and obs.enabled:
            m = obs.metrics
            m.counter("dse.search.low_evals").inc(
                evaluations["low_evals"])
            m.counter("dse.search.high_evals").inc(
                evaluations["high_evals"])
            m.gauge("dse.search.front_size").set(len(front))

        if out_dir is not None:
            _write_artifacts(result, out_dir, emit=emit)
        return result
    finally:
        if owned and ex is not None:
            ex.close()


def _write_artifacts(result: SearchResult, out_dir: str,
                     emit=None) -> None:
    from repro.kvi.dse.plots import write_search_plots
    os.makedirs(out_dir, exist_ok=True)
    result.save_json(os.path.join(out_dir, "dse_search.json"))
    with open(os.path.join(out_dir, "dse_search_canonical.json"),
              "w") as f:
        f.write(result.canonical_json() + "\n")
    wrote_svg = write_search_plots(result, out_dir)
    with open(os.path.join(out_dir, "dse_search.md"), "w") as f:
        f.write(result.to_markdown())
    # cross-link: if the exhaustive sweep's report already lives here,
    # append the trajectory section it would have added itself had the
    # search run first (idempotent — skip when already linked)
    report_md = os.path.join(out_dir, "dse_report.md")
    if wrote_svg and os.path.exists(report_md):
        from repro.kvi.dse.report import SEARCH_TRAJECTORY_SECTION
        with open(report_md) as f:
            body = f.read()
        if "dse_search_trajectory.svg" not in body:
            with open(report_md, "a") as f:
                f.write(SEARCH_TRAJECTORY_SECTION)
    bench = {
        "strategy": result.strategy,
        "seed": result.seed,
        "grid_size": result.meta.get("grid_size"),
        "evaluations": dict(result.evaluations),
        "exhaustive_fraction": result.exhaustive_fraction,
        "best": result.best.point.name if result.best else None,
        "front_size": len(result.front),
        "walltime_s": result.meta.get("walltime_s"),
        "rungs": list(result.rungs),
    }
    rec = result.meta.get("recovery")
    if rec:
        bench["front_recovery"] = rec["front_recovery"]
        bench["exhaustive_front_size"] = rec["exhaustive_front_size"]
        bench["exhaustive_walltime_s"] = rec["walltime_s"]
    pc = result.meta.get("point_cache")
    if pc:
        bench["point_cache"] = pc
    with open(os.path.join(out_dir, "BENCH_kvi_search.json"),
              "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
    if emit:
        emit(f"search artifacts written to {out_dir}")
