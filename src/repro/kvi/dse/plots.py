"""SVG figures rendered next to ``dse_report.md``.

Two figures per kernel, both drawn with the stdlib-only chart writer
(:mod:`repro.kvi.obs.svg` — no matplotlib dependency, byte-stable
output):

  * ``dse_speedup_<kernel>.svg`` — the paper's speedup-vs-D curves,
    one line per (scheme, precision) series, log-scaled lane axis;
  * ``dse_pareto_<kernel>.svg``  — the (area, cycles) plane, one
    scatter series per scheme with the report's Pareto front overlaid
    as a staircase line.

:func:`write_plots` returns ``{kernel: [filenames]}`` so the markdown
renderer can link every figure from the matching section.
"""
from __future__ import annotations

import os
from typing import Dict, List

from repro.kvi.obs.svg import line_chart, scatter_chart


def write_search_plots(result, out_dir: str) -> List[str]:
    """``dse_search_trajectory.svg`` — the auto-tuner's best-so-far
    workload-mix cycles against cycle-accurate evaluations spent, the
    anytime curve that shows what each additional simulation bought.
    Returns the written filenames (empty when the trajectory never
    produced a feasible best)."""
    points = [(t["high_evals"], float(t["best_mix_cycles"]))
              for t in result.trajectory
              if t.get("best_mix_cycles") is not None]
    if not points:
        return []
    svg = line_chart(
        f"{result.strategy} (seed {result.seed}): best-so-far",
        "cycle-accurate evaluations",
        "best workload-mix cycles",
        {result.strategy: points})
    fname = "dse_search_trajectory.svg"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(svg + "\n")
    return [fname]


def _kernel_measure(rec, kern: str):
    if kern == "composite":
        return rec.composite
    return rec.kernels.get(kern)


def write_plots(result, report: Dict[str, object],
                out_dir: str) -> Dict[str, List[str]]:
    """Write every figure for ``report`` into ``out_dir``; returns the
    per-kernel filename lists (relative to ``out_dir``, ready to embed
    as markdown image links)."""
    ok = result.ok_records
    plots: Dict[str, List[str]] = {}
    for kern, data in report["kernels"].items():
        files: List[str] = []

        curves = data.get("speedup_vs_lanes") or {}
        if curves:
            series = {
                label: [(int(d[1:]), s) for d, s in by_d.items()]
                for label, by_d in sorted(curves.items())}
            svg = line_chart(f"{kern}: speedup vs lane count D",
                             "D (vector lanes, log)",
                             "speedup vs smallest swept D",
                             series, log_x=True)
            fname = f"dse_speedup_{kern}.svg"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(svg + "\n")
            files.append(fname)

        by_scheme: Dict[str, List[tuple]] = {}
        for r in ok:
            k = _kernel_measure(r, kern)
            if k is None:
                continue
            by_scheme.setdefault(r.point.scheme, []).append(
                (r.area.area_luteq, int(k["cycles"])))
        front = [(row["area_luteq"], row["cycles"])
                 for row in data.get("front") or []]
        if by_scheme:
            svg = scatter_chart(f"{kern}: cycles vs area",
                                "area (LUT-equivalents)",
                                "cycles",
                                {s: by_scheme[s]
                                 for s in sorted(by_scheme)},
                                front=front or None)
            fname = f"dse_pareto_{kern}.svg"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(svg + "\n")
            files.append(fname)

        if files:
            plots[kern] = files
    return plots
