"""repro.kvi.dse — design-space exploration over coprocessor configs.

The paper's analysis, reproducible end to end:

  1. :mod:`~repro.kvi.dse.space` — declare the grid (scheme x M x F x
     D x sub-word precision x SPM capacity x pass toggles) as a
     :class:`DesignSpace`; enumeration is deterministic and validated.
  2. :mod:`~repro.kvi.dse.cost` — analytic LUT/FF/DSP/BRAM area and
     energy-per-cycle for any :class:`KlessydraConfig` (one documented
     calibration table).
  3. :mod:`~repro.kvi.dse.sweep` — fan design points out through
     ``CycleSimBackend.run_workload`` (homogeneous + composite
     protocols), recording cycles, per-hart utilization, area, energy.
  4. :mod:`~repro.kvi.dse.pareto` / :mod:`~repro.kvi.dse.report` —
     non-dominated front over (cycles, area, energy), speedup-vs-D
     curves, and the paper's scheme-ordering story as checks.

Enumeration has a budget-constrained inverse:
:mod:`~repro.kvi.dse.search` *searches* the same space — analytic
ranking (:func:`~repro.kvi.dse.cost.estimate_kernel`) screens sampled
candidates, and only survivors spend cycle-accurate simulations.

CLI::

    PYTHONPATH=src python -m repro.kvi.dse --smoke   # CI-sized sweep
    PYTHONPATH=src python -m repro.kvi.dse           # paper-scale sweep
    PYTHONPATH=src python -m repro.kvi.dse search --smoke  # auto-tuner
"""
from repro.kvi.dse.cost import (CALIBRATION, CALIBRATION_FIT_MAX_REL_ERR,
                                HardwareCost, KernelProfile,
                                calibration_fit, energy_model,
                                estimate_kernel, hardware_cost,
                                kernel_profile)
from repro.kvi.dse.executors import (AUTO_SERIAL_MAX, EXECUTORS, PointJob,
                                     ProcessExecutor, SerialExecutor,
                                     SweepExecutor, ThreadExecutor,
                                     make_executor, resolve_auto)
from repro.kvi.dse.pareto import dominates, front_metrics, pareto_front
from repro.kvi.dse.pointcache import (PointCache, default_cache_dir,
                                      pallas_class_key, point_key,
                                      program_fingerprint)
from repro.kvi.dse.report import (build_report, full_space, render_markdown,
                                  run_dse, smoke_space)
from repro.kvi.dse.space import (SCHEMES, DesignPoint, DesignSpace,
                                 SpaceConstraints, preflight_point,
                                 scheme_config)
from repro.kvi.dse.search import (STRATEGIES, CandidateSampler,
                                  SearchBudget, SearchResult,
                                  TwoFidelityEvaluator, front_recovery,
                                  run_search)
from repro.kvi.dse.sweep import (PointRecord, SweepResult,
                                 measure_pallas_points,
                                 paper_kernel_factory, run_point, sweep)

__all__ = [
    "STRATEGIES", "CandidateSampler", "SearchBudget", "SearchResult",
    "TwoFidelityEvaluator", "front_recovery", "run_search",
    "CALIBRATION", "CALIBRATION_FIT_MAX_REL_ERR", "HardwareCost",
    "KernelProfile", "calibration_fit", "energy_model",
    "estimate_kernel", "hardware_cost", "kernel_profile",
    "AUTO_SERIAL_MAX", "EXECUTORS", "PointJob", "ProcessExecutor",
    "SerialExecutor", "SweepExecutor", "ThreadExecutor", "make_executor",
    "resolve_auto", "PointCache", "default_cache_dir", "pallas_class_key",
    "point_key", "program_fingerprint",
    "dominates", "front_metrics", "pareto_front", "build_report",
    "full_space", "render_markdown", "run_dse", "smoke_space", "SCHEMES",
    "DesignPoint", "DesignSpace", "SpaceConstraints", "preflight_point",
    "scheme_config",
    "PointRecord", "SweepResult", "measure_pallas_points",
    "paper_kernel_factory", "run_point", "sweep",
]
