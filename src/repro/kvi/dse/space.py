"""Declarative design space over Klessydra-T coprocessor configurations.

The paper's contribution is not one configuration but a *sweep*: SPM
interface replication (M), MFU replication (F), lane width (D) and
sub-word precision across the shared / symmetric-MIMD / heterogeneous-
MIMD interconnection schemes, each judged on cycles, hardware cost and
energy. A :class:`DesignSpace` declares that grid once; its deterministic
:meth:`~DesignSpace.points` enumeration feeds the sweep driver
(:mod:`repro.kvi.dse.sweep`), the cost model (:mod:`repro.kvi.dse.cost`)
and the Pareto analysis (:mod:`repro.kvi.dse.pareto`).

A :class:`DesignPoint` couples the *data* precision of the workload to
the *hardware* sub-word capability: an 8-bit point runs 8-bit programs
on a datapath with full sub-word lanes (``subword_bits=8``), while a
32-bit point carries no sub-word hardware at all — so the precision axis
trades real area against real cycles, exactly the SPEED-style
multi-precision trade-off.

Invalid combinations are rejected eagerly (``ValueError`` naming the
field/axis); SPM-capacity feasibility against a concrete workload is a
separate *preflight* (:func:`preflight_point`) reusing the lowering
allocator's :class:`~repro.kvi.lowering.SpmOverflowError` check.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.configs.base import KlessydraConfig

SCHEMES = ("shared", "sym_mimd", "het_mimd")

_VALID_PRECISIONS = (8, 16, 32)


def scheme_config(scheme: str, D: int = 4, spm_kbytes: int = 64,
                  M: int = 3, F: Optional[int] = None,
                  subword_bits: int = 8,
                  fu_counts: Tuple[Tuple[str, int], ...] = (),
                  name: Optional[str] = None, **kw) -> KlessydraConfig:
    """One scheme name -> a validated :class:`KlessydraConfig`.

    ``M`` is the SPMI replication of the MIMD schemes (the shared scheme
    always has M=F=1); ``F`` overrides the heterogeneous scheme's MFU
    count (default 1, the paper's configuration)."""
    if scheme == "shared":
        m, f = 1, 1
    elif scheme == "sym_mimd":
        m, f = M, M
    elif scheme == "het_mimd":
        m, f = M, 1 if F is None else F
    else:
        raise ValueError(f"unknown scheme {scheme!r}; valid: {SCHEMES}")
    return KlessydraConfig(name or scheme, M=m, F=f, D=D,
                           spm_kbytes=spm_kbytes,
                           subword_bits=subword_bits,
                           fu_counts=fu_counts, **kw)


@dataclass(frozen=True)
class DesignPoint:
    """One fully-specified coprocessor configuration + workload precision
    + per-point pass toggles — the unit the sweep executes."""

    scheme: str
    M: int
    F: int
    D: int
    precision_bits: int = 32
    spm_kbytes: int = 64
    chaining: bool = False
    fu_counts: Tuple[Tuple[str, int], ...] = ()
    # None -> the backend's default optimizing pipeline; () -> raw
    # programs; a tuple of registered pass names -> custom pipeline.
    passes: Optional[Tuple[str, ...]] = None
    # Opt-in Pallas walltime measurement: the sweep additionally batches
    # this point's programs through PallasBackend.run_workload and
    # records real walltime + compiled pallas_call counts. A measurement
    # mode, not a hardware axis — it does not enter the point's name.
    measure_pallas: bool = False

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"DesignPoint: scheme must be one of "
                             f"{SCHEMES}, got {self.scheme!r}")
        if self.scheme == "shared" and (self.M != 1 or self.F != 1):
            raise ValueError(f"DesignPoint: shared scheme requires "
                             f"M=F=1, got M={self.M} F={self.F}")
        if self.scheme == "sym_mimd" and (self.M < 2 or self.F != self.M):
            raise ValueError(f"DesignPoint: sym_mimd requires F=M>=2, "
                             f"got M={self.M} F={self.F}")
        if self.scheme == "het_mimd" and not (1 <= self.F < self.M):
            raise ValueError(f"DesignPoint: het_mimd requires "
                             f"1 <= F < M, got M={self.M} F={self.F}")
        if self.precision_bits not in _VALID_PRECISIONS:
            raise ValueError(f"DesignPoint: precision_bits must be one "
                             f"of {_VALID_PRECISIONS}, got "
                             f"{self.precision_bits}")
        # config construction validates D / spm_kbytes / fu_counts and
        # raises the field-naming ValueError itself
        self.config()

    @property
    def elem_bytes(self) -> int:
        return self.precision_bits // 8

    @property
    def name(self) -> str:
        n = (f"{self.scheme}_M{self.M}F{self.F}_D{self.D}"
             f"_b{self.precision_bits}_spm{self.spm_kbytes}")
        if self.chaining:
            n += "_chain"
        if self.passes == ():
            n += "_raw"
        elif self.passes is not None:
            n += "_p" + "-".join(self.passes)
        if self.fu_counts:
            n += "_fu" + "-".join(f"{u}{c}" for u, c in self.fu_counts)
        return n

    def canonical_dict(self) -> dict:
        """JSON-native identity of the point for content-addressed
        caching (:mod:`repro.kvi.dse.pointcache`): every field that can
        change a measurement. ``measure_pallas`` is deliberately
        excluded — it is a measurement *mode* (Pallas results cache
        under their own class key), not a hardware axis — and ``name``
        is derived, so it is excluded too."""
        return {"scheme": self.scheme, "M": self.M, "F": self.F,
                "D": self.D, "precision_bits": self.precision_bits,
                "spm_kbytes": self.spm_kbytes,
                "chaining": bool(self.chaining),
                "fu_counts": [[u, c] for u, c in self.fu_counts],
                "passes": list(self.passes)
                if self.passes is not None else None}

    def config(self) -> KlessydraConfig:
        """The concrete machine: hardware sub-word support matches the
        point's data precision (a 32-bit point carries no sub-word
        lanes; an 8-bit point carries the full splitters)."""
        return scheme_config(self.scheme, D=self.D,
                             spm_kbytes=self.spm_kbytes, M=self.M,
                             F=self.F, subword_bits=self.precision_bits,
                             fu_counts=self.fu_counts, name=self.name)


@dataclass(frozen=True)
class DesignSpace:
    """A declarative grid over design points. Axes are tuples; the
    product (restricted to scheme-consistent combinations) is the swept
    space. Enumeration order is deterministic: axes iterate in declared
    order, nested scheme -> M -> F -> D -> precision -> spm -> chaining
    -> pipeline -> fu_counts."""

    schemes: Tuple[str, ...] = SCHEMES
    lanes: Tuple[int, ...] = (2, 4, 8, 16)            # D axis
    precisions: Tuple[int, ...] = (8, 16, 32)         # sub-word bits
    spm_kbytes: Tuple[int, ...] = (64,)
    chaining: Tuple[bool, ...] = (False,)
    replication: Tuple[int, ...] = (3,)               # M axis (MIMD)
    het_fus: Tuple[int, ...] = (1,)                   # F axis (het only)
    pipelines: Tuple[Optional[Tuple[str, ...]], ...] = (None,)
    fu_counts: Tuple[Tuple[Tuple[str, int], ...], ...] = ((),)

    def __post_init__(self):
        def bad(axis: str, why: str):
            raise ValueError(f"DesignSpace: axis {axis!r} {why}")
        for axis in ("schemes", "lanes", "precisions", "spm_kbytes",
                     "chaining", "replication", "het_fus", "pipelines",
                     "fu_counts"):
            if not getattr(self, axis):
                bad(axis, "must be non-empty")
        for s in self.schemes:
            if s not in SCHEMES:
                bad("schemes", f"contains unknown scheme {s!r} "
                               f"(valid: {SCHEMES})")
        for p in self.precisions:
            if p not in _VALID_PRECISIONS:
                bad("precisions", f"contains {p}; valid: "
                                  f"{_VALID_PRECISIONS}")
        for d in self.lanes:
            if d < 1 or (d & (d - 1)):
                bad("lanes", f"must contain powers of two >= 1 "
                             f"(SPM bank counts), got {d}")
        for s in self.spm_kbytes:
            if s < 1:
                bad("spm_kbytes", f"must be >= 1 KiB, got {s}")
        for m in self.replication:
            if m < 2:
                bad("replication", f"MIMD replication must be >= 2, "
                                   f"got {m}")
        for f in self.het_fus:
            if f < 1:
                bad("het_fus", f"must be >= 1, got {f}")

    def _mf_pairs(self, scheme: str) -> List[Tuple[int, int]]:
        """The scheme-consistent (M, F) combinations of this space."""
        if scheme == "shared":
            return [(1, 1)]
        if scheme == "sym_mimd":
            return [(m, m) for m in self.replication]
        return [(m, f) for m in self.replication
                for f in self.het_fus if f < m]

    def _scheme_fus(self, scheme: str) -> tuple:
        """The fu_counts axis applies to het-MIMD only (see points())."""
        return self.fu_counts if scheme == "het_mimd" else ((),)

    @property
    def grid_size(self) -> int:
        """Number of grid cells WITHOUT enumerating them — the product
        of the per-scheme sub-grids. Equals ``len(self.points())`` when
        the axes carry no duplicate values (points() dedups by name)."""
        inner = (len(self.lanes) * len(self.precisions)
                 * len(self.spm_kbytes) * len(self.chaining)
                 * len(self.pipelines))
        return sum(len(self._mf_pairs(s)) * inner * len(self._scheme_fus(s))
                   for s in self.schemes)

    def point_at(self, index: int) -> DesignPoint:
        """Decode flat ``index`` (mixed-radix over the axes, in exactly
        the :meth:`points` nesting order) into a :class:`DesignPoint` —
        O(1) random access into the grid without materializing it. The
        lazy primitive :class:`~repro.kvi.dse.search.CandidateSampler`
        draws from: ``space.point_at(rng.randrange(space.grid_size))``
        is a uniform sample of the grid."""
        if index < 0:
            raise IndexError(f"point_at: negative index {index}")
        i = index
        for scheme in self.schemes:
            mf_pairs = self._mf_pairs(scheme)
            fus = self._scheme_fus(scheme)
            block = (len(mf_pairs) * len(self.lanes)
                     * len(self.precisions) * len(self.spm_kbytes)
                     * len(self.chaining) * len(self.pipelines)
                     * len(fus))
            if i >= block:
                i -= block
                continue
            # innermost axis varies fastest, mirroring points() nesting
            i, fu_i = divmod(i, len(fus))
            i, pipe_i = divmod(i, len(self.pipelines))
            i, ch_i = divmod(i, len(self.chaining))
            i, spm_i = divmod(i, len(self.spm_kbytes))
            i, prec_i = divmod(i, len(self.precisions))
            mf_i, d_i = divmod(i, len(self.lanes))
            m, f = mf_pairs[mf_i]
            return DesignPoint(scheme, m, f, self.lanes[d_i],
                               self.precisions[prec_i],
                               self.spm_kbytes[spm_i],
                               self.chaining[ch_i], fus[fu_i],
                               self.pipelines[pipe_i])
        raise IndexError(f"point_at: index {index} out of range for a "
                         f"{self.grid_size}-cell grid")

    def points(self) -> Tuple[DesignPoint, ...]:
        """Deterministic enumeration of all valid design points.
        Scheme-inconsistent combinations (e.g. het F >= M) are skipped;
        the shared scheme collapses the M axis (always M=F=1), and the
        ``fu_counts`` axis applies to het-MIMD only — the simulator
        contends internal FU instances solely in the heterogeneous
        scheme (shared/sym arbitrate whole MFUs), so replicated-unit
        points for the other schemes would pay area for provably
        identical cycles: always dominated, never informative."""
        out: List[DesignPoint] = []
        seen = set()
        for scheme in self.schemes:
            mf_pairs = self._mf_pairs(scheme)
            fus = self._scheme_fus(scheme)
            for m, f in mf_pairs:
                for d in self.lanes:
                    for prec in self.precisions:
                        for spm in self.spm_kbytes:
                            for ch in self.chaining:
                                for pipe in self.pipelines:
                                    for fu in fus:
                                        pt = DesignPoint(
                                            scheme, m, f, d, prec, spm,
                                            ch, fu, pipe)
                                        if pt.name not in seen:
                                            seen.add(pt.name)
                                            out.append(pt)
        return tuple(out)

    @property
    def size(self) -> int:
        return len(self.points())


@dataclass(frozen=True)
class SpaceConstraints:
    """Budget / axis predicates a candidate must satisfy *before* any
    simulation — what turns a grid into a constrained design question
    ("the best config under this area budget"). Every check here is
    closed-form over the analytic cost model, so feasibility of
    thousands of candidates per second is practical; workload-dependent
    checks (SPM fit, measured energy) belong to the search evaluator.

      * ``max_area_luteq`` — hardware area budget (LUT-equivalents,
        :func:`repro.kvi.dse.cost.hardware_cost`),
      * ``max_static_nj_per_cycle`` — static-power budget
        (:func:`repro.kvi.dse.cost.energy_per_cycle_static`),
      * ``schemes`` / ``max_lanes`` / ``precisions`` — axis filters,
      * ``predicate`` — an arbitrary extra ``point -> bool`` (must be a
        deterministic pure function; it enters no cache key).
    """

    max_area_luteq: Optional[float] = None
    max_static_nj_per_cycle: Optional[float] = None
    schemes: Optional[Tuple[str, ...]] = None
    max_lanes: Optional[int] = None
    precisions: Optional[Tuple[int, ...]] = None
    predicate: Optional[Callable[[DesignPoint], bool]] = None

    def reject_reason(self, point: DesignPoint) -> Optional[str]:
        """Why ``point`` is infeasible, or ``None`` when it satisfies
        every constraint. Axis filters run first (no cost-model work);
        the area/energy budgets evaluate the analytic model."""
        if self.schemes is not None and point.scheme not in self.schemes:
            return f"scheme {point.scheme!r} excluded"
        if self.max_lanes is not None and point.D > self.max_lanes:
            return f"D={point.D} exceeds max_lanes={self.max_lanes}"
        if self.precisions is not None \
                and point.precision_bits not in self.precisions:
            return f"precision {point.precision_bits} excluded"
        if self.predicate is not None and not self.predicate(point):
            return "predicate rejected"
        if self.max_area_luteq is not None \
                or self.max_static_nj_per_cycle is not None:
            from repro.kvi.dse.cost import (energy_per_cycle_static,
                                            hardware_cost)
            cfg = point.config()
            if self.max_area_luteq is not None:
                area = hardware_cost(cfg).area_luteq
                if area > self.max_area_luteq:
                    return (f"area {area:.0f} LUTeq exceeds budget "
                            f"{self.max_area_luteq:.0f}")
            if self.max_static_nj_per_cycle is not None:
                nj = energy_per_cycle_static(cfg)
                if nj > self.max_static_nj_per_cycle:
                    return (f"static {nj:.3f} nJ/cycle exceeds budget "
                            f"{self.max_static_nj_per_cycle:.3f}")
        return None

    def feasible(self, point: DesignPoint) -> bool:
        return self.reject_reason(point) is None

    def as_dict(self) -> dict:
        """JSON-native view for search reports (``predicate`` is
        surfaced only as a presence flag — it has no canonical form)."""
        return {"max_area_luteq": self.max_area_luteq,
                "max_static_nj_per_cycle": self.max_static_nj_per_cycle,
                "schemes": list(self.schemes)
                if self.schemes is not None else None,
                "max_lanes": self.max_lanes,
                "precisions": list(self.precisions)
                if self.precisions is not None else None,
                "has_predicate": self.predicate is not None}


def preflight_point(point: DesignPoint, programs: Sequence,
                    trace_cache=None) -> Optional[str]:
    """SPM-capacity feasibility of ``point`` for a set of programs,
    checked in two stages:

    1. the **static** SPM-pressure estimate
       (:func:`repro.kvi.analysis.spm_pressure` — the analyzer's KVI301
       check) rejects over-pressure programs without touching the
       allocator or the trace cache,
    2. programs that pass run through the lowering allocator's
       liveness-based linear scan (the same code path the real
       execution takes), surfacing any residual
       :class:`~repro.kvi.lowering.SpmOverflowError` message.

    The static estimate reuses the allocator's own liveness peak with
    the allocator's exact line rounding, so the two stages agree; the
    second stage exists to warm the :class:`~repro.kvi.lowering.
    TraceCache` (each program lowers timing-only *into the cache*, so
    the execution that follows reuses the exact traces) and as a
    belt-and-braces check that they stay in agreement.

    Returns the rejection reason of the first program that cannot be
    placed, or ``None`` when all fit."""
    from repro.kvi.analysis import spm_pressure
    from repro.kvi.lowering import SpmOverflowError, allocate_vregs
    cfg = point.config()
    for p in programs:
        pressure = spm_pressure(p, cfg)
        if not pressure.fits:
            return (f"static SPM overflow (KVI301): program "
                    f"{p.name!r} peak-live {pressure.peak_live_bytes} B "
                    f"exceeds SPM capacity {pressure.capacity_bytes} B")
        try:
            if trace_cache is not None:
                trace_cache.lower(p, cfg, chaining=point.chaining,
                                  functional=False)
            else:
                allocate_vregs(p, cfg)
        except SpmOverflowError as e:   # pragma: no cover - static
            return str(e)               # estimate should reject first
    return None
