"""The sweep driver: design points x paper kernels -> measured records.

Each :class:`~repro.kvi.dse.space.DesignPoint` is executed through
:class:`~repro.kvi.cyclesim.CycleSimBackend` exactly the way any other
caller would run it — programs go through the optimizing pass pipeline
(honoring the point's per-point ``passes`` / ``chaining`` toggles), are
lowered once per configuration (liveness-based SPM allocation,
:class:`SpmOverflowError` preflight), and the event-driven simulator
produces cycles plus the per-hart busy/stall/idle breakdown. The cost
model (:mod:`repro.kvi.dse.cost`) adds area and energy.

Points fan out over a thread pool (``max_workers``); records always
return in enumeration order, so sweeps are deterministic run-to-run.

Measured per point:
  * per kernel, the paper's homogeneous protocol — the program
    replicated on all harts (``KviWorkload.replicate``),
  * the composite protocol — one kernel pinned per hart
    (``KviWorkload.composite``), when the machine has enough harts.
"""
from __future__ import annotations

import csv
import json
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.kvi.dse.cost import HardwareCost, energy_model, hardware_cost
from repro.kvi.dse.space import (DesignPoint, DesignSpace, preflight_point)
from repro.kvi.ir import KviProgram

#: scheme-dict key under which the swept config is registered
POINT_KEY = "dse"


@dataclass
class PointRecord:
    """Everything measured for one design point."""

    point: DesignPoint
    status: str                       # "ok" | "incompatible"
    reason: Optional[str] = None
    area: Optional[HardwareCost] = None
    # kernel name -> {"cycles", "energy_nj", "nj_per_cycle",
    #                 "mfu_utilization", "hart_utilization": [...]}
    kernels: Dict[str, Dict[str, object]] = field(default_factory=dict)
    composite: Optional[Dict[str, object]] = None
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def metrics(self, kernel: str) -> Tuple[float, float, float]:
        """(cycles, area_luteq, energy_nj) — the Pareto objectives.
        ``kernel`` may be ``"composite"`` for the composite workload."""
        k = self.composite if kernel == "composite" \
            else self.kernels[kernel]
        return (float(k["cycles"]), self.area.area_luteq,
                float(k["energy_nj"]))

    def as_dict(self) -> Dict[str, object]:
        pt = self.point
        d = {"name": pt.name, "scheme": pt.scheme, "M": pt.M, "F": pt.F,
             "D": pt.D, "precision_bits": pt.precision_bits,
             "spm_kbytes": pt.spm_kbytes, "chaining": pt.chaining,
             "passes": list(pt.passes) if pt.passes is not None else None,
             "status": self.status, "wall_s": round(self.wall_s, 4)}
        if self.reason:
            d["reason"] = self.reason
        if self.area is not None:
            d["area"] = self.area.as_dict()
        if self.kernels:
            d["kernels"] = self.kernels
        if self.composite is not None:
            d["composite"] = self.composite
        return d


@dataclass
class SweepResult:
    """All records of one sweep, JSON/CSV-persistable."""

    records: List[PointRecord]
    kernel_names: Tuple[str, ...]
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def ok_records(self) -> List[PointRecord]:
        return [r for r in self.records if r.ok]

    def to_json(self) -> Dict[str, object]:
        return {"meta": dict(self.meta),
                "kernels": list(self.kernel_names),
                "points": [r.as_dict() for r in self.records]}

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    def csv_rows(self) -> List[Dict[str, object]]:
        """Flat (point x kernel) rows for spreadsheet analysis."""
        rows = []
        for r in self.records:
            if not r.ok:
                continue
            base = {"point": r.point.name, "scheme": r.point.scheme,
                    "M": r.point.M, "F": r.point.F, "D": r.point.D,
                    "precision_bits": r.point.precision_bits,
                    "spm_kbytes": r.point.spm_kbytes,
                    "chaining": int(r.point.chaining),
                    "area_luteq": round(r.area.area_luteq, 1)}
            measures = dict(r.kernels)
            if r.composite is not None:
                measures["composite"] = r.composite
            for kname, k in measures.items():
                rows.append(dict(
                    base, kernel=kname, cycles=k["cycles"],
                    energy_nj=round(float(k["energy_nj"]), 1),
                    mean_hart_utilization=round(float(np.mean(
                        [h["utilization"]
                         for h in k["hart_utilization"]])), 4)))
        return rows

    def save_csv(self, path: str) -> None:
        rows = self.csv_rows()
        if not rows:
            return
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)


def _measure(backend, workload, cfg) -> Dict[str, object]:
    res = backend.run_workload(workload, functional=False)
    sim = res.timing[POINT_KEY]
    util = res.hart_utilization[POINT_KEY]
    e = energy_model(cfg, sim)
    return {"cycles": sim.cycles,
            "energy_nj": round(e["energy_nj"], 2),
            "nj_per_cycle": round(e["nj_per_cycle"], 4),
            "mfu_utilization": round(sim.mfu_utilization, 4),
            "hart_utilization": util}


def optimize_kernels(kernels: Dict[str, KviProgram],
                     passes: Optional[Tuple[str, ...]],
                     ) -> Dict[str, KviProgram]:
    """The kernels after the pass pipeline a point with ``passes``
    would run. Split out so the sweep driver can share one optimized
    set across every point with the same (precision, passes)."""
    from repro.kvi.passes import PassPipeline
    pipe = PassPipeline.from_spec(passes)
    if not pipe:
        return kernels
    return {name: pipe.run(p) for name, p in kernels.items()}


def run_point(point: DesignPoint, kernels: Dict[str, KviProgram],
              composite: bool = True,
              preoptimized: bool = False) -> PointRecord:
    """Execute every kernel (homogeneous protocol) plus the composite
    workload on one design point; incompatible points (SPM too small for
    a kernel's peak-live footprint) are recorded, not raised.

    The point's pass pipeline runs up front (unless the caller already
    did, ``preoptimized=True``) and both the SPM preflight and the
    backend see the optimized programs — so a kernel that only fits the
    scratchpad after dce/copy_prop (the pipeline's register-reuse
    capability) is a valid design point, and the composite workload
    does not re-optimize what the homogeneous runs already did."""
    from repro.kvi.cyclesim import CycleSimBackend
    from repro.kvi.workload import KviWorkload

    t0 = time.perf_counter()
    cfg = point.config()
    if not preoptimized:
        kernels = optimize_kernels(kernels, point.passes)
    reason = preflight_point(point, list(kernels.values()))
    if reason is not None:
        return PointRecord(point, "incompatible", reason=reason,
                           wall_s=time.perf_counter() - t0)
    backend = CycleSimBackend(schemes={POINT_KEY: cfg}, passes=(),
                              chaining=point.chaining)
    rec = PointRecord(point, "ok", area=hardware_cost(cfg))
    for name, prog in kernels.items():
        wl = KviWorkload.replicate(prog, cfg.harts)
        rec.kernels[name] = _measure(backend, wl, cfg)
    if composite and cfg.harts >= len(kernels):
        wl = KviWorkload.composite(
            {h: [prog] for h, prog in enumerate(kernels.values())},
            name="composite")
        rec.composite = _measure(backend, wl, cfg)
    rec.wall_s = time.perf_counter() - t0
    return rec


KernelFactory = Callable[[int], Dict[str, KviProgram]]


def sweep(space: Union[DesignSpace, Sequence[DesignPoint]],
          kernel_factory: KernelFactory,
          composite: bool = True,
          max_workers: int = 4,
          emit: Optional[Callable[[str], None]] = None) -> SweepResult:
    """Run every point of ``space`` over the kernels the factory builds
    for that point's precision. Kernel programs are built once per
    distinct precision and shared across points (read-only)."""
    points = space.points() if isinstance(space, DesignSpace) \
        else tuple(space)
    if not points:
        raise ValueError("sweep needs at least one design point")
    kernels_by_prec: Dict[int, Dict[str, KviProgram]] = {}
    for pt in points:
        if pt.precision_bits not in kernels_by_prec:
            kernels_by_prec[pt.precision_bits] = \
                kernel_factory(pt.precision_bits)
    kernel_names = tuple(next(iter(kernels_by_prec.values())))
    # the optimized programs depend only on (precision, passes) — run
    # the pipeline once per distinct pair, not once per point
    opt_cache: Dict[tuple, Dict[str, KviProgram]] = {}
    for pt in points:
        key = (pt.precision_bits, pt.passes)
        if key not in opt_cache:
            opt_cache[key] = optimize_kernels(
                kernels_by_prec[pt.precision_bits], pt.passes)

    def job(pt: DesignPoint) -> PointRecord:
        return run_point(pt, opt_cache[(pt.precision_bits, pt.passes)],
                         composite, preoptimized=True)

    t0 = time.perf_counter()
    if max_workers and max_workers > 1:
        with ThreadPoolExecutor(max_workers=max_workers) as ex:
            records = list(ex.map(job, points))
    else:
        records = [job(pt) for pt in points]
    wall = time.perf_counter() - t0

    if emit:
        for r in records:
            if r.ok:
                cells = " ".join(
                    f"{k}={v['cycles']}" for k, v in r.kernels.items())
                emit(f"{r.point.name:42s} area={r.area.area_luteq:9.0f} "
                     f"{cells}")
            else:
                emit(f"{r.point.name:42s} SKIP ({r.reason})")
    n_ok = sum(r.ok for r in records)
    return SweepResult(
        list(records), kernel_names,
        meta={"n_points": len(points), "n_ok": n_ok,
              "n_incompatible": len(points) - n_ok,
              "schemes": sorted({p.scheme for p in points}),
              "wall_s": round(wall, 3)})


# ---------------------------------------------------------------------------
# The paper's kernel set as a precision-parameterized factory
# ---------------------------------------------------------------------------


def paper_kernel_factory(smoke: bool = False, seed: int = 0,
                         ) -> KernelFactory:
    """conv / fft / matmul at sweep-appropriate sizes. ``smoke`` shrinks
    the kernels so the whole smoke sweep finishes in seconds; data is
    drawn from ``seed`` so BENCH inputs are reproducible run-to-run.
    MatMul is forced onto the SPM-resident path at every precision so
    the precision axis compares identical instruction structures."""
    S, n_fft, m = (24, 64, 24) if smoke else (32, 256, 64)

    def factory(precision_bits: int) -> Dict[str, KviProgram]:
        from repro.kvi.programs import (conv2d_program, fft_program,
                                        matmul_program)
        eb = precision_bits // 8
        rng = np.random.default_rng(seed)
        lim = {1: 8, 2: 64, 4: 128}[eb]
        img = rng.integers(-lim, lim, (S, S)).astype(np.int32)
        filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
        A = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        B = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        re = rng.integers(-lim, lim, n_fft).astype(np.int32)
        im = rng.integers(-lim, lim, n_fft).astype(np.int32)
        return {
            "conv": conv2d_program(img, filt, shift=4, elem_bytes=eb),
            "fft": fft_program(re, im, elem_bytes=eb),
            "matmul": matmul_program(A, B, shift=2, resident=True,
                                     elem_bytes=eb),
        }

    return factory
