"""The sweep driver: design points x paper kernels -> measured records.

Each :class:`~repro.kvi.dse.space.DesignPoint` is executed through
:class:`~repro.kvi.cyclesim.CycleSimBackend` exactly the way any other
caller would run it — programs go through the optimizing pass pipeline
(honoring the point's per-point ``passes`` / ``chaining`` toggles), are
lowered **once** per (program, configuration) through a per-point
:class:`~repro.kvi.lowering.TraceCache` (liveness-based SPM allocation,
:class:`SpmOverflowError` preflight, homogeneous and composite runs all
share the cached trace), and the event-driven simulator produces cycles
plus the per-hart busy/stall/idle breakdown. The cost model
(:mod:`repro.kvi.dse.cost`) adds area and energy.

Points fan out through a pluggable executor
(:mod:`repro.kvi.dse.executors`): ``serial``, ``thread`` (the legacy
GIL-bound pool), ``process`` (a spawn pool with real multi-core
speedup) or ``auto`` (serial for small *uncached* fan-outs, process
otherwise). Records always return in enumeration order and carry
deterministic per-point cache counters, so every executor produces the
same :meth:`SweepResult.canonical_json` bytes.

With a :class:`~repro.kvi.dse.pointcache.PointCache` attached the sweep
is *incremental*: the parent process resolves content-addressed cache
hits before the fan-out and dispatches only the misses, then stores
every fresh record — a re-sweep after an edit recomputes exactly the
delta. Cached and fresh records merge order-preservingly and cache
metadata is volatile-scrubbed, so the canonical JSON stays byte-
identical cold vs. warm.

Measured per point:
  * per kernel, the paper's homogeneous protocol — the program
    replicated on all harts (``KviWorkload.replicate``),
  * the composite protocol — one kernel pinned per hart
    (``KviWorkload.composite``), when the machine has enough harts,
  * optionally (``measure_pallas``) real Pallas execution walltime and
    compiled ``pallas_call`` counts — the co-design axis that trades
    simulated cycles against measured interpret/TPU walltime. Pallas
    execution is scheme/D/SPM-blind, so one measurement per distinct
    ``(precision, passes, harts)`` class is shared across its points
    (and run in the parent process, after the executor fan-out).
"""
from __future__ import annotations

import csv
import dataclasses
import json
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.kvi.analysis import spm_pressure
from repro.kvi.dse.cost import HardwareCost, energy_model, hardware_cost
from repro.kvi.dse.executors import (PointJob, SweepExecutor, make_executor,
                                     resolve_auto)
from repro.kvi.dse.pointcache import (PointCache, pallas_class_key,
                                      point_key, program_fingerprint)
from repro.kvi.dse.space import (DesignPoint, DesignSpace, preflight_point)
from repro.kvi.ir import KviProgram
from repro.kvi.lowering import TraceCache
from repro.kvi.obs.scrub import DSE_VOLATILE, scrub

#: scheme-dict key under which the swept config is registered
POINT_KEY = "dse"

#: JSON keys excluded from ``SweepResult.canonical_json()``: wall-clock
#: measurements, the executor label and point-cache metadata — so
#: executor-equivalence AND cold/warm-equivalence can be asserted
#: byte-for-byte. The set itself now lives in the shared telemetry
#: layer (:data:`repro.kvi.obs.scrub.DSE_VOLATILE`); this module keeps
#: its historical names as aliases.
VOLATILE_KEYS = DSE_VOLATILE


def scrub_volatile(obj, keys: frozenset = VOLATILE_KEYS):
    """Backwards-compatible alias of the shared
    :func:`repro.kvi.obs.scrub.scrub` helper — ``obj`` with every
    ``keys`` entry removed, recursively."""
    return scrub(obj, keys)


@dataclass
class PointRecord:
    """Everything measured for one design point."""

    point: DesignPoint
    status: str                       # "ok" | "incompatible"
    reason: Optional[str] = None
    area: Optional[HardwareCost] = None
    # kernel name -> {"cycles", "energy_nj", "nj_per_cycle",
    #                 "mfu_utilization", "hart_utilization": [...],
    #                 "static_spm": {"peak_live_bytes", ...} (the
    #                 analyzer's KVI301 estimate for this point),
    #                 and with measure_pallas: "pallas_walltime_s",
    #                 "pallas_calls"}
    kernels: Dict[str, Dict[str, object]] = field(default_factory=dict)
    composite: Optional[Dict[str, object]] = None
    wall_s: float = 0.0
    # per-point TraceCache counters: "misses" == SPM-allocator runs
    # (exactly one per kernel per compatible point), "hits" == lowers
    # served from cache. Deterministic — part of the canonical JSON.
    lowering: Optional[Dict[str, int]] = None
    # True when this record was resolved from the persistent point
    # cache instead of computed. Surfaced in as_dict() but volatile-
    # scrubbed from canonical JSON (cold/warm byte-identity).
    cached: bool = False

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def metrics(self, kernel: str) -> Tuple[float, float, float]:
        """(cycles, area_luteq, energy_nj) — the Pareto objectives.
        ``kernel`` may be ``"composite"`` for the composite workload."""
        k = self.composite if kernel == "composite" \
            else self.kernels[kernel]
        return (float(k["cycles"]), self.area.area_luteq,
                float(k["energy_nj"]))

    def as_dict(self) -> Dict[str, object]:
        pt = self.point
        d = {"name": pt.name, "scheme": pt.scheme, "M": pt.M, "F": pt.F,
             "D": pt.D, "precision_bits": pt.precision_bits,
             "spm_kbytes": pt.spm_kbytes, "chaining": pt.chaining,
             "passes": list(pt.passes) if pt.passes is not None else None,
             "status": self.status, "wall_s": round(self.wall_s, 4)}
        if self.reason:
            d["reason"] = self.reason
        if self.area is not None:
            d["area"] = self.area.as_dict()
        if self.kernels:
            d["kernels"] = self.kernels
        if self.composite is not None:
            d["composite"] = self.composite
        if self.lowering is not None:
            d["lowering"] = dict(self.lowering)
        if pt.measure_pallas:
            d["measure_pallas"] = True
        if self.cached:
            d["cached"] = True
        return d


@dataclass
class SweepResult:
    """All records of one sweep, JSON/CSV-persistable."""

    records: List[PointRecord]
    kernel_names: Tuple[str, ...]
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def ok_records(self) -> List[PointRecord]:
        return [r for r in self.records if r.ok]

    def to_json(self) -> Dict[str, object]:
        return {"meta": dict(self.meta),
                "kernels": list(self.kernel_names),
                "points": [r.as_dict() for r in self.records]}

    def canonical_json(self) -> str:
        """The sweep serialized with every wall-clock field stripped —
        byte-identical across executors (and across runs) for the same
        space, kernels and flags. What the determinism tests compare."""
        return json.dumps(scrub_volatile(self.to_json()), indent=2,
                          sort_keys=True)

    def save_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @property
    def measured_pallas(self) -> bool:
        """True when any record carries Pallas walltime columns."""
        return any("pallas_calls" in k for r in self.ok_records
                   for k in r.kernels.values())

    def csv_rows(self) -> List[Dict[str, object]]:
        """Flat (point x kernel) rows for spreadsheet analysis. With
        Pallas measurement on, rows gain ``pallas_walltime_s`` /
        ``pallas_compile_s`` / ``pallas_steady_s`` / ``pallas_calls``
        columns (blank for unmeasured points)."""
        with_pallas = self.measured_pallas
        rows = []
        for r in self.records:
            if not r.ok:
                continue
            base = {"point": r.point.name, "scheme": r.point.scheme,
                    "M": r.point.M, "F": r.point.F, "D": r.point.D,
                    "precision_bits": r.point.precision_bits,
                    "spm_kbytes": r.point.spm_kbytes,
                    "chaining": int(r.point.chaining),
                    "area_luteq": round(r.area.area_luteq, 1)}
            measures = dict(r.kernels)
            if r.composite is not None:
                measures["composite"] = r.composite
            for kname, k in measures.items():
                row = dict(
                    base, kernel=kname, cycles=k["cycles"],
                    energy_nj=round(float(k["energy_nj"]), 1),
                    mean_hart_utilization=round(float(np.mean(
                        [h["utilization"]
                         for h in k["hart_utilization"]])), 4))
                if with_pallas:
                    for col in ("pallas_walltime_s", "pallas_compile_s",
                                "pallas_steady_s", "pallas_calls"):
                        row[col] = k.get(col, "")
                rows.append(row)
        return rows

    def save_csv(self, path: str) -> None:
        rows = self.csv_rows()
        if not rows:
            return
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)


def _measure(backend, workload, cfg) -> Dict[str, object]:
    res = backend.run_workload(workload, functional=False)
    sim = res.timing[POINT_KEY]
    util = res.hart_utilization[POINT_KEY]
    e = energy_model(cfg, sim)
    return {"cycles": sim.cycles,
            "energy_nj": round(e["energy_nj"], 2),
            "nj_per_cycle": round(e["nj_per_cycle"], 4),
            "mfu_utilization": round(sim.mfu_utilization, 4),
            "hart_utilization": util}


def optimize_kernels(kernels: Dict[str, KviProgram],
                     passes: Optional[Tuple[str, ...]],
                     ) -> Dict[str, KviProgram]:
    """The kernels after the pass pipeline a point with ``passes``
    would run. Split out so the sweep driver can share one optimized
    set across every point with the same (precision, passes)."""
    from repro.kvi.passes import PassPipeline
    pipe = PassPipeline.from_spec(passes)
    if not pipe:
        return kernels
    return {name: pipe.run(p) for name, p in kernels.items()}


def run_point(point: DesignPoint, kernels: Dict[str, KviProgram],
              composite: bool = True,
              preoptimized: bool = False) -> PointRecord:
    """Execute every kernel (homogeneous protocol) plus the composite
    workload on one design point; incompatible points (SPM too small for
    a kernel's peak-live footprint) are recorded, not raised.

    The point's pass pipeline runs up front (unless the caller already
    did, ``preoptimized=True``) and both the SPM preflight and the
    backend see the optimized programs — so a kernel that only fits the
    scratchpad after dce/copy_prop (the pipeline's register-reuse
    capability) is a valid design point, and the composite workload
    does not re-optimize what the homogeneous runs already did.

    A per-point :class:`~repro.kvi.lowering.TraceCache` threads through
    the preflight and both run protocols, so the SPM allocator runs
    exactly once per kernel and timing-only lowers stop copying
    ``mem_init`` buffers; the counters land in ``record.lowering``."""
    from repro.kvi.cyclesim import CycleSimBackend
    from repro.kvi.workload import KviWorkload

    t0 = time.perf_counter()
    cfg = point.config()
    if not preoptimized:
        kernels = optimize_kernels(kernels, point.passes)
    cache = TraceCache()
    reason = preflight_point(point, list(kernels.values()),
                             trace_cache=cache)
    if reason is not None:
        return PointRecord(point, "incompatible", reason=reason,
                           wall_s=time.perf_counter() - t0,
                           lowering=cache.stats)
    backend = CycleSimBackend(schemes={POINT_KEY: cfg}, passes=(),
                              chaining=point.chaining, trace_cache=cache)
    rec = PointRecord(point, "ok", area=hardware_cost(cfg))
    for name, prog in kernels.items():
        wl = KviWorkload.replicate(prog, cfg.harts)
        rec.kernels[name] = _measure(backend, wl, cfg)
        # the analyzer's static SPM estimate for this (kernel, point) —
        # deterministic, so it rides into the canonical JSON
        rec.kernels[name]["static_spm"] = spm_pressure(prog, cfg).as_dict()
    if composite and cfg.harts >= len(kernels):
        wl = KviWorkload.composite(
            {h: [prog] for h, prog in enumerate(kernels.values())},
            name="composite")
        rec.composite = _measure(backend, wl, cfg)
    rec.lowering = cache.stats
    rec.wall_s = time.perf_counter() - t0
    return rec


KernelFactory = Callable[[int], Dict[str, KviProgram]]


def measure_pallas_points(records: Sequence[PointRecord],
                          opt_cache: Dict[tuple, Dict[str, KviProgram]],
                          composite: bool = True,
                          emit: Optional[Callable[[str], None]] = None,
                          cache: Optional[PointCache] = None,
                          ) -> Dict[str, object]:
    """The opt-in Pallas walltime stage: batch each measured point's
    programs through ``PallasBackend.run_workload`` (the paper's
    homogeneous protocol as a :class:`KviWorkload`, plus the composite
    workload) and attach ``pallas_walltime_s`` / ``pallas_calls`` to the
    point's kernel measures.

    Each workload runs **twice** against one instance-scoped
    :class:`~repro.kvi.pallas_backend.KernelCache`: the first (cold)
    iteration traces and compiles, the second (warm) replays compiled
    executables only. The split lands as ``pallas_compile_s`` (cold
    minus warm, the one-time cost) and ``pallas_steady_s`` (warm — what
    a serving loop pays per batch); ``pallas_walltime_s`` stays the cold
    total for continuity with earlier sweeps.

    Pallas execution does not model the swept hardware (no D, SPM or
    scheme effect — the TPU grid is the parallelism), so points sharing
    ``(precision_bits, passes, harts)`` are *one* measurement class:
    the class is executed once and its numbers shared, which is what
    makes ``--measure-pallas`` affordable over a 36-point smoke sweep
    (3 classes, not 36 runs). Runs in the parent process, after the
    executor fan-out, so worker processes never touch jax.

    With a :class:`~repro.kvi.dse.pointcache.PointCache` attached,
    class measurements persist under their content-addressed class key
    — a warm re-sweep resolves every class from the store and never
    imports jax, let alone compiles. The cached payload carries the
    class's original compile-cache counters so the (canonical, i.e.
    deterministic) ``compile_cache`` meta totals reproduce exactly."""

    def _measure(backend, wl) -> Dict[str, object]:
        cold = backend.run_workload(wl)
        warm = backend.run_workload(wl)
        if warm.pallas_calls != cold.pallas_calls:
            raise RuntimeError(
                f"warm-up changed the kernel-launch count for "
                f"{wl.name!r}: {cold.pallas_calls} cold vs "
                f"{warm.pallas_calls} warm")
        cold_s = float(cold.meta["wall_s"])
        warm_s = float(warm.meta["wall_s"])
        return {"pallas_walltime_s": round(cold_s, 4),
                "pallas_compile_s": round(max(cold_s - warm_s, 0.0), 4),
                "pallas_steady_s": round(warm_s, 4),
                "pallas_calls": cold.pallas_calls}

    def _run_class(kernels: Dict[str, KviProgram],
                   harts: int) -> Dict[str, object]:
        # jax is only imported here — a fully cache-resolved warm sweep
        # never reaches this function
        from repro.kvi.pallas_backend import PallasBackend
        from repro.kvi.workload import KviWorkload
        backend = PallasBackend(passes=())       # plans already attached
        per: Dict[str, Dict[str, object]] = {}
        for name, prog in kernels.items():
            per[name] = _measure(
                backend, KviWorkload.replicate(prog, harts))
        if composite and harts >= len(kernels):
            wl = KviWorkload.composite(
                {h: [p] for h, p in enumerate(kernels.values())},
                name="composite")
            per["composite"] = _measure(backend, wl)
        return {"per": per,
                "compile_cache": {"hits": backend.kernel_cache.hits,
                                  "misses": backend.kernel_cache.misses}}

    classes: Dict[tuple, Dict[str, object]] = {}
    cache_totals = {"hits": 0, "misses": 0}
    measured_points = 0
    for rec in records:
        if not (rec.ok and rec.point.measure_pallas):
            continue
        pt = rec.point
        harts = pt.config().harts
        key = (pt.precision_bits, pt.passes, harts)
        if key not in classes:
            kernels = opt_cache[(pt.precision_bits, pt.passes)]
            payload = None
            ckey = label = None
            if cache is not None:
                fps = {n: program_fingerprint(p)
                       for n, p in kernels.items()}
                ckey = pallas_class_key(fps, pt.precision_bits,
                                        pt.passes, harts, composite)
                label = (f"b{pt.precision_bits}|"
                         f"passes={pt.passes}|harts={harts}")
                payload = cache.lookup_pallas(ckey, label)
            if payload is None:
                payload = _run_class(kernels, harts)
                if cache is not None:
                    cache.store_pallas(ckey, label, payload)
            classes[key] = payload
            cc = payload["compile_cache"]
            cache_totals["hits"] += cc["hits"]
            cache_totals["misses"] += cc["misses"]
            if emit:
                cells = " ".join(
                    f"{k}={v['pallas_compile_s']}+"
                    f"{v['pallas_steady_s']}s/"
                    f"{v['pallas_calls']}calls"
                    for k, v in payload["per"].items())
                emit(f"pallas[b{key[0]} passes={key[1]} "
                     f"harts={key[2]}] {cells}")
        per = classes[key]["per"]
        for name, measures in per.items():
            target = rec.composite if name == "composite" \
                else rec.kernels.get(name)
            if target is not None:
                target.update(measures)
        measured_points += 1
    return {"n_measured_points": measured_points,
            "n_measurement_classes": len(classes),
            "compile_cache": cache_totals}


def sweep(space: Union[DesignSpace, Sequence[DesignPoint]],
          kernel_factory: KernelFactory,
          composite: bool = True,
          max_workers: int = 4,
          emit: Optional[Callable[[str], None]] = None,
          executor: Union[str, SweepExecutor, None] = None,
          measure_pallas: Optional[bool] = None,
          cache: Optional[PointCache] = None,
          obs=None, progress_every: int = 16,
          shared_opt_cache: Optional[Dict] = None) -> SweepResult:
    """Run every point of ``space`` over the kernels the factory builds
    for that point's precision. Kernel programs are built once per
    distinct precision, optimized once per distinct (precision, passes)
    pair, and shared across points (read-only).

    ``executor`` picks the fan-out strategy (``"serial"`` / ``"thread"``
    / ``"process"`` or a :class:`SweepExecutor` instance); ``None``
    keeps the legacy behavior — threads when ``max_workers > 1`` —
    and ``"auto"`` picks serial for small uncached fan-outs, the
    process pool otherwise.
    ``measure_pallas=True`` forces the Pallas walltime stage on every
    point (``None`` honors each point's own ``measure_pallas`` flag).

    ``cache`` attaches a persistent content-addressed
    :class:`~repro.kvi.dse.pointcache.PointCache`: hits are resolved
    here in the parent (workers never touch the store), only misses
    dispatch to the executor, fresh records are stored back, and
    ``meta["point_cache"]`` reports hit/miss/invalidation counters.

    With ``emit`` set, a progress line goes out every ``progress_every``
    completed fresh points (throughput in points/s, cache hit rate, ETA)
    as the executor streams records back. ``obs`` attaches a telemetry
    bundle (:class:`repro.kvi.obs.Obs`): per-point wall spans on the
    ``dse`` track plus sweep counters in the metrics registry.

    ``shared_opt_cache`` (any mutable dict, created empty by the caller)
    carries the built/optimized kernel programs and their fingerprints
    *across* sweep calls: multi-round drivers (the search tuner batch-
    confirming one survivor rung per call) pass the same dict every
    round so programs optimize and hash once per (precision, passes)
    pair for the whole search, not once per round."""
    points = space.points() if isinstance(space, DesignSpace) \
        else tuple(space)
    if not points:
        raise ValueError("sweep needs at least one design point")
    if measure_pallas is not None:
        points = tuple(
            dataclasses.replace(pt, measure_pallas=measure_pallas)
            for pt in points)
    if shared_opt_cache is None:
        shared_opt_cache = {}
    kernels_by_prec: Dict[int, Dict[str, KviProgram]] = \
        shared_opt_cache.setdefault("raw", {})
    for pt in points:
        if pt.precision_bits not in kernels_by_prec:
            kernels_by_prec[pt.precision_bits] = \
                kernel_factory(pt.precision_bits)
    kernel_names = tuple(next(iter(kernels_by_prec.values())))
    # the optimized programs depend only on (precision, passes) — run
    # the pipeline once per distinct pair, not once per point
    opt_cache: Dict[tuple, Dict[str, KviProgram]] = \
        shared_opt_cache.setdefault("opt", {})
    for pt in points:
        key = (pt.precision_bits, pt.passes)
        if key not in opt_cache:
            opt_cache[key] = optimize_kernels(
                kernels_by_prec[pt.precision_bits], pt.passes)

    jobs = [PointJob(pt, opt_cache[(pt.precision_bits, pt.passes)],
                     composite) for pt in points]

    # resolve persistent-cache hits in the parent; dispatch only misses
    records: List[Optional[PointRecord]] = [None] * len(points)
    point_keys: List[Optional[str]] = [None] * len(points)
    if cache is not None:
        # program fingerprints are shared per (precision, passes) set —
        # hash each optimized program once, not once per point
        fp_cache = shared_opt_cache.setdefault("fp", {})
        for k, kernels in opt_cache.items():
            if k not in fp_cache:
                fp_cache[k] = {name: program_fingerprint(p)
                               for name, p in kernels.items()}
        for i, pt in enumerate(points):
            pk = point_key(pt, fp_cache[(pt.precision_bits, pt.passes)],
                           composite)
            point_keys[i] = pk
            records[i] = cache.lookup_point(pk, pt)
    miss_idx = [i for i, r in enumerate(records) if r is None]

    ex = make_executor(resolve_auto(executor, len(miss_idx)),
                       max_workers=max_workers)
    t0 = time.perf_counter()
    fresh: List[PointRecord] = []
    n_cached = len(points) - len(miss_idx)
    for rec in (ex.imap_jobs([jobs[i] for i in miss_idx])
                if miss_idx else ()):
        fresh.append(rec)
        done = len(fresh)
        if emit and progress_every > 0 and \
                (done % progress_every == 0 or done == len(miss_idx)):
            dt = time.perf_counter() - t0
            rate = done / dt if dt > 0 else 0.0
            eta = (len(miss_idx) - done) / rate if rate > 0 else 0.0
            emit(f"progress {done}/{len(miss_idx)} fresh points "
                 f"({n_cached}/{len(points)} cached) "
                 f"{rate:.1f} pts/s eta {eta:.0f}s")
    wall = time.perf_counter() - t0
    if len(fresh) != len(miss_idx):
        raise RuntimeError(f"executor {ex.name!r} returned "
                           f"{len(fresh)} records for {len(miss_idx)} "
                           f"points — order-preserving map broken")
    for i, rec in zip(miss_idx, fresh):
        records[i] = rec
        if cache is not None:
            # store before the Pallas stage attaches walltime columns:
            # point records persist cyclesim-only, Pallas measurements
            # persist under their own class keys
            cache.store_point(point_keys[i], points[i], rec)

    pallas_meta = None
    if any(pt.measure_pallas for pt in points):
        pallas_meta = measure_pallas_points(records, opt_cache,
                                            composite=composite,
                                            emit=emit, cache=cache)

    if emit:
        for r in records:
            if r.ok:
                cells = " ".join(
                    f"{k}={v['cycles']}" for k, v in r.kernels.items())
                emit(f"{r.point.name:42s} area={r.area.area_luteq:9.0f} "
                     f"{cells}")
            else:
                emit(f"{r.point.name:42s} SKIP ({r.reason})")
    n_ok = sum(r.ok for r in records)
    lowering = {
        "hits": sum(r.lowering["hits"] for r in records if r.lowering),
        "misses": sum(r.lowering["misses"] for r in records
                      if r.lowering)}
    meta = {"n_points": len(points), "n_ok": n_ok,
            "n_incompatible": len(points) - n_ok,
            "schemes": sorted({p.scheme for p in points}),
            "executor": ex.name, "lowering": lowering,
            "wall_s": round(wall, 3)}
    if pallas_meta is not None:
        meta["pallas"] = pallas_meta
    if cache is not None:
        meta["point_cache"] = cache.stats

    if obs is not None and obs.enabled:
        # synthetic wall timeline: each point's measured wall_s laid out
        # end-to-end on one dse lane (cache hits have wall_s == 0 from
        # the original run but still mark their slot)
        cur = 0.0
        for r in records:
            dur = round(max(float(r.wall_s), 0.0) * 1e6, 3)
            obs.tracer.span(("dse", "points"), r.point.name,
                            round(cur, 3), dur, cat="point", clock="wall",
                            args={"status": r.status,
                                  "cached": bool(r.cached)})
            cur += dur
        m = obs.metrics
        m.counter("dse.points").inc(len(points))
        m.counter("dse.points_ok").inc(n_ok)
        m.absorb("dse.lowering", lowering)
        if cache is not None:
            m.absorb("dse.point_cache", cache.stats)
        if pallas_meta is not None:
            m.absorb("dse.pallas.compile_cache",
                     pallas_meta["compile_cache"])
    return SweepResult(list(records), kernel_names, meta=meta)


# ---------------------------------------------------------------------------
# The paper's kernel set as a precision-parameterized factory
# ---------------------------------------------------------------------------


def paper_kernel_factory(smoke: bool = False, seed: int = 0,
                         ) -> KernelFactory:
    """conv / fft / matmul at sweep-appropriate sizes. ``smoke`` shrinks
    the kernels so the whole smoke sweep finishes in seconds; data is
    drawn from ``seed`` so BENCH inputs are reproducible run-to-run.
    MatMul is forced onto the SPM-resident path at every precision so
    the precision axis compares identical instruction structures."""
    S, n_fft, m = (24, 64, 24) if smoke else (32, 256, 64)

    def factory(precision_bits: int) -> Dict[str, KviProgram]:
        from repro.kvi.programs import (conv2d_program, fft_program,
                                        matmul_program)
        eb = precision_bits // 8
        rng = np.random.default_rng(seed)
        lim = {1: 8, 2: 64, 4: 128}[eb]
        img = rng.integers(-lim, lim, (S, S)).astype(np.int32)
        filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
        A = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        B = rng.integers(-lim // 2 or 2, lim // 2 or 2, (m, m)
                         ).astype(np.int32)
        re = rng.integers(-lim, lim, n_fft).astype(np.int32)
        im = rng.integers(-lim, lim, n_fft).astype(np.int32)
        return {
            "conv": conv2d_program(img, filt, shift=4, elem_bytes=eb),
            "fft": fft_program(re, im, elem_bytes=eb),
            "matmul": matmul_program(A, B, shift=2, resident=True,
                                     elem_bytes=eb),
        }

    return factory
