"""Pareto analysis + report generation over a finished sweep.

Reproduces the paper's scheme-comparison story as machine-checkable
facts per kernel (conv / matmul / fft) and for the composite workload:

  * the fastest point on the Pareto front is symmetric MIMD,
  * the cheapest point is the shared scheme,
  * heterogeneous MIMD sits on the front strictly between them
    (near-sym cycles at sub-sym area — the paper's headline trade-off),
  * sub-word 8-bit points cut cycles >= 2x vs 32-bit on the MFU-bound
    kernels (conv, matmul) at matched scheme/D,

plus per-kernel speedup-vs-D curves and the non-dominated front over
(cycles, area, energy). Rendered as JSON (``build_report``) and
markdown (``render_markdown``); :func:`run_dse` is the one-call
orchestrator the CLI and the benchmark harness share.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.kvi.dse.pareto import pareto_front
from repro.kvi.dse.space import DesignSpace
from repro.kvi.dse.sweep import (PointRecord, SweepResult,
                                 paper_kernel_factory, sweep)

#: kernels the paper treats as MFU-bound (long vector streams; the FFT's
#: bit-reversal copies make it TLP- rather than DLP-bound)
MFU_BOUND_KERNELS = ("conv", "matmul")

#: how much faster than sym-MIMD a het-MIMD point may be before the
#: "sym fastest" checks call it a violation. The paper's own Table 2
#: has het edging sym on composite cells (conv32 D=2: 15973 vs 16144,
#: ~1%) — "1% to 7%" is het's TYPICAL overhead, but the sign flips at
#: high D where SPMI streaming, not the shared units, binds.
SYM_TIE_TOLERANCE = 1.02


def _measures(rec: PointRecord) -> Dict[str, Dict[str, object]]:
    out = dict(rec.kernels)
    if rec.composite is not None:
        out["composite"] = rec.composite
    return out


def _match_key(rec: PointRecord) -> tuple:
    """Everything but the scheme AND its M/F replication — the shared
    scheme always has M=F=1, so a matched shared/sym/het triple can
    only form when replication is excluded from the key. With several
    replication values on the axis, each (sym, het) pair at one M is
    compared against the same shared point."""
    p = rec.point
    return (p.D, p.precision_bits, p.spm_kbytes, p.chaining, p.passes,
            p.fu_counts)


def _precision_key(rec: PointRecord) -> tuple:
    """Everything but the precision — for the sub-word speedup pairs."""
    p = rec.point
    return (p.scheme, p.M, p.F, p.D, p.spm_kbytes, p.chaining, p.passes,
            p.fu_counts)


def kernel_front(records: List[PointRecord], kernel: str,
                 ) -> List[Dict[str, object]]:
    """Non-dominated records over (cycles, area, energy) for one
    kernel, as compact report rows."""
    front = pareto_front(records, key=lambda r: r.metrics(kernel))
    rows = []
    for r in sorted(front, key=lambda r: r.metrics(kernel)[0]):
        cyc, area, energy = r.metrics(kernel)
        rows.append({"point": r.point.name, "scheme": r.point.scheme,
                     "D": r.point.D,
                     "precision_bits": r.point.precision_bits,
                     "cycles": int(cyc), "area_luteq": round(area, 1),
                     "energy_nj": round(energy, 1)})
    return rows


def speedup_vs_lanes(records: List[PointRecord], kernel: str,
                     ) -> Dict[str, Dict[str, float]]:
    """Per (scheme, precision): cycles normalized to the smallest swept
    D of that series — the paper's speedup-vs-D curves."""
    series: Dict[tuple, Dict[int, int]] = {}
    labels: Dict[tuple, str] = {}
    for r in records:
        p = r.point
        if p.chaining or p.passes is not None:
            continue                  # curves use the default pipeline
        key = (p.scheme, p.precision_bits, p.spm_kbytes, p.fu_counts,
               p.M, p.F)
        series.setdefault(key, {})[p.D] = int(r.metrics(kernel)[0])
        # label omits the D-independent suffix when it is unambiguous
        labels[key] = p.name.replace(f"_D{p.D}", "")
    out: Dict[str, Dict[str, float]] = {}
    for key, by_d in sorted(series.items()):
        if len(by_d) < 2:
            continue
        base_d = min(by_d)
        out[labels[key]] = {
            f"D{d}": round(by_d[base_d] / by_d[d], 3)
            for d in sorted(by_d)}
    return out


def scheme_ordering_checks(records: List[PointRecord], kernel: str,
                           ) -> Dict[str, bool]:
    """The paper's qualitative ordering, checked on the front and on
    every matched (same-everything-but-scheme) group."""
    front = pareto_front(records, key=lambda r: r.metrics(kernel))
    fastest = min(front, key=lambda r: r.metrics(kernel)[0])
    cheapest = min(front, key=lambda r: r.metrics(kernel)[1])
    # "fastest is sym" by cycle VALUE, not point identity: when harts
    # go issue-bound (wide lanes + sub-word + chaining) het ties sym
    # exactly and, being cheaper, dominates it off the front — the
    # paper's own "het within 1-7% of sym" convergence, not a failure
    best_sym = min((r.metrics(kernel)[0] for r in records
                    if r.point.scheme == "sym_mimd"), default=float("inf"))
    best_shared_area = min((r.metrics(kernel)[1] for r in records
                            if r.point.scheme == "shared"),
                           default=float("inf"))
    het_front = [r for r in front if r.point.scheme == "het_mimd"]
    het_between = any(
        r.metrics(kernel)[0] <= cheapest.metrics(kernel)[0]
        and r.metrics(kernel)[1] <= fastest.metrics(kernel)[1]
        for r in het_front)

    # matched groups: same everything-but-scheme/replication; within a
    # group, each MIMD replication level M pairs sym(M)/het(M) against
    # the (unique) shared point
    groups: Dict[tuple, Dict[tuple, PointRecord]] = {}
    for r in records:
        groups.setdefault(_match_key(r), {})[
            (r.point.scheme, r.point.M)] = r
    sym_fastest_matched = True
    shared_cheapest_matched = True
    n_matched = 0
    for g in groups.values():
        shared_rec = g.get(("shared", 1))
        if shared_rec is None:
            continue
        for (scheme, m), sym_rec in g.items():
            if scheme != "sym_mimd":
                continue
            het_rec = g.get(("het_mimd", m))
            if het_rec is None:
                continue
            n_matched += 1
            cyc = [rec.metrics(kernel)[0]
                   for rec in (sym_rec, het_rec, shared_rec)]
            area = [rec.metrics(kernel)[1]
                    for rec in (shared_rec, het_rec, sym_rec)]
            if not (cyc[0] <= cyc[1] * SYM_TIE_TOLERANCE
                    and cyc[1] <= cyc[2]):
                sym_fastest_matched = False
            if not (area[0] < area[1] < area[2]):
                shared_cheapest_matched = False
    # no matched triple at all would make both checks vacuous — treat
    # that as a failure so the gate cannot pass by accident
    if n_matched == 0:
        sym_fastest_matched = shared_cheapest_matched = False
    return {
        "front_fastest_is_sym":
            best_sym <= fastest.metrics(kernel)[0] * SYM_TIE_TOLERANCE,
        "front_cheapest_is_shared":
            best_shared_area <= cheapest.metrics(kernel)[1],
        "het_on_front_between": bool(het_front) and het_between,
        "sym_fastest_matched_groups": sym_fastest_matched,
        "shared_cheapest_matched_groups": shared_cheapest_matched,
        "n_matched_groups": n_matched,
    }


def hart_utilization_by_scheme(records: List[PointRecord], kernel: str,
                               ) -> Dict[str, Dict[str, object]]:
    """Per scheme, the per-hart busy/stall/idle breakdown of that
    scheme's fastest default-pipeline point on ``kernel`` — the record
    that explains *why* het-MIMD tracks sym-MIMD (its harts stall on the
    shared MFU instead of idling). Deterministic representative: lowest
    cycles, then point name."""
    out: Dict[str, Dict[str, object]] = {}
    for scheme in ("shared", "sym_mimd", "het_mimd"):
        cands = [r for r in records
                 if r.point.scheme == scheme and not r.point.chaining
                 and r.point.passes is None and kernel in _measures(r)]
        if not cands:
            continue
        best = min(cands, key=lambda r: (r.metrics(kernel)[0],
                                         r.point.name))
        k = _measures(best)[kernel]
        out[scheme] = {"point": best.point.name,
                       "cycles": int(k["cycles"]),
                       "harts": [dict(h) for h in k["hart_utilization"]]}
    return out


def pallas_summary(records: List[PointRecord], kernel: str,
                   ) -> List[Dict[str, object]]:
    """The walltime axis, one row per measured (precision, passes)
    class: real Pallas walltime — split into one-time compile and warm
    steady-state when the sweep measured both — plus the compiled
    ``pallas_call`` count next to the best simulated cycle count of the
    class's points — the cycles-vs-walltime trade the co-design argument
    needs measured, not modeled."""
    rows: Dict[tuple, Dict[str, object]] = {}
    for r in records:
        k = _measures(r).get(kernel)
        if not k or "pallas_calls" not in k:
            continue
        key = (r.point.precision_bits, r.point.passes)
        row = rows.get(key)
        if row is None:
            row = rows[key] = {
                "precision_bits": r.point.precision_bits,
                "passes": list(r.point.passes)
                if r.point.passes is not None else None,
                "pallas_walltime_s": k["pallas_walltime_s"],
                "pallas_calls": k["pallas_calls"],
                "best_cycles": int(k["cycles"]),
                "n_points": 0}
            for col in ("pallas_compile_s", "pallas_steady_s"):
                if col in k:
                    row[col] = k[col]
        row["best_cycles"] = min(row["best_cycles"], int(k["cycles"]))
        row["n_points"] += 1
    return [rows[key] for key in sorted(
        rows, key=lambda t: (t[0], t[1] is not None, t[1] or ()))]


def subword_speedups(records: List[PointRecord], kernel: str,
                     ) -> Dict[str, object]:
    """cycles(32-bit) / cycles(8-bit) for every matched configuration
    pair — the sub-word SIMD payoff."""
    by_cfg: Dict[tuple, Dict[int, PointRecord]] = {}
    for r in records:
        by_cfg.setdefault(_precision_key(r), {})[
            r.point.precision_bits] = r
    pairs = []
    for _cfg_key, by_prec in sorted(by_cfg.items()):
        if 8 in by_prec and 32 in by_prec:
            c32 = by_prec[32].metrics(kernel)[0]
            c8 = by_prec[8].metrics(kernel)[0]
            pairs.append({"point_8bit": by_prec[8].point.name,
                          "D": by_prec[8].point.D,
                          "cycles_32": int(c32), "cycles_8": int(c8),
                          "speedup": round(c32 / max(c8, 1), 3)})
    best = max((p["speedup"] for p in pairs), default=0.0)
    # the narrow-lane pairs are where a kernel is genuinely MFU-bound
    # (at wide D + sub-word, setup latency and scalar issue dominate and
    # the ratio legitimately decays toward 1 — Amdahl, not a bug), so
    # the gate below also requires EVERY smallest-D pair to clear the
    # threshold, not just the single best configuration
    min_d = min((p["D"] for p in pairs), default=0)
    floor = min((p["speedup"] for p in pairs if p["D"] == min_d),
                default=0.0)
    return {"pairs": pairs, "max_speedup": best,
            "min_lanes": min_d, "min_speedup_at_min_lanes": floor}


def build_report(result: SweepResult,
                 subword_min_speedup: float = 2.0) -> Dict[str, object]:
    """The full analysis: per-kernel fronts, curves and checks, plus
    the aggregate pass/fail booleans the acceptance gate reads."""
    ok = result.ok_records
    kernels = list(result.kernel_names)
    if any(r.composite is not None for r in ok):
        kernels.append("composite")

    per_kernel: Dict[str, object] = {}
    ordering_ok = True
    subword_ok = True
    for kern in kernels:
        recs = [r for r in ok
                if kern in _measures(r)]
        if not recs:
            continue
        front = kernel_front(recs, kern)
        checks = scheme_ordering_checks(recs, kern)
        sub = subword_speedups(recs, kern)
        per_kernel[kern] = {"front": front,
                            "speedup_vs_lanes":
                                speedup_vs_lanes(recs, kern),
                            "subword": sub, "checks": checks,
                            "hart_utilization":
                                hart_utilization_by_scheme(recs, kern)}
        pallas = pallas_summary(recs, kern)
        if pallas:
            per_kernel[kern]["pallas"] = pallas
        # the checks dict mixes pass/fail booleans with integer
        # diagnostics (n_matched_groups) — gate on the booleans only,
        # the same contract __main__ uses when listing failures
        ordering_ok &= all(v for v in checks.values()
                           if isinstance(v, bool))
        if kern in MFU_BOUND_KERNELS:
            subword_ok &= (sub["max_speedup"] >= subword_min_speedup
                           and sub["min_speedup_at_min_lanes"]
                           >= subword_min_speedup)

    schemes_covered = sorted({r.point.scheme for r in ok})
    return {
        "meta": dict(result.meta),
        "kernels": per_kernel,
        "checks": {
            "n_points_ok": len(ok),
            "all_schemes_covered":
                schemes_covered == ["het_mimd", "shared", "sym_mimd"],
            "pareto_ordering_ok": ordering_ok,
            "subword_2x_on_mfu_bound": subword_ok,
        },
    }


#: width of one utilization bar in characters
_BAR_WIDTH = 30


def _utilization_bar(busy: int, stall: int, total: int,
                     width: int = _BAR_WIDTH) -> str:
    """busy/stall/idle as one fixed-width bar: ``█`` busy, ``▒`` stall,
    ``·`` idle. Cumulative rounding so the segments always sum to
    ``width``."""
    total = max(total, 1)
    n_busy = round(width * busy / total)
    n_stall = round(width * (busy + stall) / total) - n_busy
    n_idle = width - n_busy - n_stall
    return "█" * n_busy + "▒" * n_stall + "·" * n_idle


def render_markdown(report: Dict[str, object],
                    plots: Optional[Dict[str, List[str]]] = None) -> str:
    """A human-readable walkthrough of the sweep. ``plots`` maps kernel
    names to SVG filenames (written next to the markdown by
    :func:`repro.kvi.dse.plots.write_plots`) to embed as images."""
    lines = ["# Klessydra-T design-space exploration", ""]
    meta = report["meta"]
    lines += [f"- points swept: {meta['n_points']} "
              f"({meta['n_ok']} ok, {meta['n_incompatible']} "
              f"incompatible), wall {meta['wall_s']}s",
              f"- schemes: {', '.join(meta['schemes'])}", ""]

    lines += ["## Checks", ""]
    for k, v in report["checks"].items():
        lines.append(f"- `{k}`: **{v}**")
    lines.append("")

    for kern, data in report["kernels"].items():
        lines += [f"## {kern}", ""]
        for fname in (plots or {}).get(kern, ()):
            lines.append(f"![{os.path.splitext(fname)[0]}]({fname})")
        if (plots or {}).get(kern):
            lines.append("")
        lines += ["### Pareto front "
                  "(cycles / area / energy, all minimized)", "",
                  "| point | scheme | D | bits | cycles | area (LUTeq) "
                  "| energy (nJ) |",
                  "|---|---|---|---|---|---|---|"]
        for row in data["front"]:
            lines.append(
                f"| {row['point']} | {row['scheme']} | {row['D']} | "
                f"{row['precision_bits']} | {row['cycles']} | "
                f"{row['area_luteq']} | {row['energy_nj']} |")
        lines.append("")
        if data["speedup_vs_lanes"]:
            lines += ["### Speedup vs lane count (baseline: smallest "
                      "swept D per series)", ""]
            for series, by_d in data["speedup_vs_lanes"].items():
                cells = ", ".join(f"{d}: {s}x"
                                  for d, s in by_d.items())
                lines.append(f"- `{series}`: {cells}")
            lines.append("")
        sub = data["subword"]
        if sub["pairs"]:
            lines.append(f"### Sub-word: best 32-bit -> 8-bit speedup "
                         f"{sub['max_speedup']}x")
            lines.append("")
        util = data.get("hart_utilization") or {}
        if util:
            lines += ["### Hart utilization (fastest default-pipeline "
                      "point per scheme; █ busy, ▒ stall, · idle)", ""]
            for scheme, u in util.items():
                lines.append(f"- `{scheme}` — `{u['point']}` "
                             f"({u['cycles']} cycles)")
                for h, hb in enumerate(u["harts"]):
                    bar = _utilization_bar(hb["busy"], hb["stall"],
                                           hb["total"])
                    lines.append(
                        f"  - hart{h} `{bar}` "
                        f"{100 * hb['busy'] // max(hb['total'], 1)}% busy, "
                        f"{100 * hb['stall'] // max(hb['total'], 1)}% "
                        f"stall, "
                        f"{100 * hb['idle'] // max(hb['total'], 1)}% idle")
            lines.append("")
        pallas = data.get("pallas")
        if pallas:
            lines += ["### Pallas walltime (measured, homogeneous "
                      "batch; one measurement per precision/pipeline "
                      "class; compile = one-time cost, steady = warm "
                      "per-batch cost)", "",
                      "| bits | pipeline | walltime (s) | compile (s) "
                      "| steady (s) | pallas_calls "
                      "| best sim cycles | points |",
                      "|---|---|---|---|---|---|---|---|"]
            for row in pallas:
                pipe = "default" if row["passes"] is None else \
                    ("raw" if row["passes"] == [] else
                     "-".join(row["passes"]))
                lines.append(
                    f"| {row['precision_bits']} | {pipe} | "
                    f"{row['pallas_walltime_s']} | "
                    f"{row.get('pallas_compile_s', '-')} | "
                    f"{row.get('pallas_steady_s', '-')} | "
                    f"{row['pallas_calls']} | {row['best_cycles']} | "
                    f"{row['n_points']} |")
            lines.append("")
    return "\n".join(lines)


#: appended to ``dse_report.md`` when the auto-tuner's trajectory
#: figure sits next to it (either tool may run first — both link it).
SEARCH_TRAJECTORY_SECTION = (
    "\n## Auto-tuner trajectory\n\n"
    "The budget-constrained search (`python -m repro.kvi.dse search`) "
    "over this space — best-so-far workload-mix cycles per "
    "cycle-accurate evaluation spent (details in `dse_search.md`):\n\n"
    "![search trajectory](dse_search_trajectory.svg)\n")


def smoke_space() -> DesignSpace:
    """The CI sweep: 3 schemes x D in (2,4,8,16) x 8/16/32-bit = 36
    points, seconds of wall time."""
    return DesignSpace()


def full_space() -> DesignSpace:
    """The paper-scale sweep: adds the chaining toggle axis and the FU
    replication axis (het-MIMD with a second MAC instance — the shared
    multiplier is exactly what its three harts serialize on, so the
    dual-MAC point lands on the matmul Pareto front between base het
    and sym). Gated out of the smoke space so CI stays at 36 points."""
    return DesignSpace(chaining=(False, True),
                       fu_counts=((), (("multiplier", 2),)))


def run_dse(smoke: bool = False, seed: int = 0,
            emit: Optional[Callable[[str], None]] = None,
            out_dir: Optional[str] = None,
            max_workers: int = 4,
            space: Optional[DesignSpace] = None,
            executor: Optional[str] = None,
            measure_pallas: bool = False,
            cache=None, obs=None,
            ) -> Tuple[SweepResult, Dict[str, object]]:
    """Sweep + report (+ artifacts). Writes ``dse_sweep.json``,
    ``dse_sweep.csv``, ``dse_report.md`` (with SVG speedup/Pareto
    figures alongside) and ``BENCH_kvi_dse.json`` into ``out_dir`` when
    given. ``executor`` selects the sweep executor
    (serial/thread/process/auto); ``measure_pallas`` adds the Pallas
    walltime stage to every point. ``cache`` attaches a persistent
    :class:`~repro.kvi.dse.pointcache.PointCache` — the sweep then
    recomputes only points whose inputs changed, and
    ``dse_cache_stats.json`` lands next to the other artifacts.
    ``obs`` threads a telemetry bundle through the sweep."""
    t0 = time.perf_counter()
    space = space or (smoke_space() if smoke else full_space())
    result = sweep(space, paper_kernel_factory(smoke=smoke, seed=seed),
                   emit=emit, max_workers=max_workers,
                   executor=executor,
                   measure_pallas=True if measure_pallas else None,
                   cache=cache, obs=obs)
    report = build_report(result)
    report["meta"]["smoke"] = smoke
    report["meta"]["seed"] = seed
    report["meta"]["total_wall_s"] = round(time.perf_counter() - t0, 3)
    if out_dir is not None:
        import json

        from repro.kvi.dse.plots import write_plots
        os.makedirs(out_dir, exist_ok=True)
        result.save_json(os.path.join(out_dir, "dse_sweep.json"))
        result.save_csv(os.path.join(out_dir, "dse_sweep.csv"))
        plots = write_plots(result, report, out_dir)
        md = render_markdown(report, plots=plots)
        if os.path.exists(os.path.join(out_dir,
                                       "dse_search_trajectory.svg")):
            md += SEARCH_TRAJECTORY_SECTION
        with open(os.path.join(out_dir, "dse_report.md"), "w") as f:
            f.write(md)
        with open(os.path.join(out_dir, "BENCH_kvi_dse.json"), "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        if cache is not None:
            stats = dict(cache.stats)
            stats["total_wall_s"] = report["meta"]["total_wall_s"]
            with open(os.path.join(out_dir,
                                   "dse_cache_stats.json"), "w") as f:
                json.dump(stats, f, indent=2, sort_keys=True)
    return result, report
