"""CLI: ``python -m repro.kvi.dse [--smoke] [--out-dir DIR] ...``

Runs the design-space sweep over the paper's kernels, writes the
artifacts (``dse_sweep.json``, ``dse_sweep.csv``, ``dse_report.md``,
``BENCH_kvi_dse.json``) and exits non-zero when any acceptance check
fails (all schemes covered, Pareto scheme ordering, sub-word >= 2x on
the MFU-bound kernels).

``--executor {serial,thread,process}`` selects the sweep executor
(process = real multi-core speedup past the GIL; all three produce
identical canonical results). ``--measure-pallas`` adds the walltime
axis: each point's programs also run through ``PallasBackend`` and the
artifacts gain walltime + compiled-``pallas_call``-count columns.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.kvi.dse")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI-sized, <60s)")
    ap.add_argument("--full", action="store_true",
                    help="explicit paper-scale sweep (the default when "
                         "--smoke is absent): adds the chaining and "
                         "fu_counts axes")
    ap.add_argument("--out-dir", default=".",
                    help="where to write sweep/report artifacts")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible BENCH)")
    ap.add_argument("--jobs", type=int, default=4,
                    help="sweep worker count (threads or processes)")
    ap.add_argument("--executor", default=None,
                    choices=("serial", "thread", "process"),
                    help="sweep executor (default: thread when --jobs "
                         "> 1, else serial)")
    ap.add_argument("--measure-pallas", action="store_true",
                    help="also measure real Pallas walltime + "
                         "pallas_call counts per point (one execution "
                         "per precision/pipeline class)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-point progress lines")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")

    from repro.kvi.dse.report import run_dse
    emit = (lambda s: None) if args.quiet else print
    result, report = run_dse(smoke=args.smoke, seed=args.seed,
                             emit=emit, out_dir=args.out_dir,
                             max_workers=args.jobs,
                             executor=args.executor,
                             measure_pallas=args.measure_pallas)

    meta = report["meta"]
    print(f"\n# swept {meta['n_points']} points "
          f"({meta['n_ok']} ok) in {meta['total_wall_s']}s "
          f"[executor={meta['executor']}, lowering cache "
          f"{meta['lowering']['hits']} hits / "
          f"{meta['lowering']['misses']} misses]")
    if "pallas" in meta:
        print(f"# pallas walltime: {meta['pallas']['n_measured_points']} "
              f"points in {meta['pallas']['n_measurement_classes']} "
              f"measurement classes")
    failed = [k for k, v in report["checks"].items()
              if isinstance(v, bool) and not v]
    for k, v in report["checks"].items():
        print(f"#   {k} = {v}")
    print(f"# wrote dse_sweep.json / dse_sweep.csv / dse_report.md / "
          f"BENCH_kvi_dse.json under {args.out_dir}")
    if failed:
        print(f"# FAILED checks: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
