"""CLI: ``python -m repro.kvi.dse [--smoke] [--out-dir DIR] ...``
     or ``python -m repro.kvi.dse search [--smoke] [--strategy S] ...``

Without a subcommand, runs the exhaustive design-space sweep over the
paper's kernels, writes the artifacts (``dse_sweep.json``,
``dse_sweep.csv``, ``dse_report.md``, ``BENCH_kvi_dse.json``,
``dse_cache_stats.json``) and exits non-zero when any acceptance check
fails (all schemes covered, Pareto scheme ordering, sub-word >= 2x on
the MFU-bound kernels).

``search`` runs the budget-constrained auto-tuner instead
(:mod:`repro.kvi.dse.search`): sample feasible candidates, rank them
with the analytic cost model, spend cycle-accurate simulations only on
survivors. Writes ``dse_search.json`` / ``dse_search_canonical.json``
/ ``dse_search.md`` / ``dse_search_trajectory.svg`` /
``BENCH_kvi_search.json``; with ``--smoke`` it also confirms the rest
of the grid and exits non-zero unless the search recovered the full
exhaustive Pareto front within half the grid's simulations.

``--executor {auto,serial,thread,process}`` selects the sweep executor
(default ``auto``: serial for small uncached fan-outs, the spawn
process pool otherwise; all executors produce identical canonical
results). ``--measure-pallas`` adds the walltime axis: each point's
programs also run through ``PallasBackend`` and the artifacts gain
walltime + compiled-``pallas_call``-count columns.

Sweeps are **incremental** by default: measured points persist in a
content-addressed cache (``~/.cache/klessydra-dse`` or ``--cache-dir``)
and a re-run with unchanged inputs resolves every point — and every
``--measure-pallas`` compile — from the store. ``--no-cache`` restores
the cold-sweep behavior; ``--cache-stats`` prints the store's counters
and shape after the run.
"""
from __future__ import annotations

import argparse
import json
import sys


def search_main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kvi.dse search",
        description="budget-constrained design-space auto-tuner")
    ap.add_argument("--smoke", action="store_true",
                    help="36-point CI space + exhaustive yardstick: "
                         "fails unless the full Pareto front is "
                         "recovered within half the grid's sims")
    ap.add_argument("--strategy", default="successive_halving",
                    help="search strategy (default successive_halving)")
    ap.add_argument("--budget", type=int, default=None,
                    help="max cycle-accurate evaluations (default: "
                         "half the grid, capped)")
    ap.add_argument("--pool", type=int, default=None,
                    help="candidate pool screened analytically "
                         "(default: 8x budget, capped at the grid)")
    ap.add_argument("--eps", type=float, default=None,
                    help="low-fidelity dominance relaxation (default "
                         "0.02 — the estimator's error margin)")
    ap.add_argument("--max-area", type=float, default=None,
                    metavar="LUTEQ",
                    help="feasibility constraint: analytic area budget")
    ap.add_argument("--max-static-nj", type=float, default=None,
                    metavar="NJ",
                    help="feasibility constraint: static nJ/cycle "
                         "budget")
    ap.add_argument("--compare-exhaustive", action="store_true",
                    help="confirm the remaining grid afterwards and "
                         "score front recovery (implied by --smoke)")
    ap.add_argument("--out-dir", default=".",
                    help="where to write search artifacts")
    ap.add_argument("--seed", type=int, default=0,
                    help="search RNG + kernel input data seed")
    ap.add_argument("--jobs", type=int, default=4,
                    help="confirmation worker count")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "thread", "process"),
                    help="confirmation executor (default auto: serial "
                         "for tiny budgets, persistent process pool "
                         "otherwise)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent point-cache directory (shared "
                         "with the exhaustive sweep)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the persistent point cache")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress progress lines")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON")
    args = ap.parse_args(argv)
    if args.no_cache and args.cache_dir:
        ap.error("--no-cache and --cache-dir are mutually exclusive")

    from repro.kvi.dse.search import STRATEGIES, run_search
    if args.strategy not in STRATEGIES:
        ap.error(f"unknown strategy {args.strategy!r}; choose from "
                 f"{', '.join(sorted(STRATEGIES))}")
    constraints = None
    if args.max_area is not None or args.max_static_nj is not None:
        from repro.kvi.dse.space import SpaceConstraints
        constraints = SpaceConstraints(
            max_area_luteq=args.max_area,
            max_static_nj_per_cycle=args.max_static_nj)
    cache = None
    if not args.no_cache:
        from repro.kvi.dse.pointcache import PointCache
        cache = PointCache(cache_dir=args.cache_dir)
    obs = None
    if args.trace_out or args.metrics_out:
        from repro.kvi.obs import Obs
        obs = Obs.on()
    result = run_search(
        strategy=args.strategy, smoke=args.smoke, seed=args.seed,
        budget=args.budget, pool=args.pool,
        **({"eps": args.eps} if args.eps is not None else {}),
        constraints=constraints,
        compare_exhaustive=True if (args.smoke
                                    or args.compare_exhaustive)
        else None,
        emit=None if args.quiet else print, out_dir=args.out_dir,
        max_workers=args.jobs, executor=args.executor,
        cache=cache, obs=obs)
    if obs is not None:
        obs.save(trace_path=args.trace_out,
                 metrics_path=args.metrics_out)

    ev = result.evaluations
    frac = result.exhaustive_fraction
    print(f"\n# search[{result.strategy}] seed {result.seed}: "
          f"{ev['high_evals']} sims "
          f"({frac:.1%} of the {result.meta['grid_size']}-point grid), "
          f"{ev['low_evals']} analytic scores, "
          f"front size {len(result.front)} "
          f"in {result.meta['walltime_s']}s")
    if result.best is not None:
        print(f"# best: {result.best.point.name}")
    failed = []
    rec = result.meta.get("recovery")
    if rec is not None:
        print(f"# front recovery: {rec['front_recovery']:.1%} of "
              f"{rec['exhaustive_front_size']} exhaustive front "
              f"members (exhaustive confirm took "
              f"{rec['walltime_s']}s)")
        if args.smoke:
            if rec["front_recovery"] < 1.0:
                failed.append("front_recovery == 1.0")
            if frac is not None and frac > 0.5:
                failed.append("high_evals <= 50% of grid")
    print(f"# wrote dse_search.json / dse_search.md / "
          f"BENCH_kvi_search.json under {args.out_dir}")
    if failed:
        print(f"# FAILED checks: {', '.join(failed)}",
              file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "search":
        return search_main(argv[1:])
    ap = argparse.ArgumentParser(prog="python -m repro.kvi.dse")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI-sized, <60s)")
    ap.add_argument("--full", action="store_true",
                    help="explicit paper-scale sweep (the default when "
                         "--smoke is absent): adds the chaining and "
                         "fu_counts axes")
    ap.add_argument("--out-dir", default=".",
                    help="where to write sweep/report artifacts")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible BENCH)")
    ap.add_argument("--jobs", type=int, default=4,
                    help="sweep worker count (threads or processes)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "thread", "process"),
                    help="sweep executor (default auto: serial for <8 "
                         "uncached points, process pool otherwise)")
    ap.add_argument("--measure-pallas", action="store_true",
                    help="also measure real Pallas walltime + "
                         "pallas_call counts per point (one execution "
                         "per precision/pipeline class; cached across "
                         "runs like any other measurement)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persistent point-cache directory (default: "
                         "$XDG_CACHE_HOME/klessydra-dse or "
                         "~/.cache/klessydra-dse)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the persistent point cache: compute "
                         "every point cold and store nothing")
    ap.add_argument("--cache-stats", action="store_true",
                    help="print point-cache counters and store shape "
                         "after the sweep")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-point progress lines")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace of the "
                         "sweep (per-point wall spans)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot JSON")
    args = ap.parse_args(argv)
    if args.smoke and args.full:
        ap.error("--smoke and --full are mutually exclusive")
    if args.no_cache and args.cache_dir:
        ap.error("--no-cache and --cache-dir are mutually exclusive")

    from repro.kvi.dse.report import run_dse
    cache = None
    if not args.no_cache:
        from repro.kvi.dse.pointcache import PointCache
        cache = PointCache(cache_dir=args.cache_dir)
    emit = None if args.quiet else print
    obs = None
    if args.trace_out or args.metrics_out:
        from repro.kvi.obs import Obs
        obs = Obs.on()
    result, report = run_dse(smoke=args.smoke, seed=args.seed,
                             emit=emit, out_dir=args.out_dir,
                             max_workers=args.jobs,
                             executor=args.executor,
                             measure_pallas=args.measure_pallas,
                             cache=cache, obs=obs)
    if obs is not None:
        obs.save(trace_path=args.trace_out,
                 metrics_path=args.metrics_out)

    meta = report["meta"]
    print(f"\n# swept {meta['n_points']} points "
          f"({meta['n_ok']} ok) in {meta['total_wall_s']}s "
          f"[executor={meta['executor']}, lowering cache "
          f"{meta['lowering']['hits']} hits / "
          f"{meta['lowering']['misses']} misses]")
    if cache is not None:
        pc = meta["point_cache"]
        print(f"# point cache: {pc['hits']} hits / {pc['misses']} "
              f"misses / {pc['invalidations']} invalidations "
              f"(pallas: {pc['pallas_hits']} hits / "
              f"{pc['pallas_misses']} misses)")
        if args.cache_stats:
            print(f"# cache stats: {json.dumps(pc, sort_keys=True)}")
    if "pallas" in meta:
        print(f"# pallas walltime: {meta['pallas']['n_measured_points']} "
              f"points in {meta['pallas']['n_measurement_classes']} "
              f"measurement classes")
    failed = [k for k, v in report["checks"].items()
              if isinstance(v, bool) and not v]
    for k, v in report["checks"].items():
        print(f"#   {k} = {v}")
    print(f"# wrote dse_sweep.json / dse_sweep.csv / dse_report.md / "
          f"BENCH_kvi_dse.json under {args.out_dir}")
    if failed:
        print(f"# FAILED checks: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
