"""CLI: ``python -m repro.kvi.dse [--smoke] [--out-dir DIR] ...``

Runs the design-space sweep over the paper's kernels, writes the
artifacts (``dse_sweep.json``, ``dse_sweep.csv``, ``dse_report.md``,
``BENCH_kvi_dse.json``) and exits non-zero when any acceptance check
fails (all schemes covered, Pareto scheme ordering, sub-word >= 2x on
the MFU-bound kernels).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.kvi.dse")
    ap.add_argument("--smoke", action="store_true",
                    help="small kernels + default axes (CI-sized, <60s)")
    ap.add_argument("--out-dir", default=".",
                    help="where to write sweep/report artifacts")
    ap.add_argument("--seed", type=int, default=0,
                    help="kernel input data seed (reproducible BENCH)")
    ap.add_argument("--jobs", type=int, default=4,
                    help="sweep thread-pool width")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-point progress lines")
    args = ap.parse_args(argv)

    from repro.kvi.dse.report import run_dse
    emit = (lambda s: None) if args.quiet else print
    result, report = run_dse(smoke=args.smoke, seed=args.seed,
                             emit=emit, out_dir=args.out_dir,
                             max_workers=args.jobs)

    print(f"\n# swept {report['meta']['n_points']} points "
          f"({report['meta']['n_ok']} ok) in "
          f"{report['meta']['total_wall_s']}s")
    failed = [k for k, v in report["checks"].items()
              if isinstance(v, bool) and not v]
    for k, v in report["checks"].items():
        print(f"#   {k} = {v}")
    print(f"# wrote dse_sweep.json / dse_sweep.csv / dse_report.md / "
          f"BENCH_kvi_dse.json under {args.out_dir}")
    if failed:
        print(f"# FAILED checks: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
