"""Content-addressed persistent point cache: incremental re-sweeps.

Every sweep invocation used to start cold — all points recomputed (and
every ``--measure-pallas`` class recompiled) even when nothing changed.
This module makes re-sweeps proportional to the *delta*: each measured
:class:`~repro.kvi.dse.sweep.PointRecord` is stored on disk under a
content-addressed key, and :func:`~repro.kvi.dse.sweep.sweep` consults
the store before dispatching :class:`~repro.kvi.dse.executors.PointJob`
units to any executor, so only points whose inputs actually changed run.

The key (:func:`point_key`) fingerprints everything a record depends on:

  * the :class:`~repro.kvi.dse.space.DesignPoint` canonical dict —
    every hardware axis plus the per-point ``chaining`` toggle,
  * the **optimized** kernel program IR (:func:`program_fingerprint`:
    structure, operands, scalar blocks, ``mem_init`` bytes, and the
    attached fusion-plan metadata — what the backend actually executes),
  * the *resolved* pass-pipeline spec (``None`` resolves to the default
    pipeline's names, so changing ``DEFAULT_PASSES`` invalidates),
  * explicit version tokens for the cost model
    (:data:`repro.kvi.dse.cost.CALIBRATION_VERSION`) and the cyclesim
    timing semantics (:data:`repro.kvi.cyclesim.TIMING_VERSION`) —
    bumped by hand and pinned by tests, **not** source hashes, so
    comment-only edits keep caches warm while semantic changes miss,
  * the composite-protocol flag and the store schema version.

``--measure-pallas`` class measurements cache under their own key
(:func:`pallas_class_key`) joined with the ``(precision, passes,
harts)`` measurement class, so warm re-sweeps skip jax imports and
compiles entirely.

The store (:class:`PointCache`) is a JSON-lines file under
``~/.cache/klessydra-dse`` (or ``--cache-dir``): one self-checksummed
entry per line, corrupted or schema-stale lines discarded on load (and
recomputed — never fatal), last write per key wins, and a byte-budget
GC policy that compacts the file dropping oldest entries first.
Workers never touch the store: the sweep driver resolves hits in the
parent process and only dispatches misses, so executor spawn semantics
(and canonical-output byte-identity across serial/thread/process) are
unchanged.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Optional

from repro.kvi.ir import KviProgram, ScalarBlock
from repro.kvi.dse.cost import HardwareCost
from repro.kvi.dse.space import DesignPoint

#: Store layout version: a bump discards every existing entry (the
#: loader skips lines whose version differs). Raise it when the entry
#: format — not the measured semantics — changes.
SCHEMA_VERSION = 1

#: Basename of the JSON-lines store inside the cache directory.
STORE_BASENAME = "dse_point_cache.jsonl"

#: Default store size budget before GC compaction drops oldest entries.
DEFAULT_MAX_BYTES = 256 << 20


def default_cache_dir() -> str:
    """``$XDG_CACHE_HOME/klessydra-dse`` (``~/.cache`` fallback)."""
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "klessydra-dse")


def _canonical_dumps(obj) -> str:
    """Deterministic JSON: the byte string checksums and keys hash."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------


def program_fingerprint(program: KviProgram) -> str:
    """A content hash of one program: structure (items, operands,
    scalar blocks), vreg/mem declarations, initial memory bytes, and
    ``meta`` (the fusion plan rides there and changes cyclesim timing
    under chaining). Two programs with equal fingerprints lower to the
    same traces on the same configuration."""
    h = hashlib.sha256()

    def put(*parts):
        for p in parts:
            h.update(repr(p).encode("utf-8"))
            h.update(b"\x1f")

    put("program", program.name, program.alg_ops)
    for v in program.vregs:
        put("vreg", v.name, v.id, v.length, v.elem_bytes)
    for m in program.mems:
        put("mem", m.name, m.id, m.length, m.elem_bytes, m.is_output)
    for item in program.items:
        if isinstance(item, ScalarBlock):
            put("scalar", item.count)
        else:
            put(item.op.value, item.dst, item.src1, item.src2,
                item.scalar, item.length, item.elem_bytes)
    # meta: frozen dataclasses (FusionPlan et al.) have deterministic,
    # content-only reprs — no ids or addresses
    for k in sorted(program.meta):
        put("meta", k, program.meta[k])
    for mid in sorted(program.mem_init):
        arr = program.mem_init[mid]
        put("mem_init", mid, str(arr.dtype), arr.shape)
        h.update(arr.tobytes())
    return h.hexdigest()


def resolved_passes(passes) -> list:
    """The pass names a point's spec actually runs: ``None`` resolves
    to the default pipeline, so a changed ``DEFAULT_PASSES`` changes
    every default-pipeline key."""
    from repro.kvi.passes.pipeline import PassPipeline
    return list(PassPipeline.from_spec(passes).names)


def _version_tokens() -> Dict[str, object]:
    # read through the modules (not from-imports) so test monkeypatching
    # of the tokens is visible to key computation
    from repro.kvi import cyclesim
    from repro.kvi.dse import cost
    return {"schema": SCHEMA_VERSION,
            "calibration": cost.CALIBRATION_VERSION,
            "cyclesim_timing": cyclesim.TIMING_VERSION}


def point_key_components(point: DesignPoint,
                         program_fps: Dict[str, str],
                         composite: bool) -> Dict[str, object]:
    """The key's anatomy, exposed for debugging and the README — what
    :func:`point_key` hashes."""
    comp = _version_tokens()
    comp.update({
        "kind": "point",
        "point": point.canonical_dict(),
        "passes": resolved_passes(point.passes),
        "programs": dict(sorted(program_fps.items())),
        "composite": bool(composite),
    })
    return comp


def point_key(point: DesignPoint, program_fps: Dict[str, str],
              composite: bool) -> str:
    """The content address of one (point, optimized kernels) record.

    ``program_fps`` maps kernel name -> :func:`program_fingerprint` of
    the **optimized** program the point executes — so both the raw
    kernel inputs and the behavior of every active pass are covered."""
    return _sha(_canonical_dumps(
        point_key_components(point, program_fps, composite)))


def pallas_class_key(program_fps: Dict[str, str], precision_bits: int,
                     passes, harts: int, composite: bool) -> str:
    """Content address of one Pallas walltime measurement class.
    Pallas execution is scheme/D/SPM-blind, so the class — not the
    point — is the cacheable unit: ``(precision, resolved passes,
    harts)`` over the same programs."""
    comp = _version_tokens()
    comp.update({
        "kind": "pallas",
        "precision_bits": int(precision_bits),
        "passes": resolved_passes(passes),
        "harts": int(harts),
        "composite": bool(composite),
        "programs": dict(sorted(program_fps.items())),
    })
    return _sha(_canonical_dumps(comp))


# ---------------------------------------------------------------------------
# Record (de)serialization
# ---------------------------------------------------------------------------


def record_to_payload(rec) -> Dict[str, object]:
    """A :class:`~repro.kvi.dse.sweep.PointRecord` as a JSON-native
    payload. Floats are stored full-precision (JSON round-trips them
    exactly), so a reloaded record re-serializes byte-identically —
    the cold-vs-warm canonical-JSON guarantee rests on this."""
    p: Dict[str, object] = {"point": rec.point.canonical_dict(),
                            "status": rec.status}
    if rec.reason is not None:
        p["reason"] = rec.reason
    if rec.area is not None:
        a = rec.area
        p["area"] = {"luts": a.luts, "ffs": a.ffs, "dsps": a.dsps,
                     "brams": a.brams, "breakdown": dict(a.breakdown)}
    p["kernels"] = rec.kernels
    if rec.composite is not None:
        p["composite"] = rec.composite
    if rec.lowering is not None:
        p["lowering"] = dict(rec.lowering)
    return p


def record_from_payload(payload: Dict[str, object], point: DesignPoint):
    """Rebuild a :class:`PointRecord` from a stored payload. ``point``
    is the *live* design point of the current sweep (key-equal to the
    stored one by construction; volatile flags like ``measure_pallas``
    may differ, which is why the live object is used)."""
    from repro.kvi.dse.sweep import PointRecord
    area = payload.get("area")
    return PointRecord(
        point=point, status=payload["status"],
        reason=payload.get("reason"),
        area=HardwareCost(
            luts=area["luts"], ffs=area["ffs"], dsps=area["dsps"],
            brams=area["brams"], breakdown=dict(area["breakdown"]))
        if area is not None else None,
        kernels=payload.get("kernels") or {},
        composite=payload.get("composite"),
        wall_s=0.0,
        lowering=payload.get("lowering"),
        cached=True)


# ---------------------------------------------------------------------------
# The on-disk store
# ---------------------------------------------------------------------------


class PointCache:
    """Content-addressed persistent store of sweep measurements.

    One JSON-lines file; each line::

        {"v": 1, "kind": "point"|"pallas", "key": <sha256>,
         "label": <human identity>, "sha": <payload checksum>,
         "payload": {...}}

    Lookups and stores happen only in the sweep's parent process.
    ``label`` is the *identity* of what the entry measures (point name
    or pallas class) independent of content: a miss whose label is
    present under a different key is counted as an **invalidation** —
    the same point measured under changed inputs — and the subsequent
    store replaces the stale entry. Corrupted or schema-stale lines are
    discarded on load and recomputed, never fatal. When the file grows
    past ``max_bytes`` it is compacted (duplicates collapse, oldest
    entries drop first)."""

    def __init__(self, cache_dir: Optional[str] = None,
                 max_bytes: int = DEFAULT_MAX_BYTES):
        self.cache_dir = cache_dir or default_cache_dir()
        self.path = os.path.join(self.cache_dir, STORE_BASENAME)
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.pallas_hits = 0
        self.pallas_misses = 0
        self.stores = 0
        self.corrupt_discarded = 0
        self._entries: Optional[Dict[str, Dict[str, object]]] = None
        self._labels: Dict[tuple, str] = {}
        self._rounds: list = []
        self._round_base: Optional[Dict[str, int]] = None

    # -- round accounting -------------------------------------------------

    _COUNTERS = ("hits", "misses", "invalidations", "pallas_hits",
                 "pallas_misses", "stores", "corrupt_discarded")

    def _counter_snapshot(self) -> Dict[str, int]:
        return {c: getattr(self, c) for c in self._COUNTERS}

    def _close_round(self) -> None:
        if self._round_base is None:
            return
        snap = self._counter_snapshot()
        self._rounds[-1].update(
            {c: snap[c] - self._round_base[c] for c in self._COUNTERS})
        self._round_base = None

    def begin_round(self, label: str) -> None:
        """Open a named accounting round: counter deltas from here to
        the next ``begin_round`` (or a ``stats`` read) are attributed to
        ``label`` in :attr:`rounds`. Multi-round drivers (the search
        tuner's successive-halving rungs) use this to show *which* rung
        the cache paid off in — lifetime counters alone can't."""
        self._close_round()
        self._rounds.append({"label": str(label)})
        self._round_base = self._counter_snapshot()

    @property
    def rounds(self) -> list:
        """Per-round counter deltas: ``[{"label", "hits", ...}, ...]``.
        The open round (if any) is closed by the read."""
        self._close_round()
        return [dict(r) for r in self._rounds]

    # -- loading ----------------------------------------------------------

    def _load(self) -> Dict[str, Dict[str, object]]:
        if self._entries is not None:
            return self._entries
        self._entries = {}
        try:
            f = open(self.path, "r", encoding="utf-8")
        except OSError:
            return self._entries
        with f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    entry = json.loads(line)
                    if entry["v"] != SCHEMA_VERSION:
                        raise ValueError("schema version mismatch")
                    payload = entry["payload"]
                    if entry["sha"] != _sha(_canonical_dumps(payload)):
                        raise ValueError("payload checksum mismatch")
                    key, kind = entry["key"], entry["kind"]
                    label = entry["label"]
                except (ValueError, KeyError, TypeError):
                    self.corrupt_discarded += 1
                    continue
                self._entries[key] = {"kind": kind, "label": label,
                                      "payload": payload}
                self._labels[(kind, label)] = key
        return self._entries

    # -- lookup / store ---------------------------------------------------

    def _lookup(self, kind: str, key: str,
                label: str) -> Optional[Dict[str, object]]:
        entries = self._load()
        entry = entries.get(key)
        if entry is not None and entry["kind"] == kind:
            # deep copy: callers may attach pallas columns to record
            # dicts in place — the stored entry must stay pristine
            return json.loads(_canonical_dumps(entry["payload"]))
        if self._labels.get((kind, label), key) != key:
            self.invalidations += 1
        return None

    def _store(self, kind: str, key: str, label: str,
               payload: Dict[str, object]) -> None:
        entries = self._load()
        blob = _canonical_dumps(payload)
        entries[key] = {"kind": kind, "label": label,
                        "payload": json.loads(blob)}
        stale = self._labels.get((kind, label))
        if stale is not None and stale != key:
            entries.pop(stale, None)
        self._labels[(kind, label)] = key
        line = json.dumps({"v": SCHEMA_VERSION, "kind": kind, "key": key,
                           "label": label, "sha": _sha(blob),
                           "payload": json.loads(blob)},
                          sort_keys=True)
        os.makedirs(self.cache_dir, exist_ok=True)
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(line + "\n")
        self.stores += 1
        try:
            oversized = os.path.getsize(self.path) > self.max_bytes
        except OSError:
            oversized = False
        if oversized:
            self.compact()

    def lookup_point(self, key: str, point: DesignPoint):
        """The cached :class:`PointRecord` for ``key``, or ``None``.
        Hit/miss/invalidation counters update as a side effect."""
        payload = self._lookup("point", key, point.name)
        if payload is None:
            self.misses += 1
            return None
        self.hits += 1
        return record_from_payload(payload, point)

    def store_point(self, key: str, point: DesignPoint, record) -> None:
        self._store("point", key, point.name, record_to_payload(record))

    def lookup_pallas(self, key: str,
                      label: str) -> Optional[Dict[str, object]]:
        """The cached Pallas class measurement payload, or ``None`` —
        a hit means the warm sweep never imports jax for this class."""
        payload = self._lookup("pallas", key, label)
        if payload is None:
            self.pallas_misses += 1
            return None
        self.pallas_hits += 1
        return payload

    def store_pallas(self, key: str, label: str,
                     payload: Dict[str, object]) -> None:
        self._store("pallas", key, label, payload)

    # -- maintenance ------------------------------------------------------

    def compact(self) -> None:
        """Rewrite the store keeping one line per key (last write wins)
        and, if still over ``max_bytes``, dropping oldest entries first.
        Atomic via temp-file + rename."""
        entries = self._load()
        lines = []
        for key, entry in entries.items():      # dict order: oldest first
            blob = _canonical_dumps(entry["payload"])
            lines.append((key, json.dumps(
                {"v": SCHEMA_VERSION, "kind": entry["kind"], "key": key,
                 "label": entry["label"], "sha": _sha(blob),
                 "payload": entry["payload"]}, sort_keys=True) + "\n"))
        total = sum(len(line.encode("utf-8")) for _, line in lines)
        while lines and total > self.max_bytes:
            key, line = lines.pop(0)
            total -= len(line.encode("utf-8"))
            dropped = entries.pop(key)
            self._labels.pop((dropped["kind"], dropped["label"]), None)
        os.makedirs(self.cache_dir, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            for _, line in lines:
                f.write(line)
        os.replace(tmp, self.path)

    @property
    def n_entries(self) -> int:
        return len(self._load())

    @property
    def store_bytes(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    @property
    def stats(self) -> Dict[str, object]:
        """This run's counters plus store shape — what lands in sweep
        meta (``meta["point_cache"]``, scrubbed from canonical JSON)
        and in ``dse_cache_stats.json``."""
        out: Dict[str, object] = {
            "hits": self.hits, "misses": self.misses,
            "invalidations": self.invalidations,
            "pallas_hits": self.pallas_hits,
            "pallas_misses": self.pallas_misses,
            "stores": self.stores,
            "corrupt_discarded": self.corrupt_discarded,
            "entries": self.n_entries,
            "store_bytes": self.store_bytes,
            "path": self.path}
        if self._rounds:
            out["rounds"] = self.rounds
        return out
