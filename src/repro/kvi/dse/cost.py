"""Analytic hardware cost / energy model for one design point.

FPGA-resource flavored (LUT / FF / DSP / BRAM, the paper synthesizes on
a Xilinx Kintex-7), aggregated into one LUT-equivalent area scalar for
Pareto analysis. The model is *relative*, not sign-off: the calibration
constants below are chosen so the orderings the paper's synthesis tables
establish hold —

  * shared (M=1,F=1) is the cheapest scheme, symmetric MIMD (M=F=3) the
    most expensive, heterogeneous MIMD (M=3,F=1) strictly between: SPMI
    replication is cheaper than MFU replication;
  * area grows with lane count D in every scheme (datapath + bank
    interleaver width);
  * sub-word SIMD support (subword_bits < 32) costs extra lane logic
    (splitters, carry breaks, per-subword predication), so an 8-bit
    design point pays area for its cycle advantage;
  * energy-per-cycle at the operating point lands in the few-nJ range
    of the paper's Table 3 (e.g. Sym MIMD D=8, 12k cycles, 29 uJ ->
    ~2.4 nJ/cycle), with static power proportional to area — so faster
    execution saves energy, the paper's ">85% energy saving" mechanism.

Every constant lives in :data:`CALIBRATION` — one documented table, the
single knob future synthesis-data calibration should touch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import MFU_UNITS, KlessydraConfig

#: Version token of the cost model, part of every persistent sweep
#: cache key (:mod:`repro.kvi.dse.pointcache`). Bump it whenever a
#: :data:`CALIBRATION` constant or the area/energy formulas change in a
#: way that alters any number a :class:`PointRecord` carries — cached
#: records keyed to the old token then miss instead of serving stale
#: areas/energies. Deliberately explicit (not a source hash): comment
#: or refactor-only edits must not cold-start every user's cache.
CALIBRATION_VERSION = 1

#: The calibration table. Units: LUTs / FFs / DSP48s / BRAM36s for area
#: entries, nanojoules for energy entries (at the paper's ~100 MHz
#: Kintex-7 operating point).
CALIBRATION: Dict[str, object] = {
    # scalar core: the T13 3-hart IMT front end (fetch/decode/regfile),
    # present once regardless of coprocessor scheme
    "core_luts": 7400.0,
    "core_ffs": 3900.0,
    # per-MFU fixed control (sequencer, CSRs, hart arbitration)
    "mfu_base_luts": 450.0,
    "mfu_base_ffs": 260.0,
    # per-lane datapath cost of each internal functional unit at full
    # 32-bit width (multiplier maps to DSP slices)
    "unit_luts_per_lane": {"adder": 110.0, "multiplier": 55.0,
                          "shifter": 85.0, "cmp": 40.0, "move": 20.0},
    "unit_ffs_per_lane": {"adder": 38.0, "multiplier": 64.0,
                          "shifter": 32.0, "cmp": 16.0, "move": 8.0},
    "multiplier_dsps_per_lane": 3.0,
    # sub-word support factor on lane datapath cost (lane splitters,
    # carry breaks, per-subword predication muxes)
    "subword_factor": {32: 1.0, 16: 1.12, 8: 1.25},
    # SPM banks: one BRAM36 holds ~4 KiB; each SPMI adds a base
    # controller plus a per-bank interleaver slice (width D)
    "bram_kbytes": 4.0,
    "spmi_base_luts": 260.0,
    "spmi_base_ffs": 140.0,
    "spmi_luts_per_bank": 90.0,
    "spmi_ffs_per_bank": 42.0,
    # load/store unit (one per SPMI — it rides the interface port)
    "lsu_luts": 520.0,
    "lsu_ffs": 270.0,
    # LUT-equivalent aggregation weights (a DSP48 / BRAM36 in LUT terms,
    # the usual FPGA area-accounting convention)
    "ff_lut_weight": 0.35,
    "dsp_lut_weight": 102.0,
    "bram_lut_weight": 96.0,
    # energy: static power scales with area; dynamic adds per active
    # engine-cycle costs (lane-count weighted for the MFU stream)
    "static_nj_per_cycle_per_kluteq": 0.045,
    "core_nj_per_cycle": 0.35,
    "mfu_nj_per_active_lane_cycle": 0.011,
    "lsu_nj_per_active_cycle": 0.14,
    # low-fidelity cycle estimator (the search tuner's cheap rung):
    # per-op issue/dependency overhead exposed when a hart's own program
    # chain is the bound (per-hart sym/het schemes; in the shared scheme
    # the saturated SPMI hides it), and the contention factor of the
    # heterogeneous scheme's shared unit pool (per-hart dependency
    # chains prevent the perfect cross-unit overlap a pure capacity
    # bound assumes). Fit once against the cycle-accurate simulator on
    # the smoke space (act/est within ~7% per scheme, rank correlation
    # 0.99) — see tests/kvi/test_search.py.
    "est_issue_overhead_cycles": 2.0,
    "est_het_pool_factor": 1.15,
}


@dataclass(frozen=True)
class HardwareCost:
    """FPGA-resource totals for one configuration, with a per-subsystem
    LUT-equivalent breakdown."""

    luts: float
    ffs: float
    dsps: float
    brams: float
    breakdown: Dict[str, float]       # subsystem -> LUT-equivalent area

    @property
    def area_luteq(self) -> float:
        """One aggregate area scalar (LUT equivalents)."""
        c = CALIBRATION
        return (self.luts + c["ff_lut_weight"] * self.ffs
                + c["dsp_lut_weight"] * self.dsps
                + c["bram_lut_weight"] * self.brams)

    def as_dict(self) -> Dict[str, object]:
        return {"luts": round(self.luts, 1), "ffs": round(self.ffs, 1),
                "dsps": round(self.dsps, 1),
                "brams": round(self.brams, 1),
                "area_luteq": round(self.area_luteq, 1),
                "breakdown": {k: round(v, 1)
                              for k, v in self.breakdown.items()}}


def _luteq(luts: float, ffs: float = 0.0, dsps: float = 0.0,
           brams: float = 0.0) -> float:
    c = CALIBRATION
    return (luts + c["ff_lut_weight"] * ffs + c["dsp_lut_weight"] * dsps
            + c["bram_lut_weight"] * brams)


def mfu_cost(cfg: KlessydraConfig) -> Dict[str, float]:
    """LUT/FF/DSP of all F MFUs: per internal unit, ``fu_count``
    instances of a D-lane datapath, scaled by the sub-word factor."""
    c = CALIBRATION
    sub = c["subword_factor"][cfg.subword_bits]
    luts = cfg.F * c["mfu_base_luts"]
    ffs = cfg.F * c["mfu_base_ffs"]
    dsps = 0.0
    for unit in MFU_UNITS:
        n = cfg.F * cfg.fu_count(unit) * cfg.D
        luts += n * c["unit_luts_per_lane"][unit] * sub
        ffs += n * c["unit_ffs_per_lane"][unit] * sub
        if unit == "multiplier":
            dsps += n * c["multiplier_dsps_per_lane"]
    return {"luts": luts, "ffs": ffs, "dsps": dsps}


def spm_cost(cfg: KlessydraConfig) -> Dict[str, float]:
    """BRAM for the SPM arrays plus the M replicated SPMI interleavers
    (width D) and their LSU ports."""
    c = CALIBRATION
    brams = cfg.M * cfg.N * (cfg.spm_kbytes / c["bram_kbytes"])
    luts = cfg.M * (c["spmi_base_luts"]
                    + cfg.D * c["spmi_luts_per_bank"] + c["lsu_luts"])
    ffs = cfg.M * (c["spmi_base_ffs"]
                   + cfg.D * c["spmi_ffs_per_bank"] + c["lsu_ffs"])
    return {"luts": luts, "ffs": ffs, "brams": brams}


def hardware_cost(cfg: KlessydraConfig) -> HardwareCost:
    """The full configuration: scalar core + MFUs + SPM subsystem."""
    c = CALIBRATION
    mfu = mfu_cost(cfg)
    spm = spm_cost(cfg)
    luts = c["core_luts"] + mfu["luts"] + spm["luts"]
    ffs = c["core_ffs"] + mfu["ffs"] + spm["ffs"]
    dsps = mfu["dsps"]
    brams = spm["brams"]
    breakdown = {
        "core": _luteq(c["core_luts"], c["core_ffs"]),
        "mfu": _luteq(mfu["luts"], mfu["ffs"], mfu["dsps"]),
        "spm": _luteq(spm["luts"], spm["ffs"], brams=spm["brams"]),
    }
    return HardwareCost(luts, ffs, dsps, brams, breakdown)


def energy_per_cycle_static(cfg: KlessydraConfig) -> float:
    """Static + clock-tree nJ burned every cycle, area-proportional."""
    c = CALIBRATION
    return (c["core_nj_per_cycle"]
            + c["static_nj_per_cycle_per_kluteq"]
            * hardware_cost(cfg).area_luteq / 1000.0)


#: Calibration-fit gate: maximum per-row relative error of the model's
#: nJ/cycle against the paper's Table 3 measured energies, after the
#: two-parameter dynamic-energy regression below. The current
#: CALIBRATION table fits within ~15%; 0.25 leaves headroom for future
#: retuning without letting the model drift into a different energy
#: regime (2x would mean the static/dynamic split is wrong, not noisy).
CALIBRATION_FIT_MAX_REL_ERR = 0.25

#: Table 3 row label -> the (M, F) of the scheme it measures.
_TABLE3_SCHEMES = {"T13 SIMD": (1, 1), "T13 Sym MIMD": (3, 3),
                   "T13 Het MIMD": (3, 1)}


def calibration_fit(table3: Optional[Dict] = None) -> Dict[str, object]:
    """Regress the energy model against the paper's Table 3 energies.

    Every T13 row of Table 3 gives a measured energy-per-cycle at one
    (scheme, D) operating point: ``E_uJ / kcycles`` nJ/cycle. The model
    predicts ``energy_per_cycle_static(cfg)`` (area-proportional, fully
    determined by :data:`CALIBRATION`) plus a dynamic term the paper's
    table cannot pin per-component — so the dynamic part is regressed
    here as the least-squares line ``a*D + b`` over the residuals
    (``a`` absorbs the lane-count-weighted MFU stream, ``b`` the LSU
    and issue overhead), exactly the shape of
    :func:`energy_model`'s dynamic terms.

    Returns per-row observed/predicted nJ/cycle with relative errors,
    the fitted ``(a, b)``, and ``ok`` — False when ``max_rel_err``
    exceeds :data:`CALIBRATION_FIT_MAX_REL_ERR` (the bench ``--check``
    gate). A failing fit means the CALIBRATION constants have drifted
    out of the paper's energy regime, not that a run was noisy: every
    input here is a published table value."""
    if table3 is None:
        # deferred: benchmarks/ is a sibling top-level package, present
        # when running from the repo root (tests, CI, the bench harness)
        from benchmarks.paper_data import TABLE3_FILTERS
        table3 = TABLE3_FILTERS
    rows = []
    for (label, D), by_order in sorted(table3.items()):
        mf = _TABLE3_SCHEMES.get(label)
        if mf is None:                   # baseline cores: no coprocessor
            continue
        cfg = KlessydraConfig(f"{label} D={D}", M=mf[0], F=mf[1], D=D)
        static = energy_per_cycle_static(cfg)
        for order, (kcycles, _t_us, e_uj) in sorted(by_order.items()):
            rows.append({"scheme": label, "D": D, "filter_order": order,
                         "observed_nj_per_cycle": e_uj / kcycles,
                         "static_nj_per_cycle": static})
    resid = np.array([r["observed_nj_per_cycle"]
                      - r["static_nj_per_cycle"] for r in rows])
    lanes = np.array([[r["D"], 1.0] for r in rows])
    (a, b), *_ = np.linalg.lstsq(lanes, resid, rcond=None)
    rel_errs = []
    for r in rows:
        pred = float(r["static_nj_per_cycle"] + a * r["D"] + b)
        r["predicted_nj_per_cycle"] = round(pred, 4)
        r["rel_err"] = round(
            abs(pred - r["observed_nj_per_cycle"])
            / r["observed_nj_per_cycle"], 4)
        r["observed_nj_per_cycle"] = round(
            r["observed_nj_per_cycle"], 4)
        r["static_nj_per_cycle"] = round(r["static_nj_per_cycle"], 4)
        rel_errs.append(r["rel_err"])
    max_err = max(rel_errs)
    return {"rows": rows,
            "dyn_nj_per_lane_cycle": round(float(a), 5),
            "dyn_nj_per_cycle_base": round(float(b), 5),
            "max_rel_err": round(max_err, 4),
            "mean_rel_err": round(float(np.mean(rel_errs)), 4),
            "threshold": CALIBRATION_FIT_MAX_REL_ERR,
            "ok": bool(max_err <= CALIBRATION_FIT_MAX_REL_ERR)}


# ---------------------------------------------------------------------------
# Low-fidelity analytic cycle estimation (the search tuner's cheap rung)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KernelProfile:
    """Static per-program operand arrays — everything the closed-form
    cycle estimator needs, extracted **once** per optimized program (no
    lowering, no SPM allocation, no simulation). All arrays are aligned
    over the program's coprocessor instructions:

      * ``lengths`` / ``elem_bytes`` — vector shape per op,
      * ``n_src`` — vector sources streamed per result line (the SPMI
        read-port pressure),
      * ``unit_idx`` — index into :data:`~repro.configs.base.MFU_UNITS`
        (-1 for LSU transfers),
      * ``mem_bytes`` — transfer size of LSU ops (0 for MFU ops),
      * ``chainable`` — ops a chaining-enabled lowering would discount
        (interior of a planned fused region, from the same static
        fusion-plan metadata ``lowering._chained_items`` reads).

    The estimator is a *rank* model: it reproduces the contention
    structure (per-scheme serialization, shared LSU port, het per-unit
    pools) that orders design points, not exact cycle counts — the
    search confirms survivors on the cycle-accurate simulator."""

    name: str
    lengths: np.ndarray
    elem_bytes: np.ndarray
    n_src: np.ndarray
    unit_idx: np.ndarray
    mem_bytes: np.ndarray
    chainable: np.ndarray
    n_scalar: int = 0


def kernel_profile(program) -> KernelProfile:
    """Build the :class:`KernelProfile` of one (optimized) KVI program."""
    from repro.kvi.ir import KviInstr
    from repro.kvi.lowering import _chained_items
    from repro.core.isa import OPDEFS

    unit_of = {u: i for i, u in enumerate(MFU_UNITS)}
    chained = _chained_items(program)
    lengths, ebs, n_src, unit_idx, mem_bytes, chainable = \
        [], [], [], [], [], []
    n_scalar = 0
    for idx, it in enumerate(program.items):
        if not isinstance(it, KviInstr):
            n_scalar += it.count
            continue
        od = OPDEFS[it.op.value]
        lengths.append(it.length)
        ebs.append(it.elem_bytes)
        if od.engine == "lsu":
            unit_idx.append(-1)
            n_src.append(0)
            mem_bytes.append(it.length * it.elem_bytes)
        else:
            unit_idx.append(unit_of[od.unit.value])
            n_src.append(max(int(it.src1 is not None)
                             + int(it.src2 is not None), 1))
            mem_bytes.append(0)
        chainable.append(idx in chained)
    return KernelProfile(
        program.name,
        np.asarray(lengths, dtype=np.int64),
        np.asarray(ebs, dtype=np.int64),
        np.asarray(n_src, dtype=np.int64),
        np.asarray(unit_idx, dtype=np.int64),
        np.asarray(mem_bytes, dtype=np.int64),
        np.asarray(chainable, dtype=bool),
        n_scalar)


def estimate_kernel(profile: KernelProfile, cfg: KlessydraConfig,
                    chaining: bool = False) -> Dict[str, float]:
    """Closed-form cycle + energy estimate of the paper's homogeneous
    protocol (``profile`` replicated on every hart of ``cfg``) —
    vectorized numpy over the profile's op arrays, thousands of points
    per second.

    The contention structure mirrors the simulator's resource model:
    per-op SPMI streaming (``n_src`` lines per result line) and
    line-rate unit occupancy; the shared scheme serializes every stream
    on one SPMI, sym-MIMD runs per-hart, het-MIMD pools F x fu_count
    instances per internal unit; the single 32-bit memory port is
    shared by all schemes."""
    H = cfg.harts
    setup = cfg.vector_setup_cycles
    is_mfu = profile.unit_idx >= 0
    eff_eb = np.maximum(profile.elem_bytes, cfg.subword_bits // 8)
    lanes = cfg.D * np.maximum(1, 4 // eff_eb)
    lines = np.ceil(profile.lengths / np.maximum(lanes, 1)).astype(np.int64)
    unit_c = np.where(is_mfu, setup + lines, 0)
    spmi_c = np.where(is_mfu, setup + profile.n_src * lines, 0)
    lsu_c = np.where(
        ~is_mfu,
        setup + cfg.mem_latency_cycles
        + np.ceil(profile.mem_bytes / cfg.mem_port_bytes).astype(np.int64),
        0)
    if chaining:
        disc = np.where(profile.chainable & is_mfu, setup, 0)
        unit_c = np.maximum(np.where(is_mfu, 1, 0), unit_c - disc)
        spmi_c = np.maximum(np.where(is_mfu, 1, 0), spmi_c - disc)
    op_dur = np.maximum(np.maximum(unit_c, spmi_c), lsu_c)

    c0 = CALIBRATION["est_issue_overhead_cycles"]
    if cfg.M == 1 and cfg.F == 1:            # shared: one SPMI, one MFU
        est = H * float(op_dur.sum()) + profile.n_scalar
    else:
        t_serial = float((op_dur + c0).sum()) + profile.n_scalar
        t_lsu = float(lsu_c.sum()) + c0 * int((~is_mfu).sum())
        if cfg.F == cfg.M and cfg.F > 1:     # sym: only the LSU port shared
            est = max(t_serial, H * t_lsu)
        else:                                # het: per-internal-unit pools
            pool_bound = 0.0
            for i, unit in enumerate(MFU_UNITS):
                tu = float(unit_c[profile.unit_idx == i].sum())
                pool_bound = max(pool_bound,
                                 H * tu / (cfg.F * cfg.fu_count(unit)))
            est = CALIBRATION["est_het_pool_factor"] \
                * max(t_serial, H * t_lsu, pool_bound)
    est = max(est, 1.0)

    mfu_busy = H * float(np.where(is_mfu, op_dur, 0).sum())
    lsu_busy = H * float(lsu_c.sum())
    static = energy_per_cycle_static(cfg) * est
    c = CALIBRATION
    energy = (static + c["mfu_nj_per_active_lane_cycle"] * cfg.D * mfu_busy
              + c["lsu_nj_per_active_cycle"] * lsu_busy)
    return {"est_cycles": est, "est_energy_nj": energy}


def batch_estimate(profiles: Dict[str, KernelProfile], points,
                   ) -> List[Dict[str, object]]:
    """Low-fidelity scores for an explicit point list: per point, the
    analytic area plus per-kernel ``est_cycles`` / ``est_energy_nj``.
    ``profiles`` may be keyed per precision (``(precision_bits ->
    {kernel: profile})``) or flat (``{kernel: profile}`` applied to all
    points). Pure closed-form — safe to call on thousands of points."""
    out: List[Dict[str, object]] = []
    per_prec = profiles and all(
        isinstance(k, int) for k in profiles)
    for pt in points:
        cfg = pt.config()
        kern_profiles = profiles[pt.precision_bits] if per_prec \
            else profiles
        row: Dict[str, object] = {
            "point": pt.name,
            "area_luteq": hardware_cost(cfg).area_luteq,
            "kernels": {name: estimate_kernel(prof, cfg,
                                              chaining=pt.chaining)
                        for name, prof in kern_profiles.items()}}
        out.append(row)
    return out


def energy_model(cfg: KlessydraConfig, sim) -> Dict[str, float]:
    """Energy of one simulated run (``sim`` is a
    :class:`~repro.core.simulator.SimResult`): static power for the
    whole window plus dynamic energy for the MFU-stream and LSU busy
    cycles. Lane-count weights the MFU stream (D banks switching), with
    sub-word packing holding the switched width constant — narrow
    elements save energy through *fewer cycles*, not cheaper cycles."""
    c = CALIBRATION
    lanes = cfg.D
    static = energy_per_cycle_static(cfg) * sim.cycles
    mfu_dyn = c["mfu_nj_per_active_lane_cycle"] * lanes * sim.mfu_busy_cycles
    lsu_dyn = c["lsu_nj_per_active_cycle"] * sim.lsu_busy_cycles
    total = static + mfu_dyn + lsu_dyn
    return {"energy_nj": total, "static_nj": static,
            "mfu_dynamic_nj": mfu_dyn, "lsu_dynamic_nj": lsu_dyn,
            "nj_per_cycle": total / max(sim.cycles, 1)}
