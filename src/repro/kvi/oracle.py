"""OracleBackend — pure-numpy functional execution (repro.core.mfu).

The reference semantics: int32 two's-complement fixed point, 64-bit
intermediate products wrapped to the element width, exactly the paper's
MFU datapath. No timing. Used as the ground truth for differential tests
against the cycle-sim and Pallas backends.
"""
from __future__ import annotations

from typing import Optional

from repro.configs.base import KlessydraConfig
from repro.kvi.backend import BackendBase, BackendResult, register_backend
from repro.kvi.workload import (KviWorkload, WorkloadResult,
                                dedup_entry_outputs)
from repro.kvi.lowering import lower

# Functionally the SPM is just an address space: give the oracle a big one
# so any program the other backends accept lowers here too.
_ORACLE_CFG = KlessydraConfig("oracle", M=1, F=1, D=4, spm_kbytes=256)


@register_backend("oracle")
class OracleBackend(BackendBase):
    """Functional reference executor (no timing model). Workloads execute
    entry-by-entry — hart assignments do not change functional values.

    ``passes=()`` runs the raw, unoptimized program — the ground truth
    the differential fuzz tests compare every optimized run against."""

    def __init__(self, config: Optional[KlessydraConfig] = None,
                 passes=None, verify: bool = False):
        self.config = config or _ORACLE_CFG
        self.passes = passes
        self.verify = verify

    def run_workload(self, workload: KviWorkload,
                     verify: Optional[bool] = None) -> WorkloadResult:
        workload = self.optimize_workload(workload, verify=verify)
        outs = dedup_entry_outputs(
            workload.entries,
            lambda p: lower(p, self.config).execute())
        return WorkloadResult(
            self.name, workload,
            tuple(BackendResult(self.name, out) for out in outs))
