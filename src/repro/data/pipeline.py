"""Deterministic, shardable, resumable data pipeline.

Sources:
  * synthetic  — seeded token streams (markov-ish mixture so small models
                 have learnable structure; loss decreases measurably)
  * file       — byte-level tokenization of a text file, chunked into
                 sequences (used by examples/train_lm.py)

Determinism contract: batch(step) is a pure function of (seed, step,
host_id) — restart/resume at any step reproduces the exact stream, and
elastic re-sharding (different host count) re-partitions the same global
stream. Prefetch is a background thread pipelining host batch assembly.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.steps import LABEL_IGNORE


@dataclass(frozen=True)
class DataConfig:
    source: str = "synthetic"        # synthetic | file
    path: Optional[str] = None
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


class SyntheticTokens:
    """Seeded mixture of repeated n-grams + noise: predictable enough that
    a 100M model's loss visibly drops within tens of steps."""

    def __init__(self, vocab: int, seed: int):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.n_patterns = 64
        self.patterns = rng.integers(
            0, vocab, (self.n_patterns, 16)).astype(np.int32)

    def sequence(self, seed: int, length: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        out = np.empty(length + 1, np.int32)
        i = 0
        while i < length + 1:
            if rng.random() < 0.8:
                p = self.patterns[rng.integers(self.n_patterns)]
                n = min(len(p), length + 1 - i)
                out[i:i + n] = p[:n]
                i += n
            else:
                out[i] = rng.integers(self.vocab)
                i += 1
        return out


class FileTokens:
    """Byte-level tokenizer over a text file (vocab 256 + offset)."""

    def __init__(self, path: str, vocab: int):
        raw = Path(path).read_bytes()
        self.data = np.frombuffer(raw, np.uint8).astype(np.int32) % vocab
        self.vocab = vocab

    def sequence(self, seed: int, length: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        if len(self.data) <= length + 1:
            reps = (length + 2) // len(self.data) + 1
            data = np.tile(self.data, reps)
        else:
            data = self.data
        start = rng.integers(0, len(data) - length - 1)
        return data[start:start + length + 1].copy()


class DataPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig):
        self.cfg, self.shape, self.dc = cfg, shape, data_cfg
        vocab = cfg.vocab_size
        if data_cfg.source == "file":
            assert data_cfg.path, "file source needs a path"
            self.src = FileTokens(data_cfg.path, vocab)
        else:
            self.src = SyntheticTokens(vocab, data_cfg.seed)
        assert shape.global_batch % data_cfg.num_hosts == 0
        self.host_batch = shape.global_batch // data_cfg.num_hosts
        self._queue: "queue.Queue" = queue.Queue(maxsize=data_cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- pure batch construction ----------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host_id): the resume contract."""
        cfg, shape, dc = self.cfg, self.shape, self.dc
        S = shape.seq_len
        rows = []
        for b in range(self.host_batch):
            gidx = (step * shape.global_batch +
                    dc.host_id * self.host_batch + b)
            seed = (dc.seed * 1_000_003 + gidx) % (2 ** 63)
            if cfg.family == "audio":
                rows.append(self.src.sequence(seed, S // 2))
            elif cfg.family == "vlm":
                rows.append(self.src.sequence(seed, S - cfg.frontend_len))
            else:
                rows.append(self.src.sequence(seed, S))
        toks = np.stack(rows)
        batch: Dict[str, np.ndarray] = {}
        if cfg.family == "audio":
            Se = S // 2
            frng = np.random.default_rng((dc.seed, step, dc.host_id, 7))
            batch["frames"] = frng.normal(
                0, 1, (self.host_batch, Se, cfg.d_model)).astype(np.float32)
            batch["tokens"] = toks[:, :-1]
            batch["labels"] = toks[:, 1:]
        elif cfg.family == "vlm":
            Fl = cfg.frontend_len
            frng = np.random.default_rng((dc.seed, step, dc.host_id, 11))
            batch["patch_embeds"] = frng.normal(
                0, 1, (self.host_batch, Fl, cfg.d_model)).astype(np.float32)
            batch["tokens"] = toks[:, :-1]
            # labels cover the concatenated stream; patch positions masked
            lab = np.full((self.host_batch, S), LABEL_IGNORE, np.int32)
            lab[:, Fl:] = toks[:, 1:]
            batch["labels"] = lab
        else:
            batch["tokens"] = toks[:, :-1]
            batch["labels"] = toks[:, 1:]
        return batch

    # ---- prefetching iterator --------------------------------------------
    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._queue.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._stop.clear()
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._queue.get()
        finally:
            self._stop.set()

    def close(self):
        self._stop.set()


def make_batch_fn(cfg: ModelConfig, shape: ShapeConfig, data_cfg: DataConfig):
    pipe = DataPipeline(cfg, shape, data_cfg)
    return pipe.batch_at
