from repro.data.pipeline import DataConfig, DataPipeline, make_batch_fn
