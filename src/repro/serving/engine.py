"""Batched serving engine: continuous batching over a fixed-slot KV cache.

The paper's composite-workload idea at serving granularity: B cache slots
are the "harts"; heterogeneous requests (different lengths/phases) share
the same compute engine. Scheduler policy:

  * new requests are admitted into free slots (prefill one sequence at a
    time through the shared prefill step — TPU-friendly static shapes),
  * every engine step decodes ALL active slots in one batched decode_step,
  * finished sequences (EOS or max_tokens) free their slot immediately
    (continuous batching — no head-of-line blocking on long generations).

Runs on CPU with small models in examples/serve_lm.py; the same engine
drives the decode_32k serving cells on the production mesh.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import steps as steps_lib


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 32
    eos_id: int = -1                   # -1 => never
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = field(default_factory=time.monotonic)
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 512, rules=None, par=None):
        from repro.configs.base import Parallelism
        from repro.models.sharding import make_rules
        self.cfg = cfg
        self.par = par or Parallelism(remat="none")
        self.rules = rules or make_rules(None, cfg, self.par)
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        shape = ShapeConfig("serve", "decode", max_seq, slots)
        self.shape = shape

        self._decode = jax.jit(steps_lib.make_decode_step(
            cfg, self.rules, self.par, shape), donate_argnums=(1,))
        # per-slot prefill uses batch=1 cache then scatters into slot caches;
        # for simplicity and static shapes we re-embed prompts token-by-token
        # through the decode step (prefill == teacher-forced decode), which
        # keeps ONE compiled executable for the whole engine.
        self.cache = self._init_cache()
        self.active: Dict[int, Request] = {}       # slot -> request
        self.queue: List[Request] = []
        self.slot_pos = np.zeros(slots, np.int64)  # per-slot write position
        self.slot_prompt_left: Dict[int, List[int]] = {}
        self._finished: List[Request] = []

    # ------------------------------------------------------------------
    def _init_cache(self):
        from repro.models import params as params_lib
        t = steps_lib.cache_template(self.cfg, self.shape)
        return params_lib.initialize(t, jax.random.PRNGKey(0))

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _reset_slot(self, s: int):
        """Invalidate a slot's cache lines before reuse (continuous
        batching: new request must not attend to stale entries)."""
        lc = self.cache["layers"]
        for key in ("cpos",):
            if key in lc:
                lc[key] = lc[key].at[:, s, :].set(-1)
        for key in ("conv", "state"):
            if key in lc:
                lc[key] = lc[key].at[:, s].set(0)
        self.cache["pos"] = self.cache["pos"].at[s].set(0)
        self.cache["layers"] = lc

    def _admit(self):
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.queue:
            s = free.pop(0)
            req = self.queue.pop(0)
            self._reset_slot(s)
            self.active[s] = req
            self.slot_prompt_left[s] = list(req.prompt)
        return

    def step(self):
        """One engine step: feed each active slot its next token (prompt
        token during prefill phase, last sampled token during decode)."""
        self._admit()
        if not self.active:
            return False
        tokens = np.zeros((self.slots, 1), np.int32)
        for s, req in self.active.items():
            left = self.slot_prompt_left[s]
            if left:
                tokens[s, 0] = left.pop(0)
            else:
                tokens[s, 0] = req.out_tokens[-1] if req.out_tokens else 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        next_tok = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        now = time.monotonic()
        done_slots = []
        for s, req in self.active.items():
            if self.slot_prompt_left[s]:
                continue                       # still prefill phase
            tok = int(next_tok[s])
            if req.first_token_at is None:
                req.first_token_at = now
            req.out_tokens.append(tok)
            if tok == req.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done_at = now
                done_slots.append(s)
        for s in done_slots:
            self._finished.append(self.active.pop(s))
            self.slot_prompt_left.pop(s, None)
        return True

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self._finished

    @property
    def finished(self) -> List[Request]:
        return self._finished
