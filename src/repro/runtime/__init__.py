from repro.runtime.fault_tolerance import (FaultToleranceConfig, Heartbeats,
                                           PreemptionGuard,
                                           StragglerDetector, plan_remesh)
