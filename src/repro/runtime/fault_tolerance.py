"""Fault-tolerance runtime for 1000+-node operation.

Components (all host-side, framework-agnostic, unit-tested):

  * Heartbeats        — per-host liveness registry; detects missing hosts
                        within `timeout_s` and emits a remesh plan.
  * plan_remesh       — elastic scaling: given surviving hosts, pick the
                        largest (data' x model) mesh that keeps the model
                        axis intact (TP groups must be co-located) and
                        rebalance global batch; returns a RemeshPlan the
                        trainer applies by re-lowering + elastic restore
                        (checkpoint/manager.restore with new shardings).
  * StragglerDetector — per-step-time EMA + MAD outlier test; flags hosts
                        that exceed `k` deviations for `patience` steps
                        (mitigation: report / drop into remesh plan).
  * PreemptionGuard   — SIGTERM/SIGINT handler that requests a synchronous
                        checkpoint at the next step boundary (the classic
                        preemptible-VM save-on-signal pattern).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass(frozen=True)
class FaultToleranceConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_k: float = 4.0            # MAD multiplier
    straggler_patience: int = 5
    min_data_parallel: int = 1


# ---------------------------------------------------------------------------
# liveness
# ---------------------------------------------------------------------------

class Heartbeats:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last: Dict[int, float] = {h: clock() for h in hosts}

    def beat(self, host: int, at: Optional[float] = None):
        self.last[host] = self.clock() if at is None else at

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = self.clock() if now is None else now
        return sorted(h for h, t in self.last.items()
                      if now - t > self.timeout)

    def alive_hosts(self, now: Optional[float] = None) -> List[int]:
        dead = set(self.dead_hosts(now))
        return sorted(h for h in self.last if h not in dead)


# ---------------------------------------------------------------------------
# elastic remesh planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RemeshPlan:
    data_axis: int
    model_axis: int
    hosts: tuple
    global_batch: int
    dropped_hosts: tuple

    @property
    def n_chips(self) -> int:
        return self.data_axis * self.model_axis


def plan_remesh(alive_hosts: Sequence[int], chips_per_host: int,
                model_axis: int, global_batch: int,
                *, min_data_parallel: int = 1,
                dropped: Sequence[int] = ()) -> RemeshPlan:
    """Largest power-of-two data axis that the surviving chips support,
    keeping the model (TP) axis intact. Batch stays divisible by rounding
    down to a multiple of the new data axis."""
    chips = len(alive_hosts) * chips_per_host
    if chips < model_axis * min_data_parallel:
        raise RuntimeError(
            f"only {chips} chips alive; need >= {model_axis * min_data_parallel}")
    data = chips // model_axis
    # keep power-of-two data axis for clean batch math
    p = 1
    while p * 2 <= data:
        p *= 2
    data = p
    used_hosts = alive_hosts[: (data * model_axis) // chips_per_host]
    gb = max((global_batch // data) * data, data)
    return RemeshPlan(data, model_axis, tuple(used_hosts), gb,
                      tuple(dropped))


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

class StragglerDetector:
    """Median + MAD over per-host step durations; robust to the stragglers
    it is trying to detect."""

    def __init__(self, hosts: Sequence[int], k: float = 4.0,
                 patience: int = 5):
        self.k = k
        self.patience = patience
        self.strikes: Dict[int, int] = {h: 0 for h in hosts}

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        import numpy as np
        vals = np.array(list(step_times.values()))
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med))) + 1e-9
        flagged = []
        for h, t in step_times.items():
            if (t - med) / (1.4826 * mad) > self.k:
                self.strikes[h] = self.strikes.get(h, 0) + 1
            else:
                self.strikes[h] = 0
            if self.strikes.get(h, 0) >= self.patience:
                flagged.append(h)
        return sorted(flagged)


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

class PreemptionGuard:
    """``with PreemptionGuard() as g: ... if g.requested: save+exit``."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.signals = signals
        self.requested = False
        self._old = {}

    def _handler(self, signum, frame):
        self.requested = True

    def __enter__(self):
        for s in self.signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, old in self._old.items():
            signal.signal(s, old)
        return False
