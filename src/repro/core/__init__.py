"""The paper's primary contribution as an executable system:

  isa/spm/mfu    — the Table-1 scratchpad-resident vector ISA (functional)
  coprocessor    — the SISD/SIMD/sym-MIMD/het-MIMD taxonomy (KlessydraConfig)
  simulator      — event-driven IMT + coprocessor cycle model
  programs       — conv2d / FFT / MatMul as KVI vector programs
  workloads      — homogeneous/composite measurement protocol + energy model
  baselines      — T03 / RI5CY / ZeroRiscy comparison cores (calibrated)

The TPU-scale incarnation of the same ideas lives in repro.kernels (Pallas,
SPM->VMEM) and repro.models/launch (TLP/DLP -> mesh axes).
"""
from repro.configs.base import KlessydraConfig, klessydra_taxonomy
from repro.core import baselines, mfu, programs, simulator, spm, workloads
from repro.core.isa import Instr, OPDEFS, Scalar, Unit
from repro.core.simulator import SimResult, simulate
