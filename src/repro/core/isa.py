"""The Klessydra-T custom vector instruction extension (paper Table 1).

Each instruction has:
  * functional semantics over SPM-resident int32 fixed-point vectors
    (executed by ``repro.core.mfu``), and
  * a timing/contention class used by the cycle simulator:
      - ``unit``: which MFU internal functional unit it occupies
        (the heterogeneous-MIMD scheme contends on these individually), and
      - ``engine``: MFU vs LSU (LSU transfers overlap MFU compute).

Latency model (paper: "latency proportional to the vector length", SPM line
= D banks per cycle, initial latency 4-8 cycles): setup + ceil(len/D) for
MFU ops; setup_mem + ceil(bytes/mem_port) for LSU ops.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, Optional, Tuple

import numpy as np


class Unit(Enum):
    ADDER = "adder"
    MULTIPLIER = "multiplier"
    SHIFTER = "shifter"
    CMP = "cmp"
    MOVE = "move"
    LSU = "lsu"


@dataclass(frozen=True)
class OpDef:
    name: str
    unit: Unit
    engine: str            # "mfu" | "lsu"
    description: str


# paper Table 1, verbatim order
OPDEFS: Dict[str, OpDef] = {o.name: o for o in [
    OpDef("kmemld", Unit.LSU, "lsu", "load vector into scratchpad region"),
    OpDef("kmemstr", Unit.LSU, "lsu", "store vector into main memory"),
    OpDef("kaddv", Unit.ADDER, "mfu", "adds vectors in scratchpad region"),
    OpDef("ksubv", Unit.ADDER, "mfu", "subtract vectors in scratchpad region"),
    OpDef("kvmul", Unit.MULTIPLIER, "mfu", "multiply vectors in scratchpad"),
    OpDef("kvred", Unit.ADDER, "mfu", "reduce vector by addition"),
    OpDef("kdotp", Unit.MULTIPLIER, "mfu", "vector dot product into register"),
    OpDef("ksvaddsc", Unit.ADDER, "mfu", "add vector + scalar into scratchpad"),
    OpDef("ksvaddrf", Unit.ADDER, "mfu", "add vector + scalar into register"),
    OpDef("ksvmulsc", Unit.MULTIPLIER, "mfu",
          "multiply vector + scalar into scratchpad"),
    OpDef("ksvmulrf", Unit.MULTIPLIER, "mfu",
          "multiply vector + scalar into register"),
    OpDef("kdotpps", Unit.MULTIPLIER, "mfu",
          "vector dot product and post scaling"),
    OpDef("ksrlv", Unit.SHIFTER, "mfu", "vector logic shift within scratchpad"),
    OpDef("ksrav", Unit.SHIFTER, "mfu",
          "vector arithmetic shift within scratchpad"),
    OpDef("krelu", Unit.CMP, "mfu", "vector ReLu within scratchpad"),
    OpDef("kvslt", Unit.CMP, "mfu", "compare vectors and create mask vector"),
    OpDef("ksvslt", Unit.CMP, "mfu", "compare vector-scalar and create mask"),
    OpDef("kvcp", Unit.MOVE, "mfu", "copy vector within scratchpad region"),
]}


@dataclass
class Instr:
    """One dynamic KVI instruction instance.

    dst/src1/src2 are SPM addresses (byte offsets into the unified SPM
    address space) or None; ``scalar`` holds an immediate/register scalar
    operand; ``length`` is the element count (32-bit elements by default).
    """
    op: str
    dst: Optional[int] = None
    src1: Optional[int] = None
    src2: Optional[int] = None
    scalar: int = 0
    length: int = 0
    elem_bytes: int = 4

    def __post_init__(self):
        if self.op not in OPDEFS and self.op != "scalar":
            raise ValueError(f"unknown KVI op {self.op!r}")

    @property
    def unit(self) -> Unit:
        return OPDEFS[self.op].unit

    @property
    def engine(self) -> str:
        return OPDEFS[self.op].engine

    @property
    def bytes(self) -> int:
        return self.length * self.elem_bytes


@dataclass
class Scalar:
    """A compressed run of ``count`` scalar (non-coprocessor) instructions —
    loop bookkeeping, address arithmetic, branches. Each consumes one issue
    slot of its hart."""
    count: int

    op: str = "scalar"
    engine: str = "none"


def mfu_cycles(instr: Instr, D: int, setup: int,
               min_elem_bytes: int = 1) -> Tuple[int, int]:
    """(unit_cycles, spmi_cycles) for one vector op.

    * SPMI streaming: one SPM line (D banks) per cycle PER VECTOR SOURCE —
      each SPM has a single read port, so two-source ops (kaddv, kvmul,
      kdotp, ...) stream two lines per result line. The paper's own D-sweep
      implies this: conv32 cycle deltas between D=1/2/4/8 are ~1.6x the
      single-pass prediction and fit the two-pass model within ~5%.
    * Functional unit occupancy: one line per cycle (the adder/multiplier
      pipelines are line-rate) — this is why heterogeneous MIMD (shared
      units, per-hart SPMIs) stays within 1-7% of symmetric MIMD in the
      paper: the SPMI streaming, not the unit, is the real bottleneck.

    Sub-word SIMD: 8/16-bit elements pack more lanes per 32-bit bank —
    but only down to the hardware's narrowest supported lane width
    (``min_elem_bytes`` = config.subword_bits/8). A datapath without
    sub-word lanes (min_elem_bytes=4) streams narrow elements one per
    bank, getting no packing benefit."""
    eff_eb = max(instr.elem_bytes, min_elem_bytes)
    lanes = D * max(1, 4 // eff_eb)
    n_src = max(int(instr.src1 is not None) + int(instr.src2 is not None), 1)
    lines = int(np.ceil(instr.length / max(lanes, 1)))
    return setup + lines, setup + n_src * lines


def lsu_cycles(instr: Instr, mem_port_bytes: int, setup: int) -> int:
    """Main-memory transfer: 32-bit port, one word per cycle."""
    return setup + int(np.ceil(instr.bytes / mem_port_bytes))
