"""Analytic cycle models for the comparison cores of Table 2.

  * Klessydra-T03: the same IMT core without the vector coprocessor —
    scalar RV32IMA code, IPC=1 aggregate across 3 harts (no stalls by
    construction), no DSP/hardware-loop support.
  * RI5CY: single-issue in-order with DSP extension (MAC + hardware loops)
    — fewer instructions per MAC, but load-use and branch stalls.
  * ZeroRiscy: 2-stage single-issue, no DSP — more cycles per MAC
    (multi-cycle multiplier) + branch overhead.

The per-MAC instruction constants are calibrated once against the paper's
published Table 2 cycle counts (they are *data*, recorded below), and the
models then generalize across kernel sizes — benchmarks/table2 checks the
model against every published cell.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ScalarCoreModel:
    name: str
    # cycles per inner-loop MAC (load, mul, add, store amortized, index)
    conv_mac: float
    matmul_mac: float
    fft_butterfly: float            # cycles per radix-2 butterfly
    loop_overhead: float            # per inner-loop iteration extra
    kernel_overhead: float = 200.0  # setup/teardown per kernel


def conv_cycles(m: ScalarCoreModel, S: int, F: int) -> int:
    macs = S * S * F * F
    return int(macs * (m.conv_mac + m.loop_overhead) + m.kernel_overhead)


def matmul_cycles(m: ScalarCoreModel, n: int) -> int:
    macs = n ** 3
    return int(macs * (m.matmul_mac + m.loop_overhead) + m.kernel_overhead)


def fft_cycles(m: ScalarCoreModel, n: int) -> int:
    bf = (n // 2) * int(np.log2(n))
    reorder = 6 * n
    return int(bf * m.fft_butterfly + reorder + m.kernel_overhead)


# Calibrated so that the model reproduces the paper's Table 2 within a few
# percent on the published sizes (conv 4..32 w/ 3x3, fft 256, matmul 64):
#   T03:      conv32 79230, fft 47256, matmul 2679304
#   RI5CY:    conv32 57020, fft 37344, matmul 1360854
#   ZeroRiscy conv32 113793, fft 61158, matmul 4006241
T03 = ScalarCoreModel("klessydra-t03", conv_mac=8.2, matmul_mac=9.7,
                      fft_butterfly=44.0, loop_overhead=0.4)
RI5CY = ScalarCoreModel("ri5cy", conv_mac=5.9, matmul_mac=4.9,
                        fft_butterfly=35.0, loop_overhead=0.3)
ZERORISCY = ScalarCoreModel("zeroriscy", conv_mac=11.9, matmul_mac=14.5,
                            fft_butterfly=57.0, loop_overhead=0.4)

BASELINES = {m.name: m for m in (T03, RI5CY, ZERORISCY)}


def baseline_cycles(core: str, kernel: str, **kw) -> int:
    m = BASELINES[core]
    if kernel == "conv":
        return conv_cycles(m, kw["S"], kw.get("F", 3))
    if kernel == "matmul":
        return matmul_cycles(m, kw["n"])
    if kernel == "fft":
        return fft_cycles(m, kw["n"])
    raise ValueError(kernel)


# Published synthesis data (paper Table 2) — used by the energy/time
# figures; these are *inputs from the paper*, not our results.
SYNTHESIS = {
    # name: dict(D -> (FF, LUT, fmax_MHz))
    "sisd":          {1: (2488, 6982, 144.4)},
    "simd":          {2: (2627, 8400, 146.0), 4: (3301, 11366, 137.2),
                      8: (4800, 17331, 137.7)},
    "sym_mimd":      {1: (3512, 10458, 148.2)},
    "sym_mimd_simd": {2: (4712, 15943, 131.7), 4: (6753, 25089, 120.0),
                      8: (10854, 43419, 105.1)},
    "het_mimd":      {1: (3012, 10182, 117.2)},
    "het_mimd_simd": {2: (3871, 15577, 128.9), 4: (5015, 23282, 122.0),
                      8: (7325, 42944, 108.6)},
    "klessydra-t03": {0: (1418, 4281, 221.1)},
    "ri5cy":         {0: (2527, 7674, 91.4)},
    "zeroriscy":     {0: (1933, 5275, 117.2)},
}


def synthesis_for(scheme: str, D: int):
    """(FF, LUT, fmax_MHz) for a Klessydra config or baseline core."""
    key = {
        ("SISD", 1): ("sisd", 1),
        ("SIMD", 0): ("simd", D),
        ("SymMIMD", 1): ("sym_mimd", 1),
        ("SymMIMD+SIMD", 0): ("sym_mimd_simd", D),
        ("HetMIMD", 1): ("het_mimd", 1),
        ("HetMIMD+SIMD", 0): ("het_mimd_simd", D),
    }
    if scheme in ("klessydra-t03", "ri5cy", "zeroriscy"):
        return SYNTHESIS[scheme][0]
    for (s, d), (grp, dd) in key.items():
        if s == scheme and (d == 1 and D == 1 or d == 0 and D > 1):
            return SYNTHESIS[grp][dd]
    raise KeyError((scheme, D))
