"""Workload assembly + energy model: reproduce the paper's measurement
protocol (homogeneous = same kernel on all 3 harts on different data;
composite = conv / FFT / MatMul on three respective harts, repeatedly;
metric = average cycle count per computation kernel).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import KlessydraConfig, klessydra_taxonomy
from repro.core import baselines
from repro.core.simulator import simulate

RNG = np.random.default_rng(42)


def _lower_items(prog, cfg):
    """Bind a backend-neutral KviProgram to ``cfg`` and return its
    Instr/Scalar trace (lazy import: repro.kvi imports repro.core.isa)."""
    from repro.kvi.lowering import lower
    return lower(prog, cfg).items


def _cyclesim(cfg: KlessydraConfig):
    """A CycleSimBackend timing exactly one scheme (lazy import: repro.kvi
    imports repro.core.isa)."""
    from repro.kvi.cyclesim import CycleSimBackend
    return CycleSimBackend(schemes={"scheme": cfg})


def _conv_prog(cfg, S=32, F=3, seed=0):
    from repro.kvi.programs import conv2d_program
    rng = np.random.default_rng(seed)
    img = rng.integers(-128, 128, (S, S)).astype(np.int32)
    filt = rng.integers(-8, 8, (F, F)).astype(np.int32)
    return conv2d_program(img, filt, shift=4)


def _fft_prog(cfg, n=256, seed=0):
    from repro.kvi.programs import fft_program
    rng = np.random.default_rng(seed)
    re = rng.integers(-2048, 2048, n).astype(np.int32)
    im = rng.integers(-2048, 2048, n).astype(np.int32)
    return fft_program(re, im)


def _matmul_prog(cfg, n=64, seed=0):
    from repro.kvi.programs import matmul_program
    rng = np.random.default_rng(seed)
    A = rng.integers(-64, 64, (n, n)).astype(np.int32)
    B = rng.integers(-64, 64, (n, n)).astype(np.int32)
    return matmul_program(A, B, shift=4,
                          spm_bytes=cfg.N * cfg.spm_kbytes * 1024)


KERNEL_BUILDERS: Dict[str, Callable] = {
    "conv4": lambda cfg, seed=0: _conv_prog(cfg, 4, 3, seed),
    "conv8": lambda cfg, seed=0: _conv_prog(cfg, 8, 3, seed),
    "conv16": lambda cfg, seed=0: _conv_prog(cfg, 16, 3, seed),
    "conv32": lambda cfg, seed=0: _conv_prog(cfg, 32, 3, seed),
    "conv32_f5": lambda cfg, seed=0: _conv_prog(cfg, 32, 5, seed),
    "conv32_f7": lambda cfg, seed=0: _conv_prog(cfg, 32, 7, seed),
    "conv32_f9": lambda cfg, seed=0: _conv_prog(cfg, 32, 9, seed),
    "conv32_f11": lambda cfg, seed=0: _conv_prog(cfg, 32, 11, seed),
    "fft256": lambda cfg, seed=0: _fft_prog(cfg, 256, seed),
    "matmul64": lambda cfg, seed=0: _matmul_prog(cfg, 64, seed),
}

BASELINE_ARGS = {
    "conv4": ("conv", dict(S=4)), "conv8": ("conv", dict(S=8)),
    "conv16": ("conv", dict(S=16)), "conv32": ("conv", dict(S=32)),
    "conv32_f5": ("conv", dict(S=32, F=5)),
    "conv32_f7": ("conv", dict(S=32, F=7)),
    "conv32_f9": ("conv", dict(S=32, F=9)),
    "conv32_f11": ("conv", dict(S=32, F=11)),
    "fft256": ("fft", dict(n=256)), "matmul64": ("matmul", dict(n=64)),
}


def homogeneous_workload(cfg: KlessydraConfig, kernel: str,
                         harts: Optional[int] = None):
    """The paper's homogeneous protocol as a KviWorkload: `kernel` on
    every hart, different data per hart (seed = hart index)."""
    from repro.kvi.workload import (HartAssignment, KviWorkload,
                                    WorkloadEntry)
    n = harts if harts is not None else cfg.harts
    entries = tuple(
        WorkloadEntry(KERNEL_BUILDERS[kernel](cfg, seed=h),
                      HartAssignment(h))
        for h in range(n))
    return KviWorkload(f"homogeneous_{kernel}", entries,
                       meta={"kernel": kernel})


COMPOSITE_KERNELS = ("conv32", "fft256", "matmul64")


def composite_workload(cfg: KlessydraConfig,
                       reps: Optional[Dict[str, int]] = None,
                       kernels=COMPOSITE_KERNELS):
    """The paper's composite protocol as a KviWorkload: conv32 / fft256 /
    matmul64 pinned to harts 0/1/2, each repeated ``reps[kernel]`` times
    back-to-back on fresh data (seed = 100*hart + rep). Kernels missing
    from ``reps`` run once."""
    from repro.kvi.workload import KviWorkload
    reps = reps or {"conv32": 6, "fft256": 6, "matmul64": 1}
    by_hart = {
        h: [KERNEL_BUILDERS[kern](cfg, seed=100 * h + r)
            for r in range(reps.get(kern, 1))]
        for h, kern in enumerate(kernels)}
    wl = KviWorkload.composite(by_hart, name="composite")
    wl.meta.update(kernels=tuple(kernels), reps=dict(reps))
    return wl


def homogeneous_cycles(cfg: KlessydraConfig, kernel: str) -> dict:
    """All harts run `kernel` on different data; avg cycles per kernel.
    KERNEL_BUILDERS produce backend-neutral KviPrograms; the workload runs
    through ``CycleSimBackend.run_workload`` bound to ``cfg``."""
    res = _cyclesim(cfg).run_workload(homogeneous_workload(cfg, kernel),
                                      functional=False)
    sim = res.timing["scheme"]
    return {"avg_cycles": sim.cycles / cfg.harts, "total_cycles": sim.cycles,
            "mfu_util": sim.mfu_utilization}


def composite_cycles(cfg: KlessydraConfig, reps: Optional[Dict[str, int]] = None
                     ) -> dict:
    """conv32 / fft256 / matmul64 on harts 0/1/2 repeatedly; per-kernel
    average = hart finish time / instances (the matmul hart dominates)."""
    reps = reps or {"conv32": 6, "fft256": 6, "matmul64": 1}
    res = _cyclesim(cfg).run_workload(composite_workload(cfg, reps),
                                      functional=False)
    sim = res.timing["scheme"]
    out = {}
    for h, kern in enumerate(COMPOSITE_KERNELS):
        out[kern] = sim.per_hart[h].finish_cycle / reps[kern]
    out["total_cycles"] = sim.cycles
    return out


# ---------------------------------------------------------------------------
# energy + absolute-time model (paper Figs 3-4): cycles from OUR simulator,
# fmax + resource counts from the paper's published synthesis table.
# Dynamic power proxy: P ∝ (LUT + 2*FF) * f; energy = P * T = proxy * cycles.
# Normalized against ZeroRiscy exactly as Fig 4 does.
# ---------------------------------------------------------------------------

def exec_time_us(scheme: str, D: int, cycles: float) -> float:
    _, _, fmax = baselines.synthesis_for(scheme, D)
    return cycles / fmax  # us (fmax in MHz)


def energy_proxy(scheme: str, D: int, cycles: float) -> float:
    ff, lut, fmax = baselines.synthesis_for(scheme, D)
    power = (lut + 2.0 * ff)          # ∝ dynamic power / f
    return power * cycles             # ∝ energy (f cancels: E = P/f * cycles)


def energy_per_op(scheme: str, D: int, cycles: float, alg_ops: int) -> float:
    return energy_proxy(scheme, D, cycles) / max(alg_ops, 1)
