"""Scratchpad memories (SPMs) + allocator.

The paper: "The instructions implement vector operations without relying on
a vector register file, but rather on a memory space mapped on the local
SPMs, for maximum flexibility. The programmer can move vector data at any
point of the SPM address space with no constraint except the total
capacity." — so the model is a flat byte-addressable space of N x capacity
bytes, organized in D banks per SPM (one SPM line per cycle feeds the MFU).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.configs.base import KlessydraConfig


class SpmError(Exception):
    pass


@dataclass
class SpmSpace:
    """Functional SPM state for one SPMI (one hart's view, or the shared
    view): flat int8 backing store with int32 vector accessors."""

    config: KlessydraConfig
    data: np.ndarray = field(default=None)
    _alloc_ptr: int = 0
    _allocs: Dict[str, tuple] = field(default_factory=dict)

    def __post_init__(self):
        if self.data is None:
            self.data = np.zeros(self.total_bytes, dtype=np.int8)

    @property
    def total_bytes(self) -> int:
        return self.config.N * self.config.spm_kbytes * 1024

    # ---- allocator ------------------------------------------------------
    def alloc(self, name: str, length: int, elem_bytes: int = 4) -> int:
        """Bump allocator; returns the byte address. Alignment = SPM line
        (D banks x 4B) so vector ops start bank-aligned."""
        line = max(self.config.D * 4, 4)
        addr = (self._alloc_ptr + line - 1) // line * line
        nbytes = length * elem_bytes
        if addr + nbytes > self.total_bytes:
            raise SpmError(
                f"SPM overflow allocating {name!r}: {addr + nbytes} > "
                f"{self.total_bytes} (N={self.config.N} x "
                f"{self.config.spm_kbytes}KiB)")
        self._alloc_ptr = addr + nbytes
        self._allocs[name] = (addr, length, elem_bytes)
        return addr

    def addr_of(self, name: str) -> int:
        return self._allocs[name][0]

    def reset(self):
        self._alloc_ptr = 0
        self._allocs.clear()
        self.data[:] = 0

    # ---- typed views -----------------------------------------------------
    def read(self, addr: int, length: int, elem_bytes: int = 4) -> np.ndarray:
        dt = {1: np.int8, 2: np.int16, 4: np.int32}[elem_bytes]
        return self.data[addr:addr + length * elem_bytes].view(dt).copy()

    def write(self, addr: int, values: np.ndarray):
        raw = np.ascontiguousarray(values).reshape(-1).view(np.int8)
        if addr + raw.size > self.total_bytes:
            raise SpmError(f"SPM write out of range @{addr}+{raw.size}")
        self.data[addr:addr + raw.size] = raw
