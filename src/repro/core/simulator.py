"""Event-driven cycle simulator of the Klessydra-T13 IMT core + coprocessor.

Microarchitecture model (paper §"THE KLESSYDRA-T IMT ARCHITECTURE"):
  * 3 harts, pure IMT: hart h owns issue slots at cycles ≡ h (mod harts);
    the feed-forward pipeline sustains 1 instruction/cycle aggregate with no
    hazard hardware (the 3-hart rotation is the register-file access fence).
  * Scalar instructions retire 1 per owned slot.
  * Coprocessor instructions occupy their engine: MFU vector ops for
    setup + ceil(len/(D*subword)) cycles; LSU transfers for
    setup_mem + ceil(bytes/4) cycles (single 32-bit memory port, shared).
  * A hart's coprocessor ops execute in program order (SPM consistency);
    scalar work overlaps freely (paper: "The LSU works in parallel with
    other units"; "parallel execution may occur between coprocessor and
    non-coprocessor instructions").
  * Contention by scheme:
      shared (M=1,F=1):  one MFU — any busy vector op blocks all harts
                         ("a hart requesting access to the busy MFU executes
                         a self-referencing jump until the MFU becomes free")
      sym-MIMD (M=F=3):  per-hart MFU/SPM — no inter-hart contention
      het-MIMD (M=3,F=1): per-hart SPMI, shared MFU contended per INTERNAL
                         unit (adder/multiplier/shifter/cmp/move)

Event-driven: O(#instructions), not O(#cycles); validated invariants in
tests (e.g. sym-MIMD cycles <= het-MIMD cycles <= shared cycles).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar, Unit, lsu_cycles, mfu_cycles

Item = Union[Instr, Scalar]


@dataclass
class HartStats:
    instructions: int = 0
    vector_ops: int = 0
    lsu_ops: int = 0
    spin_cycles: int = 0
    finish_cycle: int = 0
    # cycle breakdown over the whole simulated window [0, total):
    #   busy  — the hart is doing something (a coprocessor op of its own
    #           is executing, or it is retiring a scalar issue slot),
    #   stall — waiting to issue a coprocessor op (busy resource, slot
    #           alignment) with nothing of its own in flight,
    #   idle  — the remainder (finished early / unowned slots).
    # Invariant: busy + stall + idle == total cycles (asserted in tests).
    busy_cycles: int = 0
    stall_cycles: int = 0
    idle_cycles: int = 0

    @property
    def total_cycles(self) -> int:
        return self.busy_cycles + self.stall_cycles + self.idle_cycles

    @property
    def utilization(self) -> float:
        return self.busy_cycles / max(self.total_cycles, 1)

    def breakdown(self) -> Dict[str, int]:
        return {"busy": self.busy_cycles, "stall": self.stall_cycles,
                "idle": self.idle_cycles, "total": self.total_cycles}


@dataclass
class SimRecorder:
    """Optional per-event capture for one :meth:`Simulator.run` call —
    the raw material cycle-accurate timeline traces are built from
    (:mod:`repro.kvi.obs`). Recording is opt-in: with ``recorder=None``
    (the default everywhere) the simulator's inner loop executes the
    exact pre-instrumentation path, so the disabled overhead is a
    handful of ``is not None`` branches (pinned < 2% by tests).

    All intervals are half-open ``[start, end)`` in simulated cycles:

      instrs  — (hart, op name, engine, start, end, chained) per
                coprocessor instruction's occupancy,
      scalars — (hart, start, end, count) per scalar block,
      waits   — (hart, op name, start, end) per issue stall (the hart
                wanted to issue ``op`` at ``start`` but could not until
                ``end`` — resource busy or slot alignment),
      holds   — (resource key, start, end) per resource acquisition
                (SPMI streams, LSU port, and the per-internal-unit FU
                instances het-MIMD harts contend on).
    """

    instrs: List[tuple] = field(default_factory=list)
    scalars: List[tuple] = field(default_factory=list)
    waits: List[tuple] = field(default_factory=list)
    holds: List[tuple] = field(default_factory=list)


@dataclass
class SimResult:
    cycles: int
    per_hart: List[HartStats]
    mfu_busy_cycles: float
    lsu_busy_cycles: float
    config: KlessydraConfig

    @property
    def mfu_utilization(self) -> float:
        return self.mfu_busy_cycles / max(self.cycles, 1)

    @property
    def hart_utilization(self) -> List[float]:
        """Per-hart busy fraction of the whole workload window."""
        return [h.utilization for h in self.per_hart]


def _align_up(t: int, phase: int, period: int) -> int:
    """Smallest t' >= t with t' ≡ phase (mod period)."""
    r = (t - phase) % period
    return t if r == 0 else t + (period - r)


def _merge_intervals(intervals: List[tuple]) -> List[tuple]:
    """Sorted union of half-open [s, e) intervals."""
    out: List[tuple] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1] = (out[-1][0], e)
        else:
            out.append((s, e))
    return out


def _length_outside(intervals: List[tuple], cover: List[tuple]) -> int:
    """Total length of ``intervals`` (a merged list) not overlapped by
    ``cover`` (another merged list)."""
    total = 0
    ci = 0
    for s, e in intervals:
        cur = s
        while cur < e:
            while ci < len(cover) and cover[ci][1] <= cur:
                ci += 1
            if ci == len(cover) or cover[ci][0] >= e:
                total += e - cur
                break
            cs, ce = cover[ci]
            if cs > cur:
                total += cs - cur
            cur = max(cur, min(ce, e))
    return total


def _uncovered_slots(runs: List[tuple], cover: List[tuple],
                     period: int) -> int:
    """Number of 1-cycle slots ``[s + k*period, s + k*period + 1)``,
    ``k < n`` for each run ``(s, n)``, not covered by the merged
    disjoint intervals in ``cover``. Runs must be sorted by start
    (they are appended in issue order). Cycle bounds are integers, so
    a slot is either fully inside one cover interval or fully outside
    all of them — coverage per (run, interval) pair is a closed-form
    count, never a per-slot walk."""
    total = 0
    ci = 0
    n_cover = len(cover)
    for s, n in runs:
        last = s + (n - 1) * period
        while ci < n_cover and cover[ci][1] <= s:
            ci += 1
        covered = 0
        j = ci
        while j < n_cover and cover[j][0] <= last:
            a, b = cover[j]
            klo = -((s - a) // period)        # ceil((a - s) / period)
            if klo < 0:
                klo = 0
            khi = (b - 1 - s) // period
            if khi > n - 1:
                khi = n - 1
            if khi >= klo:
                covered += khi - klo + 1
            j += 1
        total += n - covered
    return total


class Simulator:
    """Cycle simulation of one workload: programs[h] = instruction list for
    hart h. Instruction lists mix Instr (coprocessor) and Scalar(n) items."""

    def __init__(self, config: KlessydraConfig):
        self.cfg = config

    def _resource_holds(self, hart: int, instr: Instr):
        """[(candidate_keys, duration)] an op must acquire — one key per
        equivalent resource instance (the op takes whichever frees first).
        Two resources per MFU op: the SPMI stream (2 passes for 2-source
        ops) and the functional unit (line-rate). Sharing depends on the
        scheme; ``fu_counts`` replicates internal units of the shared MFU
        in the heterogeneous scheme."""
        cfg = self.cfg
        if instr.engine == "lsu":
            dur = lsu_cycles(instr, cfg.mem_port_bytes,
                             cfg.vector_setup_cycles + cfg.mem_latency_cycles)
            # single memory port; the bank interleaver routes the transfer
            # through the SPMI, so it contends with MFU streaming there
            spmi = ("spmi", 0) if cfg.M == 1 else ("spmi", hart)
            return [((("lsu", 0),), dur), ((spmi,), dur)]
        unit_c, spmi_c = mfu_cycles(instr, cfg.D, cfg.vector_setup_cycles,
                                    min_elem_bytes=cfg.subword_bits // 8)
        # FU chaining (repro.kvi.lowering, chaining=True): an op fed
        # directly by the previous op's result stream skips its startup
        # latency; plain traces carry no discount and are untouched
        disc = getattr(instr, "chain_discount", 0)
        if disc:
            unit_c = max(1, unit_c - disc)
            spmi_c = max(1, spmi_c - disc)
        if cfg.M == 1 and cfg.F == 1:
            # shared: one SPMI + one MFU for everyone; SPMI streaming binds
            return [((("spmi", 0),), spmi_c), ((("unit", 0),), unit_c)]
        if cfg.F == cfg.M and cfg.F > 1:
            # symmetric MIMD: per-hart SPMI + per-hart MFU
            return [((("spmi", hart),), spmi_c),
                    ((("unit", hart),), unit_c)]
        # heterogeneous MIMD: per-hart SPMI, F shared MFUs contended per
        # internal unit — the instance pool is F MFUs x fu_count per
        # unit (fu_counts > 1 replicates a unit inside each MFU)
        uname = instr.unit.value
        units = tuple(("unit", uname, k)
                      for k in range(cfg.F * cfg.fu_count(uname)))
        return [((("spmi", hart),), spmi_c), (units, unit_c)]

    def run(self, programs: Sequence[Sequence[Item]],
            recorder: Optional[SimRecorder] = None) -> SimResult:
        """Optimized event loop. Semantics are pinned to
        :meth:`_run_reference` by a differential test over randomized
        programs; the wins are structural, not behavioral:

          * resource-hold lists are precomputed once per (hart, item)
            — the candidate scan used to rebuild them (tuples, cycle
            math, ``getattr``) for every hart's head instruction on
            every loop iteration, O(N*H) reconstructions for N items;
          * scalar blocks record one ``(start, count)`` run instead of
            ``count`` 1-cycle interval tuples — the busy accounting
            counts covered slots arithmetically per merged coprocessor
            interval. (A hart's scalar slots can overlap only its own
            in-flight coprocessor op, never its wait intervals: waits
            and scalar slots both live inside the hart's disjoint
            per-item issue windows, so dropping scalar slots from the
            stall cover is exact.)
          * the slot-alignment and dict lookups are inlined/hoisted in
            the scan, the hottest code in every DSE confirmation.
        """
        cfg = self.cfg
        rec = recorder
        H = cfg.harts
        assert len(programs) <= H, "more programs than harts"
        busy_until: Dict[tuple, int] = {}
        bu_get = busy_until.get
        mfu_busy = 0
        lsu_busy = 0
        stats = [HartStats() for _ in range(H)]

        progs = [programs[h] if h < len(programs) else []
                 for h in range(H)]
        lens = [len(p) for p in progs]
        # dispatch fields depend only on (hart, instr), never on time:
        # None marks a Scalar block, otherwise the op's hold list
        prepared = [[None if isinstance(it, Scalar)
                     else self._resource_holds(h, it)
                     for it in progs[h]] for h in range(H)]

        next_slot = list(range(H))
        copro_ready = [0] * H
        pcs = [0] * H
        finish = [0] * H

        activity: List[List[tuple]] = [[] for _ in range(H)]
        scalar_runs: List[List[tuple]] = [[] for _ in range(H)]
        waits: List[List[tuple]] = [[] for _ in range(H)]

        remaining = sum(lens)
        while remaining > 0:
            best_h, best_t = -1, None
            for h in range(H):
                pc = pcs[h]
                if pc >= lens[h]:
                    continue
                t = next_slot[h]
                holds = prepared[h][pc]
                if holds is not None:
                    if copro_ready[h] > t:
                        t = copro_ready[h]
                    for keys, _dur in holds:
                        if len(keys) == 1:
                            avail = bu_get(keys[0], 0)
                        else:
                            avail = min(bu_get(k, 0) for k in keys)
                        if avail > t:
                            t = avail
                    r = (t - h) % H
                    if r:
                        t += H - r
                if best_t is None or t < best_t:
                    best_h, best_t = h, t
            h, t = best_h, best_t
            pc = pcs[h]
            it = progs[h][pc]
            holds = prepared[h][pc]
            st = stats[h]

            if holds is None:
                n = it.count
                end = t + (n - 1) * H + 1 if n else t
                st.instructions += n
                if n:
                    scalar_runs[h].append((t, n))
                    if rec is not None:
                        rec.scalars.append((h, t, end, n))
            else:
                st.instructions += 1
                ns = next_slot[h]
                if t > ns:
                    st.spin_cycles += t - ns
                    waits[h].append((ns, t))
                end = t
                for keys, dur in holds:
                    if len(keys) == 1:
                        k = keys[0]
                    else:
                        k = min(keys, key=lambda kk: bu_get(kk, 0))
                    busy_until[k] = t + dur
                    if t + dur > end:
                        end = t + dur
                    if rec is not None:
                        rec.holds.append((k, t, t + dur))
                if rec is not None:
                    if t > ns:
                        rec.waits.append((h, it.op, ns, t))
                    rec.instrs.append(
                        (h, it.op, it.engine, t, end,
                         getattr(it, "chain_discount", 0) > 0))
                if it.engine == "lsu":
                    st.lsu_ops += 1
                    lsu_busy += end - t
                else:
                    st.vector_ops += 1
                    mfu_busy += end - t
                copro_ready[h] = end
                activity[h].append((t, end))
                end = t + 1                  # issue slot, not occupancy
            r = (end - h) % H
            next_slot[h] = end if r == 0 else end + (H - r)
            if finish[h] < max(end, copro_ready[h]):
                finish[h] = max(end, copro_ready[h])
            pcs[h] += 1
            remaining -= 1

        total = max(finish) if finish else 0
        for h in range(H):
            stats[h].finish_cycle = finish[h]
            cover = _merge_intervals(activity[h])
            busy = sum(e - s for s, e in cover)
            busy += _uncovered_slots(scalar_runs[h], cover, H)
            stall = _length_outside(_merge_intervals(waits[h]), cover)
            stats[h].busy_cycles = busy
            stats[h].stall_cycles = stall
            stats[h].idle_cycles = total - busy - stall
        return SimResult(total, stats, mfu_busy, lsu_busy, cfg)

    def _run_reference(self, programs: Sequence[Sequence[Item]],
                       recorder: Optional[SimRecorder] = None
                       ) -> SimResult:
        """The straight-line event loop :meth:`run` is an optimization
        of — kept as the differential-testing oracle (and the baseline
        the sim-perf benchmark measures the optimized loop against)."""
        cfg = self.cfg
        rec = recorder
        H = cfg.harts
        assert len(programs) <= H, "more programs than harts"
        busy_until: Dict[tuple, int] = {}
        mfu_busy = 0
        lsu_busy = 0
        stats = [HartStats() for _ in range(H)]

        # per-hart cursor state
        next_slot = [h for h in range(H)]            # next issuable cycle
        copro_ready = [0] * H                        # in-order SPM consistency
        done = [not programs[h] if h < len(programs) else True
                for h in range(H)]
        pcs = [0] * H
        finish = [0] * H

        def hart_items(h):
            return programs[h] if h < len(programs) else []

        # per-hart activity/wait intervals for the busy/stall/idle
        # breakdown (scalar slots are 1-cycle intervals at owned slots)
        activity: List[List[tuple]] = [[] for _ in range(H)]
        waits: List[List[tuple]] = [[] for _ in range(H)]

        remaining = sum(len(hart_items(h)) for h in range(H))
        while remaining > 0:
            # pick the hart that can act earliest (deterministic tie-break
            # by hart index = the harc rotation priority)
            best_h, best_t = -1, None
            for h in range(H):
                items = hart_items(h)
                if pcs[h] >= len(items):
                    continue
                it = items[pcs[h]]
                t = next_slot[h]
                if isinstance(it, Instr):
                    # must wait for own previous coprocessor op
                    t = max(t, copro_ready[h])
                    for keys, _dur in self._resource_holds(h, it):
                        t = max(t, min(busy_until.get(k, 0) for k in keys))
                    t = _align_up(t, h, H)
                if best_t is None or t < best_t:
                    best_h, best_t = h, t
            h, t = best_h, best_t
            items = hart_items(h)
            it = items[pcs[h]]

            if isinstance(it, Scalar):
                # n scalar instructions, one per owned slot
                end = t + (it.count - 1) * H + 1 if it.count else t
                stats[h].instructions += it.count
                for k in range(it.count):
                    activity[h].append((t + k * H, t + k * H + 1))
                if rec is not None and it.count:
                    rec.scalars.append((h, t, end, it.count))
                next_slot[h] = _align_up(end, h, H)
                finish[h] = max(finish[h], end)
            else:
                stats[h].instructions += 1
                stats[h].spin_cycles += max(0, t - next_slot[h])
                waits[h].append((next_slot[h], t))
                holds = self._resource_holds(h, it)
                end = t
                for keys, dur in holds:
                    # take the instance that frees first (<= t by the
                    # availability computation above)
                    k = min(keys, key=lambda kk: busy_until.get(kk, 0))
                    busy_until[k] = t + dur
                    end = max(end, t + dur)
                    if rec is not None:
                        rec.holds.append((k, t, t + dur))
                if rec is not None:
                    if t > next_slot[h]:
                        rec.waits.append((h, it.op, next_slot[h], t))
                    rec.instrs.append(
                        (h, it.op, it.engine, t, end,
                         getattr(it, "chain_discount", 0) > 0))
                if it.engine == "lsu":
                    stats[h].lsu_ops += 1
                    lsu_busy += end - t
                else:
                    stats[h].vector_ops += 1
                    mfu_busy += end - t
                copro_ready[h] = end
                activity[h].append((t, end))
                # issuing takes one slot; hart continues with next instr
                next_slot[h] = _align_up(t + 1, h, H)
                finish[h] = max(finish[h], end)
            pcs[h] += 1
            remaining -= 1

        total = max(finish) if finish else 0
        for h in range(H):
            stats[h].finish_cycle = finish[h]
            busy_cover = _merge_intervals(activity[h])
            busy = sum(e - s for s, e in busy_cover)
            # stall = wait time not already covered by the hart's own
            # in-flight work (waiting on your own previous op is busy)
            stall = _length_outside(_merge_intervals(waits[h]), busy_cover)
            stats[h].busy_cycles = busy
            stats[h].stall_cycles = stall
            stats[h].idle_cycles = total - busy - stall
        return SimResult(total, stats, mfu_busy, lsu_busy, cfg)


def simulate(config: KlessydraConfig,
             programs: Sequence[Sequence[Item]],
             recorder: Optional[SimRecorder] = None) -> SimResult:
    return Simulator(config).run(programs, recorder=recorder)
