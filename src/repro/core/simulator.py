"""Event-driven cycle simulator of the Klessydra-T13 IMT core + coprocessor.

Microarchitecture model (paper §"THE KLESSYDRA-T IMT ARCHITECTURE"):
  * 3 harts, pure IMT: hart h owns issue slots at cycles ≡ h (mod harts);
    the feed-forward pipeline sustains 1 instruction/cycle aggregate with no
    hazard hardware (the 3-hart rotation is the register-file access fence).
  * Scalar instructions retire 1 per owned slot.
  * Coprocessor instructions occupy their engine: MFU vector ops for
    setup + ceil(len/(D*subword)) cycles; LSU transfers for
    setup_mem + ceil(bytes/4) cycles (single 32-bit memory port, shared).
  * A hart's coprocessor ops execute in program order (SPM consistency);
    scalar work overlaps freely (paper: "The LSU works in parallel with
    other units"; "parallel execution may occur between coprocessor and
    non-coprocessor instructions").
  * Contention by scheme:
      shared (M=1,F=1):  one MFU — any busy vector op blocks all harts
                         ("a hart requesting access to the busy MFU executes
                         a self-referencing jump until the MFU becomes free")
      sym-MIMD (M=F=3):  per-hart MFU/SPM — no inter-hart contention
      het-MIMD (M=3,F=1): per-hart SPMI, shared MFU contended per INTERNAL
                         unit (adder/multiplier/shifter/cmp/move)

Event-driven: O(#instructions), not O(#cycles); validated invariants in
tests (e.g. sym-MIMD cycles <= het-MIMD cycles <= shared cycles).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar, Unit, lsu_cycles, mfu_cycles

Item = Union[Instr, Scalar]


@dataclass
class HartStats:
    instructions: int = 0
    vector_ops: int = 0
    lsu_ops: int = 0
    spin_cycles: int = 0
    finish_cycle: int = 0


@dataclass
class SimResult:
    cycles: int
    per_hart: List[HartStats]
    mfu_busy_cycles: float
    lsu_busy_cycles: float
    config: KlessydraConfig

    @property
    def mfu_utilization(self) -> float:
        return self.mfu_busy_cycles / max(self.cycles, 1)


def _align_up(t: int, phase: int, period: int) -> int:
    """Smallest t' >= t with t' ≡ phase (mod period)."""
    r = (t - phase) % period
    return t if r == 0 else t + (period - r)


class Simulator:
    """Cycle simulation of one workload: programs[h] = instruction list for
    hart h. Instruction lists mix Instr (coprocessor) and Scalar(n) items."""

    def __init__(self, config: KlessydraConfig):
        self.cfg = config

    def _resource_holds(self, hart: int, instr: Instr):
        """[(resource_key, duration)] an op must acquire. Two resources per
        MFU op: the SPMI stream (2 passes for 2-source ops) and the
        functional unit (line-rate). Sharing depends on the scheme."""
        cfg = self.cfg
        if instr.engine == "lsu":
            dur = lsu_cycles(instr, cfg.mem_port_bytes,
                             cfg.vector_setup_cycles + cfg.mem_latency_cycles)
            # single memory port; the bank interleaver routes the transfer
            # through the SPMI, so it contends with MFU streaming there
            spmi = ("spmi", 0) if cfg.M == 1 else ("spmi", hart)
            return [(("lsu", 0), dur), (spmi, dur)]
        unit_c, spmi_c = mfu_cycles(instr, cfg.D, cfg.vector_setup_cycles)
        # FU chaining (repro.kvi.lowering, chaining=True): an op fed
        # directly by the previous op's result stream skips its startup
        # latency; plain traces carry no discount and are untouched
        disc = getattr(instr, "chain_discount", 0)
        if disc:
            unit_c = max(1, unit_c - disc)
            spmi_c = max(1, spmi_c - disc)
        if cfg.M == 1 and cfg.F == 1:
            # shared: one SPMI + one MFU for everyone; SPMI streaming binds
            return [(("spmi", 0), spmi_c), (("unit", 0), unit_c)]
        if cfg.F == cfg.M and cfg.F > 1:
            # symmetric MIMD: per-hart SPMI + per-hart MFU
            return [(("spmi", hart), spmi_c), (("unit", hart), unit_c)]
        # heterogeneous MIMD: per-hart SPMI, shared MFU per internal unit
        return [(("spmi", hart), spmi_c),
                (("unit", instr.unit.value), unit_c)]

    def run(self, programs: Sequence[Sequence[Item]]) -> SimResult:
        cfg = self.cfg
        H = cfg.harts
        assert len(programs) <= H, "more programs than harts"
        busy_until: Dict[tuple, int] = {}
        mfu_busy = 0
        lsu_busy = 0
        stats = [HartStats() for _ in range(H)]

        # per-hart cursor state
        next_slot = [h for h in range(H)]            # next issuable cycle
        copro_ready = [0] * H                        # in-order SPM consistency
        done = [not programs[h] if h < len(programs) else True
                for h in range(H)]
        pcs = [0] * H
        finish = [0] * H

        def hart_items(h):
            return programs[h] if h < len(programs) else []

        remaining = sum(len(hart_items(h)) for h in range(H))
        while remaining > 0:
            # pick the hart that can act earliest (deterministic tie-break
            # by hart index = the harc rotation priority)
            best_h, best_t = -1, None
            for h in range(H):
                items = hart_items(h)
                if pcs[h] >= len(items):
                    continue
                it = items[pcs[h]]
                t = next_slot[h]
                if isinstance(it, Instr):
                    # must wait for own previous coprocessor op
                    t = max(t, copro_ready[h])
                    for k, _dur in self._resource_holds(h, it):
                        t = max(t, busy_until.get(k, 0))
                    t = _align_up(t, h, H)
                if best_t is None or t < best_t:
                    best_h, best_t = h, t
            h, t = best_h, best_t
            items = hart_items(h)
            it = items[pcs[h]]

            if isinstance(it, Scalar):
                # n scalar instructions, one per owned slot
                end = t + (it.count - 1) * H + 1 if it.count else t
                stats[h].instructions += it.count
                next_slot[h] = _align_up(end, h, H)
                finish[h] = max(finish[h], end)
            else:
                stats[h].instructions += 1
                stats[h].spin_cycles += max(0, t - next_slot[h])
                holds = self._resource_holds(h, it)
                end = t
                for k, dur in holds:
                    busy_until[k] = t + dur
                    end = max(end, t + dur)
                if it.engine == "lsu":
                    stats[h].lsu_ops += 1
                    lsu_busy += end - t
                else:
                    stats[h].vector_ops += 1
                    mfu_busy += end - t
                copro_ready[h] = end
                # issuing takes one slot; hart continues with next instr
                next_slot[h] = _align_up(t + 1, h, H)
                finish[h] = max(finish[h], end)
            pcs[h] += 1
            remaining -= 1

        total = max(finish) if finish else 0
        for h in range(H):
            stats[h].finish_cycle = finish[h]
        return SimResult(total, stats, mfu_busy, lsu_busy, cfg)


def simulate(config: KlessydraConfig,
             programs: Sequence[Sequence[Item]]) -> SimResult:
    return Simulator(config).run(programs)
