"""The paper's computation kernels written as KVI vector programs.

A ``ProgramBuilder`` owns an SpmSpace + main-memory dict and emits the
dynamic instruction trace (Instr/Scalar items). The same trace drives
  (a) the cycle simulator (timing), and
  (b) the functional Mfu executor (correctness vs numpy oracles).

Kernels (paper §PERFORMANCE RESULTS): 2D convolution (3x3..11x11 filters,
zero padding, fixed-point post-scaling), radix-2 DIF FFT-256 (Q15 twiddles,
contiguous-half butterflies, final bit-reversal), MatMul 64x64 (row-vector
accumulation). 32-bit fixed point throughout, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar
from repro.core.mfu import Mfu
from repro.core.spm import SpmSpace

Item = Union[Instr, Scalar]


@dataclass
class Program:
    name: str
    items: List[Item]
    alg_ops: int                     # algorithmic mul+add count (energy denom)
    builder: "ProgramBuilder"

    @property
    def n_instructions(self) -> int:
        return sum(i.count if isinstance(i, Scalar) else 1
                   for i in self.items)


class ProgramBuilder:
    """Emit-and-execute assembler for KVI programs."""

    def __init__(self, config: KlessydraConfig):
        self.cfg = config
        self.spm = SpmSpace(config)
        self.mem: Dict[int, np.ndarray] = {}
        self._mem_next = 0
        self.items: List[Item] = []

    # ---- memory handles --------------------------------------------------
    def to_memory(self, arr: np.ndarray) -> int:
        h = self._mem_next
        self._mem_next += 1
        self.mem[h] = np.ascontiguousarray(arr)
        return h

    # ---- emitters ----------------------------------------------------------
    def emit(self, op: str, **kw) -> Instr:
        i = Instr(op, **kw)
        self.items.append(i)
        return i

    def scalar(self, n: int):
        if n > 0:
            self.items.append(Scalar(n))

    def kmemld(self, dst_addr: int, mem_handle: int, length: int):
        self.emit("kmemld", dst=dst_addr, src1=mem_handle, length=length)

    def kmemstr(self, mem_handle: int, src_addr: int, length: int):
        self.emit("kmemstr", dst=mem_handle, src1=src_addr, length=length)

    # ---- finish ------------------------------------------------------------
    def finish(self, name: str, alg_ops: int) -> Program:
        return Program(name, self.items, alg_ops, self)

    def run_functional(self) -> Dict[int, np.ndarray]:
        """Execute the trace on the SPM/main-memory model."""
        mfu = Mfu(self.spm, self.mem)
        for it in self.items:
            if isinstance(it, Instr):
                r = mfu.execute(it)
                tgt = getattr(it, "rf_store", None)
                if tgt is not None and r is not None:
                    addr, j = tgt
                    self.spm.write(addr + 4 * j, np.array([r], np.int32))
        return self.mem


# ---------------------------------------------------------------------------
# MatMul. Two code paths, chosen by SPM capacity exactly as a programmer
# would (paper: N=3 SPMs for MatMul, so a 64x64 int32 B [16 KiB] does NOT
# fit the 3x4 KiB scratchpads and must be streamed — this is what makes the
# paper's MatMul saturate at high DLP):
#   * resident: B held in SPM, row-vector accumulation (ksvmulsc + kaddv)
#   * streamed: A rows resident, B^T columns streamed per output element,
#     kdotp per element (vector MAC through the multiplier + adder tree)
# ---------------------------------------------------------------------------

def build_matmul(cfg: KlessydraConfig, A: np.ndarray, B: np.ndarray,
                 shift: int = 0) -> Program:
    n, m = A.shape
    _, p = B.shape
    b = ProgramBuilder(cfg)
    b_bytes = m * p * 4
    resident = b_bytes + (2 * p + n) * 4 <= b.spm.total_bytes

    if resident:
        hB = b.to_memory(B.astype(np.int32))
        aB = b.spm.alloc("B", m * p)
        acc = b.spm.alloc("acc", p)
        tmp = b.spm.alloc("tmp", p)
        b.scalar(40)                              # kernel prologue
        b.kmemld(aB, hB, m * p)
        for i in range(n):
            b.scalar(3)                           # row loop bookkeeping
            for k in range(m):
                b.scalar(2)                       # a-scalar load + addr bump
                aik = int(A[i, k])
                row = aB + 4 * p * k
                if k == 0:
                    b.emit("ksvmulsc", dst=acc, src1=row, scalar=aik, length=p)
                else:
                    b.emit("ksvmulsc", dst=tmp, src1=row, scalar=aik, length=p)
                    b.emit("kaddv", dst=acc, src1=acc, src2=tmp, length=p)
            if shift:
                b.emit("ksrav", dst=acc, src1=acc, scalar=shift, length=p)
            hrow = b.to_memory(np.zeros(p, np.int32))
            b.kmemstr(hrow, acc, p)
        return b.finish(f"matmul{n}x{p}", alg_ops=2 * n * m * p)

    # streamed path: per output element, kdotp(A_row, B_col)
    Bt = np.ascontiguousarray(B.astype(np.int32).T)
    arow = b.spm.alloc("arow", m)
    acol = b.spm.alloc("bcol", m)
    acc = b.spm.alloc("acc", p)
    b.scalar(40)                                  # kernel prologue
    for i in range(n):
        b.scalar(3)
        hA = b.to_memory(A[i].astype(np.int32))
        b.kmemld(arow, hA, m)
        for j in range(p):
            b.scalar(3)                           # col pointer, loop, store rd
            hcol = b.to_memory(Bt[j])
            b.kmemld(acol, hcol, m)
            op = "kdotpps" if shift else "kdotp"
            d = b.emit(op, src1=arow, src2=acol, scalar=shift, length=m)
            # register-file result written to acc[j] via LSU-free move:
            # modelled as one scalar instruction (sw to SPM)
            b.scalar(1)
            d.rf_store = (acc, j)
        hrow = b.to_memory(np.zeros(p, np.int32))
        b.kmemstr(hrow, acc, p)
    return b.finish(f"matmul{n}x{p}", alg_ops=2 * n * m * p)


def matmul_result(prog: Program, n: int, p: int) -> np.ndarray:
    """Collect the per-row kmemstr outputs back into a matrix."""
    rows = []
    for it in prog.items:
        if isinstance(it, Instr) and it.op == "kmemstr":
            rows.append(prog.builder.mem[it.dst])
    return np.stack(rows[-n:], axis=0)


# ---------------------------------------------------------------------------
# 2D convolution, FxF filter, zero padding, fixed-point post-scale
# ---------------------------------------------------------------------------

def build_conv2d(cfg: KlessydraConfig, img: np.ndarray, filt: np.ndarray,
                 shift: int = 0) -> Program:
    S = img.shape[0]
    F = filt.shape[0]
    pad = F // 2
    Sp = S + 2 * pad
    padded = np.zeros((Sp, Sp), np.int32)
    padded[pad:pad + S, pad:pad + S] = img
    b = ProgramBuilder(cfg)
    hin = b.to_memory(padded)
    ain = b.spm.alloc("in", Sp * Sp)
    acc = b.spm.alloc("acc", S)
    tmp = b.spm.alloc("tmp", S)
    b.scalar(40)                                  # kernel prologue
    b.kmemld(ain, hin, Sp * Sp)
    for i in range(S):
        b.scalar(6)                               # row loop bookkeeping
        first = True
        for fr in range(F):
            for fc in range(F):
                w = int(filt[fr, fc])
                src = ain + 4 * ((i + fr) * Sp + fc)
                b.scalar(3)
                if first:
                    b.emit("ksvmulsc", dst=acc, src1=src, scalar=w, length=S)
                    first = False
                else:
                    b.emit("ksvmulsc", dst=tmp, src1=src, scalar=w, length=S)
                    b.emit("kaddv", dst=acc, src1=acc, src2=tmp, length=S)
        if shift:
            b.emit("ksrav", dst=acc, src1=acc, scalar=shift, length=S)
        hrow = b.to_memory(np.zeros(S, np.int32))
        b.kmemstr(hrow, acc, S)
    return b.finish(f"conv{S}x{S}_f{F}", alg_ops=2 * S * S * F * F)


def conv2d_result(prog: Program, S: int) -> np.ndarray:
    rows = []
    for it in prog.items:
        if isinstance(it, Instr) and it.op == "kmemstr":
            rows.append(prog.builder.mem[it.dst])
    return np.stack(rows[-S:], axis=0)


def conv2d_oracle(img: np.ndarray, filt: np.ndarray, shift: int = 0):
    S, F = img.shape[0], filt.shape[0]
    pad = F // 2
    padded = np.zeros((S + 2 * pad, S + 2 * pad), np.int64)
    padded[pad:pad + S, pad:pad + S] = img
    out = np.zeros((S, S), np.int64)
    for fr in range(F):
        for fc in range(F):
            out += int(filt[fr, fc]) * padded[fr:fr + S, fc:fc + S]
    return (out >> shift).astype(np.int32) if shift else out.astype(np.int32)


# ---------------------------------------------------------------------------
# FFT-256: radix-2 DIF, contiguous-half butterflies, Q15 twiddles,
# final bit-reversal (element copies — deliberately DLP-unfriendly,
# matching the paper's observation that FFT gains come from TLP).
# ---------------------------------------------------------------------------

Q = 15


def _twiddles(m: int) -> tuple:
    k = np.arange(m // 2)
    w = np.exp(-2j * np.pi * k / m)
    return ((w.real * (1 << Q)).astype(np.int32),
            (w.imag * (1 << Q)).astype(np.int32))


def build_fft(cfg: KlessydraConfig, x_re: np.ndarray,
              x_im: np.ndarray) -> Program:
    n = len(x_re)
    assert n & (n - 1) == 0
    b = ProgramBuilder(cfg)
    hre = b.to_memory(x_re.astype(np.int32))
    him = b.to_memory(x_im.astype(np.int32))
    are = b.spm.alloc("re", n)
    aim = b.spm.alloc("im", n)
    t1 = b.spm.alloc("t1", n // 2)
    t2 = b.spm.alloc("t2", n // 2)
    dre = b.spm.alloc("dre", n // 2)
    dim = b.spm.alloc("dim", n // 2)
    # per-size twiddle vectors, loaded once
    tw_addr = {}
    m = n
    while m >= 2:
        wre, wim = _twiddles(m)
        ar = b.spm.alloc(f"wre{m}", m // 2)
        ai = b.spm.alloc(f"wim{m}", m // 2)
        b.kmemld(ar, b.to_memory(wre), m // 2)
        b.kmemld(ai, b.to_memory(wim), m // 2)
        tw_addr[m] = (ar, ai)
        m //= 2
    b.scalar(40)                                  # kernel prologue
    b.kmemld(are, hre, n)
    b.kmemld(aim, him, n)

    def butterfly(base: int, m: int):
        """DIF butterfly on the contiguous block [base, base+m)."""
        h = m // 2
        lo_re, hi_re = are + 4 * base, are + 4 * (base + h)
        lo_im, hi_im = aim + 4 * base, aim + 4 * (base + h)
        wre, wim = tw_addr[m]
        b.scalar(6)
        # d = lo - hi (complex), top = lo + hi
        b.emit("ksubv", dst=dre, src1=lo_re, src2=hi_re, length=h)
        b.emit("ksubv", dst=dim, src1=lo_im, src2=hi_im, length=h)
        b.emit("kaddv", dst=lo_re, src1=lo_re, src2=hi_re, length=h)
        b.emit("kaddv", dst=lo_im, src1=lo_im, src2=hi_im, length=h)
        # hi = d * w  (Q15)
        b.emit("kvmul", dst=t1, src1=dre, src2=wre, length=h)
        b.emit("ksrav", dst=t1, src1=t1, scalar=Q, length=h)
        b.emit("kvmul", dst=t2, src1=dim, src2=wim, length=h)
        b.emit("ksrav", dst=t2, src1=t2, scalar=Q, length=h)
        b.emit("ksubv", dst=hi_re, src1=t1, src2=t2, length=h)
        b.emit("kvmul", dst=t1, src1=dre, src2=wim, length=h)
        b.emit("ksrav", dst=t1, src1=t1, scalar=Q, length=h)
        b.emit("kvmul", dst=t2, src1=dim, src2=wre, length=h)
        b.emit("ksrav", dst=t2, src1=t2, scalar=Q, length=h)
        b.emit("kaddv", dst=hi_im, src1=t1, src2=t2, length=h)

    m = n
    while m >= 2:
        for base in range(0, n, m):
            butterfly(base, m)
        m //= 2

    # bit-reversal reorder via element copies (vector length 1)
    nb = int(np.log2(n))
    out_re = b.spm.alloc("out_re", n)
    out_im = b.spm.alloc("out_im", n)
    for i in range(n):
        j = int(f"{i:0{nb}b}"[::-1], 2)
        b.scalar(2)
        b.emit("kvcp", dst=out_re + 4 * j, src1=are + 4 * i, length=1)
        b.emit("kvcp", dst=out_im + 4 * j, src1=aim + 4 * i, length=1)
    ore = b.to_memory(np.zeros(n, np.int32))
    oim = b.to_memory(np.zeros(n, np.int32))
    b.kmemstr(ore, out_re, n)
    b.kmemstr(oim, out_im, n)
    prog = b.finish(f"fft{n}", alg_ops=10 * (n // 2) * nb)
    prog.out_handles = (ore, oim)
    return prog


def fft_result(prog: Program) -> np.ndarray:
    ore, oim = prog.out_handles
    return (prog.builder.mem[ore].astype(np.float64) +
            1j * prog.builder.mem[oim].astype(np.float64))
