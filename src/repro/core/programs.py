"""DEPRECATED authoring layer — the paper's kernels now live in
``repro.kvi.programs`` as backend-neutral :class:`~repro.kvi.ir.KviProgram`
definitions (authored once, executed on the oracle / cyclesim / pallas
backends).

This module remains as a thin compatibility shim for one release:

  * ``build_conv2d`` / ``build_fft`` / ``build_matmul`` return the legacy
    :class:`Program` (an ``Instr``/``Scalar`` trace bound to one config),
    now produced by lowering the canonical KVI programs — traces are
    item-for-item identical to the pre-IR builders.
  * ``ProgramBuilder`` still works for hand-rolled traces but emits a
    ``DeprecationWarning``; use :class:`repro.kvi.KviProgramBuilder`.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, List, Union

import numpy as np

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar
from repro.core.mfu import Mfu
from repro.core.spm import SpmSpace

# NOTE: repro.kvi is imported lazily inside the shim builders below —
# repro.kvi.lowering imports repro.core.isa, so a module-level import here
# would make the two packages circular.

Item = Union[Instr, Scalar]


@dataclass
class Program:
    name: str
    items: List[Item]
    alg_ops: int                     # algorithmic mul+add count (energy denom)
    builder: "ProgramBuilder"

    @property
    def n_instructions(self) -> int:
        return sum(i.count if isinstance(i, Scalar) else 1
                   for i in self.items)


def _run_items(items, spm: SpmSpace, mem: Dict[int, np.ndarray]):
    """Replay a trace on the SPM/main-memory model, spilling register-file
    reduction results (``rf_store``) back into the SPM. (Shared with
    ``repro.kvi.lowering.LoweredTrace.execute``; rf_store is the new
    3-tuple ``(addr, elem_index, elem_bytes)`` or the legacy 2-tuple.)"""
    mfu = Mfu(spm, mem)
    for it in items:
        if isinstance(it, Instr):
            r = mfu.execute(it)
            tgt = getattr(it, "rf_store", None)
            if tgt is not None and r is not None:
                addr, j, eb = tgt if len(tgt) == 3 else (*tgt, 4)
                dt = {1: np.int8, 2: np.int16, 4: np.int32}[eb]
                # wrap to the destination width like the hardware store
                # (np >= 2 raises on out-of-range python ints otherwise)
                spm.write(addr + eb * j, np.array([r], np.int64).astype(dt))
    return mem


class ProgramBuilder:
    """Emit-and-execute assembler for KVI traces.

    .. deprecated:: use :class:`repro.kvi.KviProgramBuilder` — it produces
       a backend-neutral program instead of a config-bound trace.
    """

    def __init__(self, config: KlessydraConfig, _warn: bool = True):
        if _warn:
            warnings.warn(
                "repro.core.programs.ProgramBuilder is deprecated; author "
                "programs with repro.kvi.KviProgramBuilder and run them "
                "through repro.kvi.get_backend(...)",
                DeprecationWarning, stacklevel=2)
        self.cfg = config
        self.spm = SpmSpace(config)
        self.mem: Dict[int, np.ndarray] = {}
        self._mem_next = 0
        self.items: List[Item] = []

    # ---- memory handles --------------------------------------------------
    def to_memory(self, arr: np.ndarray) -> int:
        h = self._mem_next
        self._mem_next += 1
        self.mem[h] = np.ascontiguousarray(arr)
        return h

    # ---- emitters ----------------------------------------------------------
    def emit(self, op: str, **kw) -> Instr:
        i = Instr(op, **kw)
        self.items.append(i)
        return i

    def scalar(self, n: int):
        if n > 0:
            self.items.append(Scalar(n))

    def kmemld(self, dst_addr: int, mem_handle: int, length: int):
        self.emit("kmemld", dst=dst_addr, src1=mem_handle, length=length)

    def kmemstr(self, mem_handle: int, src_addr: int, length: int):
        self.emit("kmemstr", dst=mem_handle, src1=src_addr, length=length)

    # ---- finish ------------------------------------------------------------
    def finish(self, name: str, alg_ops: int) -> Program:
        return Program(name, self.items, alg_ops, self)

    def run_functional(self) -> Dict[int, np.ndarray]:
        """Execute the trace on the SPM/main-memory model."""
        return _run_items(self.items, self.spm, self.mem)


def _legacy_program(kvi_prog, cfg: KlessydraConfig) -> Program:
    """Lower a KVI program to one config and wrap it in the legacy
    ``Program``/``ProgramBuilder`` shape existing call sites expect."""
    from repro.kvi.lowering import lower
    trace = lower(kvi_prog, cfg)
    pb = ProgramBuilder(cfg, _warn=False)
    pb.spm = trace.spm
    pb.mem = trace.mem
    pb._mem_next = len(trace.mem)
    pb.items = trace.items
    prog = Program(kvi_prog.name, trace.items, kvi_prog.alg_ops, pb)
    prog.kvi_program = kvi_prog
    prog.trace = trace
    return prog


# ---------------------------------------------------------------------------
# Legacy builders — now shims over repro.kvi.programs
# ---------------------------------------------------------------------------

def build_matmul(cfg: KlessydraConfig, A: np.ndarray, B: np.ndarray,
                 shift: int = 0) -> Program:
    from repro.kvi.programs import matmul_program
    spm_bytes = cfg.N * cfg.spm_kbytes * 1024
    kp = matmul_program(A, B, shift=shift, spm_bytes=spm_bytes)
    return _legacy_program(kp, cfg)


def build_conv2d(cfg: KlessydraConfig, img: np.ndarray, filt: np.ndarray,
                 shift: int = 0) -> Program:
    from repro.kvi.programs import conv2d_program
    kp = conv2d_program(img, filt, shift=shift)
    return _legacy_program(kp, cfg)


def build_fft(cfg: KlessydraConfig, x_re: np.ndarray,
              x_im: np.ndarray) -> Program:
    from repro.kvi.programs import fft_program
    kp = fft_program(x_re, x_im)
    prog = _legacy_program(kp, cfg)
    prog.out_handles = (prog.trace.out_handles["out_re"],
                        prog.trace.out_handles["out_im"])
    return prog


# ---------------------------------------------------------------------------
# Result collectors (trace-level, unchanged API)
# ---------------------------------------------------------------------------

def matmul_result(prog: Program, n: int, p: int) -> np.ndarray:
    """Collect the per-row kmemstr outputs back into a matrix."""
    rows = []
    for it in prog.items:
        if isinstance(it, Instr) and it.op == "kmemstr":
            rows.append(prog.builder.mem[it.dst])
    return np.stack(rows[-n:], axis=0)


def conv2d_result(prog: Program, S: int) -> np.ndarray:
    rows = []
    for it in prog.items:
        if isinstance(it, Instr) and it.op == "kmemstr":
            rows.append(prog.builder.mem[it.dst])
    return np.stack(rows[-S:], axis=0)


def conv2d_oracle(img: np.ndarray, filt: np.ndarray, shift: int = 0):
    S, F = img.shape[0], filt.shape[0]
    pad = F // 2
    padded = np.zeros((S + 2 * pad, S + 2 * pad), np.int64)
    padded[pad:pad + S, pad:pad + S] = img
    out = np.zeros((S, S), np.int64)
    for fr in range(F):
        for fc in range(F):
            out += int(filt[fr, fc]) * padded[fr:fr + S, fc:fc + S]
    return (out >> shift).astype(np.int32) if shift else out.astype(np.int32)


Q = 15                               # Q15 twiddle format (kvi.programs.Q)


def fft_result(prog: Program) -> np.ndarray:
    ore, oim = prog.out_handles
    return (prog.builder.mem[ore].astype(np.float64) +
            1j * prog.builder.mem[oim].astype(np.float64))
