"""Functional execution of KVI instructions over an SpmSpace (+ main
memory). int32 two's-complement fixed-point semantics, matching the paper's
32-bit fixed-point kernels; kdotpps applies the post-scaling right-shift
that keeps Q-format products in range.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.isa import Instr
from repro.core.spm import SpmSpace


def _mul32(a: np.ndarray, b) -> np.ndarray:
    """64-bit product wrapped to int32 (hardware multiplier low word)."""
    return (a.astype(np.int64) * np.int64(b) if np.isscalar(b) or b.ndim == 0
            else a.astype(np.int64) * b.astype(np.int64))


class Mfu:
    """Executes one instruction; register file results returned to caller."""

    def __init__(self, spm: SpmSpace, main_memory: Optional[Dict[int, np.ndarray]] = None):
        self.spm = spm
        self.mem: Dict[int, np.ndarray] = main_memory if main_memory is not None else {}

    def execute(self, i: Instr) -> Optional[int]:
        s = self.spm
        eb = i.elem_bytes
        if i.op == "kmemld":
            # src1 = main-memory handle (key into self.mem), dst = SPM addr
            s.write(i.dst, self.mem[i.src1].astype(_np_dtype(eb)))
            return None
        if i.op == "kmemstr":
            # dst = main-memory handle, src1 = SPM addr
            self.mem[i.dst] = s.read(i.src1, i.length, eb).copy()
            return None

        a = s.read(i.src1, i.length, eb) if i.src1 is not None else None
        b = s.read(i.src2, i.length, eb) if i.src2 is not None else None
        if i.op == "kaddv":
            s.write(i.dst, (a.astype(np.int64) + b).astype(a.dtype))
        elif i.op == "ksubv":
            s.write(i.dst, (a.astype(np.int64) - b).astype(a.dtype))
        elif i.op == "kvmul":
            s.write(i.dst, _mul32(a, b).astype(a.dtype))
        elif i.op == "kvred":
            return int(np.int64(a.sum(dtype=np.int64)).astype(np.int32))
        elif i.op == "kdotp":
            return int(np.int64(_mul32(a, b).sum(dtype=np.int64))
                       .astype(np.int32))
        elif i.op == "kdotpps":
            prod = _mul32(a, b).sum(dtype=np.int64)
            return int(np.int64(prod >> i.scalar).astype(np.int32))
        elif i.op == "ksvaddsc":
            s.write(i.dst, (a.astype(np.int64) + int(i.scalar)).astype(a.dtype))
        elif i.op == "ksvaddrf":
            return int(np.int64(a.astype(np.int64).sum(dtype=np.int64)
                                + int(i.scalar)).astype(np.int32))
        elif i.op == "ksvmulsc":
            s.write(i.dst, _mul32(a, int(i.scalar)).astype(a.dtype))
        elif i.op == "ksvmulrf":
            return int(np.int64(_mul32(a, int(i.scalar)).sum(dtype=np.int64))
                       .astype(np.int32))
        elif i.op == "ksrlv":
            ua = a.astype(np.uint32 if eb == 4 else np.uint16 if eb == 2
                          else np.uint8)
            s.write(i.dst, (ua >> np.uint32(i.scalar)).astype(a.dtype))
        elif i.op == "ksrav":
            s.write(i.dst, (a >> np.int32(i.scalar)).astype(a.dtype))
        elif i.op == "krelu":
            s.write(i.dst, np.maximum(a, 0).astype(a.dtype))
        elif i.op == "kvslt":
            s.write(i.dst, (a < b).astype(a.dtype))
        elif i.op == "ksvslt":
            s.write(i.dst, (a < np.int32(i.scalar)).astype(a.dtype))
        elif i.op == "kvcp":
            s.write(i.dst, a)
        else:
            raise ValueError(f"cannot execute {i.op}")
        return None


def _np_dtype(elem_bytes: int):
    return {1: np.int8, 2: np.int16, 4: np.int32}[elem_bytes]
