"""Jitted public wrappers for the Pallas kernels — the "intrinsics" layer
(the paper exposes its ISA as GCC intrinsics; we expose ours as jitted jax
ops). Model code calls these; each has a matching oracle in ref.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import kdotp as _kdotp
from repro.kernels.flash_attention import flash_attention
from repro.kernels.spm_conv2d import spm_conv2d
from repro.kernels.spm_fft import spm_fft
from repro.kernels.spm_matmul import spm_matmul
from repro.kvi.pallas_backend import fused_elementwise_call


# ---- KVI element-wise intrinsics (single-op / fused slot programs) ---------

def _ew(program, inputs):
    """One fused pallas_call over the slot program; inputs occupy slots
    0..n-1, the last op's dst slot is the result."""
    out, = fused_elementwise_call(program, list(enumerate(inputs)),
                                  [program[-1][1]])
    return out.reshape(inputs[0].shape)


def kaddv(a, b):
    return _ew([("kaddv", 2, 0, 1, 0)], [a, b])


def ksubv(a, b):
    return _ew([("ksubv", 2, 0, 1, 0)], [a, b])


def kvmul(a, b):
    return _ew([("kvmul", 2, 0, 1, 0)], [a, b])


def krelu(a):
    return _ew([("krelu", 1, 0, None, 0)], [a])


def ksvaddsc(a, imm: int):
    return _ew([("ksvaddsc", 1, 0, None, imm)], [a])


def ksvmulsc(a, imm: int):
    return _ew([("ksvmulsc", 1, 0, None, imm)], [a])


def ksrlv(a, imm: int):
    return _ew([("ksrlv", 1, 0, None, imm)], [a])


def ksrav(a, imm: int):
    return _ew([("ksrav", 1, 0, None, imm)], [a])


def kvslt(a, b):
    return _ew([("kvslt", 2, 0, 1, 0)], [a, b])


def ksvslt(a, imm: int):
    return _ew([("ksvslt", 1, 0, None, imm)], [a])


def kvcp(a):
    return _ew([("kvcp", 1, 0, None, 0)], [a])


# fused example: relu(a*w + b) >> s — one HBM pass, four KVI ops in VMEM
def fused_mac_relu(a, w, b, shift: int):
    prog = [("kvmul", 3, 0, 1, 0),
            ("kaddv", 3, 3, 2, 0),
            ("ksrav", 3, 3, None, shift),
            ("krelu", 3, 3, None, 0)]
    return _ew(prog, [a, w, b])


# ---- reductions -------------------------------------------------------------

kdotp = _kdotp.kdotp
kdotpps = _kdotp.kdotpps
kvred = _kdotp.kvred


# ---- compute kernels --------------------------------------------------------

matmul_op = jax.jit(spm_matmul, static_argnames=("bm", "bn", "bk",
                                                 "out_dtype", "interpret"))
conv2d_op = jax.jit(spm_conv2d, static_argnames=("shift", "block_rows",
                                                 "interpret"))
fft_op = jax.jit(spm_fft, static_argnames=("batch_block", "interpret"))
attention_op = jax.jit(flash_attention,
                       static_argnames=("causal", "window", "bq", "bk",
                                        "q_offset", "interpret"))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(x, dt, A, B, C, *, chunk: int = 256, interpret=None):
    """Model-facing wrapper: x [Bz,S,H,P], dt [Bz,S,H], A [H],
    B/C [Bz,S,G,N] (GQA-style groups) — broadcasts groups to heads,
    precomputes da = dt*A, calls the kernel."""
    from repro.kernels.ssd_scan import ssd_scan
    H = x.shape[2]
    G = B.shape[2]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    da = dt * A[None, None, :]
    y, state = ssd_scan(x, da, dt, Bh, Ch, chunk=chunk, interpret=interpret)
    return y, state
