"""Pallas TPU kernels implementing the paper's SPM discipline on VMEM.

kvi_vops / kdotp       — the Table-1 vector ISA (fused element-wise programs,
                         reductions with post-scaling)
spm_matmul / spm_conv2d / spm_fft — the paper's three computation kernels
flash_attention / ssd_scan       — the LM-scale hot spots, same discipline
het_mimd               — composite-workload kernel (grid slot = hart,
                         switched tile programs, dedicated VMEM blocks)

Every kernel: pl.pallas_call + explicit BlockSpec VMEM tiling, jitted
wrapper in ops.py, pure-jnp oracle in ref.py, interpret-mode validation in
tests/kernels/.

(Submodules are imported explicitly — ``from repro.kernels import ops`` —
rather than eagerly here: ops.py builds on repro.kvi.pallas_backend, which
itself uses repro.kernels.common, so an eager import would be circular.)
"""
