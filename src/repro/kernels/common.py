"""Shared kernel utilities.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against the pure-jnp oracles in
ref.py. The SPM discipline from the paper maps 1:1: BlockSpecs stage
HBM->VMEM lines (kmemld), kernel bodies are fused KVI programs operating on
VMEM-resident tiles (MFU), outputs stream back (kmemstr).
"""
from __future__ import annotations

import jax

INTERPRET = jax.default_backend() == "cpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest hardware-aligned block <= preferred that divides dim, or dim
    itself when it is small/unaligned (interpret-mode tests use odd sizes)."""
    if dim <= preferred:
        return dim
    b = preferred
    while b >= align:
        if dim % b == 0:
            return b
        b -= align
    return dim
