"""Mamba-2 SSD chunk-scan kernel.

The SSD recurrence state [P, N] is exactly an SPM-resident accumulator: the
grid walks (batch x head x chunk) with the chunk axis innermost, the state
rides in VMEM scratch between chunks (never touching HBM), and each step
does the intra-chunk quadratic work as MXU matmuls on VMEM tiles.

Inputs are pre-projected (x, da=dt*A, dt, B, C) — the surrounding jitted op
(repro.kernels.ops.ssd_scan_op) handles the head-group broadcast.
Oracle: repro.models.ssm.ssd_chunked / ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET


def _ssd_kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, y_ref, state_ref,
                h_ref, *, cs: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # [cs, P]
    da = da_ref[0, :, 0].astype(jnp.float32)          # [cs]
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # [cs]
    B = b_ref[0, :, 0].astype(jnp.float32)            # [cs, N]
    C = c_ref[0, :, 0].astype(jnp.float32)            # [cs, N]

    cum = jnp.cumsum(da)                              # [cs]
    # intra-chunk: seg[i,j] = exp(cum_i - cum_j) for i>=j
    diff = cum[:, None] - cum[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    seg = jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [cs, cs]
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(cb * seg, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [cs, P]

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cum)                           # [cs]
    h = h_ref[...]                                    # [N, P]
    y += decay_in[:, None] * jax.lax.dot_general(
        C, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h' = exp(sum da) * h + sum_j exp(cum_last - cum_j) Bj xdtj
    decay_out = jnp.exp(cum[-1] - cum)                # [cs]
    upd = jax.lax.dot_general(B * decay_out[:, None], xdt,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [N, P]
    h_ref[...] = jnp.exp(cum[-1]) * h + upd

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _flush():
        state_ref[0, 0] = h_ref[...].astype(state_ref.dtype)


def ssd_scan(x: jax.Array, da: jax.Array, dt: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 256, interpret: bool = None):
    """x: [Bz, S, H, P]; da, dt: [Bz, S, H]; B, C: [Bz, S, H, N] (already
    head-broadcast). Returns (y [Bz,S,H,P], state [Bz,H,N,P])."""
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    cs = min(chunk, S)
    assert S % cs == 0
    n_chunks = S // cs

    grid = (Bz, H, n_chunks)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, cs=cs, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cs, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, cs, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, cs, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cs, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bz, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(x, da, dt, B, C)
    return y, state
