"""Batch radix-2 FFT kernel (the paper's FFT-256, TPU-native).

Klessydra runs one FFT per hart (TLP) with vector butterflies in the SPM.
On TPU the batch dimension IS the lane dimension: the grid walks batch
tiles, and each kernel invocation runs ALL log2(n) stages over a
(batch_tile x n) VMEM-resident block — the data never leaves VMEM between
stages (the SPM-residency insight again; an XLA-op FFT would round-trip
HBM per stage). Contiguous-half DIF butterflies + final bit-reversal via a
static gather, separate re/im planes (no complex dtype on TPU).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET


def _bitrev(n: int) -> np.ndarray:
    bits = int(np.log2(n))
    return np.array([int(f"{i:0{bits}b}"[::-1], 2) for i in range(n)],
                    np.int32)


def _fft_kernel(re_ref, im_ref, perm_ref, ore_ref, oim_ref, *, n: int):
    re = re_ref[...].astype(jnp.float32)        # [bb, n]
    im = im_ref[...].astype(jnp.float32)
    bb = re.shape[0]
    m = n
    while m >= 2:
        h = m // 2
        k = jnp.arange(h, dtype=jnp.float32)
        ang = -2.0 * np.pi * k / m
        wre, wim = jnp.cos(ang), jnp.sin(ang)
        r3 = re.reshape(bb, n // m, m)
        i3 = im.reshape(bb, n // m, m)
        a_re, b_re = r3[:, :, :h], r3[:, :, h:]
        a_im, b_im = i3[:, :, :h], i3[:, :, h:]
        top_re, top_im = a_re + b_re, a_im + b_im
        d_re, d_im = a_re - b_re, a_im - b_im
        bot_re = d_re * wre - d_im * wim
        bot_im = d_re * wim + d_im * wre
        re = jnp.concatenate([top_re, bot_re], axis=2).reshape(bb, n)
        im = jnp.concatenate([top_im, bot_im], axis=2).reshape(bb, n)
        m = h
    perm = perm_ref[...]
    ore_ref[...] = jnp.take(re, perm, axis=1).astype(ore_ref.dtype)
    oim_ref[...] = jnp.take(im, perm, axis=1).astype(oim_ref.dtype)


def spm_fft(re: jax.Array, im: jax.Array, *, batch_block: int = 8,
            interpret: bool = None):
    """re, im: [B, n] (n a power of two). Returns (re, im) of the DFT."""
    B, n = re.shape
    assert n & (n - 1) == 0, "n must be a power of two"
    bb = min(batch_block, B)
    while B % bb:
        bb -= 1
    fn = pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, n), lambda i: (i, 0)),
                  pl.BlockSpec((bb, n), lambda i: (i, 0)),
                  pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((bb, n), lambda i: (i, 0)),
                   pl.BlockSpec((bb, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, n), jnp.float32),
                   jax.ShapeDtypeStruct((B, n), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )
    return fn(re.astype(jnp.float32), im.astype(jnp.float32),
              jnp.asarray(_bitrev(n)))
