"""Heterogeneous-MIMD composite kernel — the paper's headline scheme on TPU.

Klessydra het-MIMD: one shared MFU, per-hart SPM interfaces, three harts
running DIFFERENT kernels (conv / FFT / MatMul) concurrently. TPU analogue:
ONE pallas_call whose grid axis is the "hart" id; each grid step executes a
different tile program (switched on program_id) against its own dedicated
VMEM blocks — one compute engine (VPU/MXU), disjoint scratchpads,
interleaved heterogeneous execution. The paper's composite workload
(convoluting an image while FFT-ing audio while MatMul-ing for crypto)
runs as a single fused launch.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET
from repro.kernels.spm_fft import _bitrev


def _composite_kernel(img_ref, filt_ref, fre_ref, fim_ref, a_ref, b_ref,
                      perm_ref, conv_ref, ore_ref, oim_ref, mm_ref, *,
                      F: int, n: int):
    hart = pl.program_id(0)

    def conv_branch():
        H, W = conv_ref.shape
        acc = jnp.zeros((H, W), jnp.float32)
        for fr in range(F):
            for fc in range(F):
                acc += img_ref[fr:fr + H, fc:fc + W].astype(jnp.float32) * \
                    filt_ref[fr, fc].astype(jnp.float32)
        conv_ref[...] = acc.astype(conv_ref.dtype)

    def fft_branch():
        re = fre_ref[...].astype(jnp.float32)
        im = fim_ref[...].astype(jnp.float32)
        bb = re.shape[0]
        m = n
        while m >= 2:
            h = m // 2
            k = jnp.arange(h, dtype=jnp.float32)
            ang = -2.0 * np.pi * k / m
            wre, wim = jnp.cos(ang), jnp.sin(ang)
            r3 = re.reshape(bb, n // m, m)
            i3 = im.reshape(bb, n // m, m)
            a, br = r3[:, :, :h], r3[:, :, h:]
            ai, bi = i3[:, :, :h], i3[:, :, h:]
            re = jnp.concatenate([a + br, (a - br) * wre - (ai - bi) * wim],
                                 axis=2).reshape(bb, n)
            im = jnp.concatenate([ai + bi, (a - br) * wim + (ai - bi) * wre],
                                 axis=2).reshape(bb, n)
            m = h
        perm = perm_ref[...]
        ore_ref[...] = jnp.take(re, perm, axis=1)
        oim_ref[...] = jnp.take(im, perm, axis=1)

    def mm_branch():
        mm_ref[...] = jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(mm_ref.dtype)

    # the "hart id" selects the tile program; all branches share the same
    # compute engine but write disjoint VMEM outputs (dedicated SPMIs)
    jax.lax.switch(hart, [conv_branch, fft_branch, mm_branch])


def het_mimd_composite(img, filt, fft_re, fft_im, A, B, *,
                       interpret: bool = None):
    """Run conv2d(img, filt) + FFT(fft_re/im) + A@B in ONE kernel launch.
    img: [H+F-1, W+F-1] (pre-padded), filt: [F,F], fft_*: [nb, n],
    A: [m, k], B: [k, p]. Returns (conv [H,W], fft_re, fft_im, A@B)."""
    F = filt.shape[0]
    H, W = img.shape[0] - F + 1, img.shape[1] - F + 1
    nb, n = fft_re.shape
    m, kk = A.shape
    _, p = B.shape

    full = lambda shape: pl.BlockSpec(shape, lambda h: tuple(0 for _ in shape))
    outs = pl.pallas_call(
        functools.partial(_composite_kernel, F=F, n=n),
        grid=(3,),
        in_specs=[full(img.shape), full(filt.shape), full(fft_re.shape),
                  full(fft_im.shape), full(A.shape), full(B.shape),
                  full((n,))],
        out_specs=[full((H, W)), full((nb, n)), full((nb, n)), full((m, p))],
        out_shape=[
            jax.ShapeDtypeStruct((H, W), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, n), jnp.float32),
            jax.ShapeDtypeStruct((m, p), jnp.float32),
        ],
        interpret=INTERPRET if interpret is None else interpret,
    )(img, filt, fft_re, fft_im, A, B, jnp.asarray(_bitrev(n)))
    return outs
