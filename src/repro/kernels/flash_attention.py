"""SPM-tiled flash attention (online softmax in VMEM).

This is the LM-scale payoff of the paper's SPM discipline: the S x S score
matrix NEVER touches HBM — Q/K/V tiles stream through VMEM (kmemld), the
online-softmax state (m, l, acc) lives in VMEM scratch across the KV grid
dimension (SPM-resident accumulators), and only the [Sq, hd] output is
written back (kmemstr). GQA (q-head groups share a KV head), causal and
sliding-window masking supported; fully-masked KV blocks are skipped.

Oracle: repro.models.layers.attention_ref / flash_attention_xla (identical
math — the XLA path used by the dry-run).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_k: int, causal: bool, window: int,
                  scale: float, q_offset: int):
    _, iq, ik = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    visible = True
    if causal:
        visible = q_pos >= k_pos
    if window:
        visible = visible & (q_pos - k_pos < window)

    q = q_ref[0].astype(jnp.float32)                   # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                   # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal or window:
        s = jnp.where(visible, s, NEG_INF)

    m_prev = m_ref[:, :1]                              # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    if causal or window:
        p = jnp.where(visible, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                     # [bq, 1]
    l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)                   # [bk, hd]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[:, :1], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 512,
                    bk: int = 512, q_offset: int = 0,
                    interpret: bool = None) -> jax.Array:
    """q: [B, H, Sq, hd]; k, v: [B, KV, Skv, hd]; H = KV * G. -> [B,H,Sq,hd]
    """
    B, H, Sq, hd = q.shape
    _, KV, Skv, _ = k.shape
    G = H // KV
    bq = pick_block(Sq, bq, align=8)
    bk = pick_block(Skv, bk, align=8)
    n_q, n_k = Sq // bq, Skv // bk
    scale = 1.0 / np.sqrt(hd)

    qr = q.reshape(B * H, Sq, hd)
    kr = k.reshape(B * KV, Skv, hd)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                          window=window, scale=scale, q_offset=q_offset),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, i, j, G=G: (bh // G, j, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda bh, i, j, G=G: (bh // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),     # m
            pltpu.VMEM((bq, _LANES), jnp.float32),     # l
            pltpu.VMEM((bq, hd), jnp.float32),         # acc
        ],
        interpret=INTERPRET if interpret is None else interpret,
    )(qr, kr.reshape(B * KV, Skv, hd), v.reshape(B * KV, Skv, hd))
    return out.reshape(B, H, Sq, hd)
