"""Reduction kernels: kdotp / kdotpps / kvred (paper Table 1).

Grid streams SPM-line-sized tiles through VMEM; a (1,1) accumulator scratch
carries the partial sum across grid steps (the MFU's adder tree), the
result is flushed once — kdotpps applies the post-scaling arithmetic shift
at flush, exactly like the hardware writes the scaled dot product to the
register file.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block


def _reduce_kernel(*refs, n_blocks: int, mul: bool, shift: int, acc_dtype):
    if mul:
        a_ref, b_ref, o_ref, acc_ref = refs
    else:
        a_ref, o_ref, acc_ref = refs
        b_ref = None
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(acc_dtype)
    part = a * b_ref[...].astype(acc_dtype) if mul else a
    acc_ref[0, 0] += jnp.sum(part)

    @pl.when(i == n_blocks - 1)
    def _flush():
        r = acc_ref[0, 0]
        if shift:
            r = r >> jnp.asarray(shift, r.dtype) if \
                jnp.issubdtype(acc_dtype, jnp.integer) else \
                r / jnp.asarray(2.0 ** shift, r.dtype)
        o_ref[0, 0] = r


def _run_reduce(a, b, *, shift: int, block: int, interpret):
    n = a.size
    bl = pick_block(n, block, align=8)
    assert n % bl == 0
    acc_dtype = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) \
        else jnp.float32
    mul = b is not None
    args = [a.reshape(n // bl, bl)] + \
        ([b.reshape(n // bl, bl)] if mul else [])
    out = pl.pallas_call(
        functools.partial(_reduce_kernel, n_blocks=n // bl, mul=mul,
                          shift=shift, acc_dtype=acc_dtype),
        grid=(n // bl,),
        in_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0)) for _ in args],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), acc_dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), acc_dtype)],
        interpret=INTERPRET if interpret is None else interpret,
    )(*args)
    return out[0, 0]


def kdotp(a: jax.Array, b: jax.Array, *, block: int = 2048,
          interpret: bool = None):
    return _run_reduce(jnp.ravel(a), jnp.ravel(b), shift=0, block=block,
                       interpret=interpret)


def kdotpps(a: jax.Array, b: jax.Array, shift: int, *, block: int = 2048,
            interpret: bool = None):
    return _run_reduce(jnp.ravel(a), jnp.ravel(b), shift=shift, block=block,
                       interpret=interpret)


def kvred(a: jax.Array, *, block: int = 2048, interpret: bool = None):
    return _run_reduce(jnp.ravel(a), None, shift=0, block=block,
                       interpret=interpret)
