"""SPM-tiled matmul kernel (the paper's MatMul, TPU-native).

The Klessydra MatMul streams B through the SPM because 16 KiB doesn't fit;
on TPU the same discipline becomes: stage (bm x bk) and (bk x bn) tiles in
VMEM via BlockSpecs, accumulate in an f32 VMEM scratch across the K grid
dimension, write the (bm x bn) output tile once (MXU-aligned 128x128x128
default tiles). Sub-word SIMD (paper: 8/16/32-bit elements) becomes the
dtype parameter: int8 inputs accumulate in int32, bf16 in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import INTERPRET, pick_block


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def spm_matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
               bk: int = 128, out_dtype=None, interpret: bool = None):
    """a: [M, K] @ b: [K, N] -> [M, N]. int8 -> int32 accumulate; floats ->
    f32 accumulate in VMEM scratch."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    if out_dtype is None:
        out_dtype = jnp.int32 if a.dtype == jnp.int8 else a.dtype
    acc_dtype = jnp.int32 if a.dtype == jnp.int8 else jnp.float32
    bm, bn, bk = (pick_block(M, bm), pick_block(N, bn), pick_block(K, bk))
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k, out_dtype=out_dtype),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=INTERPRET if interpret is None else interpret,
    )(a, b)
