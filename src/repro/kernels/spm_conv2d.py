"""2D convolution kernel — the paper's line-buffer conv, re-thought for VMEM.

Klessydra keeps filter rows of the image in SPM and accumulates
ksvmulsc/kaddv taps per output row. On TPU the analogue: the padded image
is VMEM-resident, the grid walks output ROW BLOCKS, and each grid step
accumulates the F*F taps as shifted VPU multiply-adds over a (rows x W)
tile — taps are static Python loops (fully unrolled vector code, no
gather). The filter tile rides in VMEM like an SPM-resident constant.

This variant keeps the whole padded image in VMEM (fine up to ~2k x 2k
f32); a production giant-image variant would stage row slabs via ANY-space
DMA — the paper's images are 4x4..32x32, far below the threshold.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET


def _conv_kernel(img_ref, filt_ref, o_ref, *, F: int, bt: int, W: int,
                 shift: int):
    i = pl.program_id(0)
    acc = jnp.zeros((bt, W), jnp.int32 if img_ref.dtype == jnp.int32
                    else jnp.float32)
    row0 = i * bt
    for fr in range(F):
        # one (bt x W+F-1) slab per filter row, staged once
        slab = img_ref[pl.ds(row0 + fr, bt), :]
        for fc in range(F):
            w = filt_ref[fr, fc]
            acc += slab[:, fc:fc + W].astype(acc.dtype) * w.astype(acc.dtype)
    if shift and jnp.issubdtype(acc.dtype, jnp.integer):
        acc = acc >> shift
    o_ref[...] = acc.astype(o_ref.dtype)


def spm_conv2d(img: jax.Array, filt: jax.Array, *, shift: int = 0,
               block_rows: int = 8, interpret: bool = None) -> jax.Array:
    """img: [H, W] (unpadded); filt: [F, F]. Zero padding, same-size output,
    optional fixed-point post-scale (int32 inputs)."""
    H, W = img.shape
    F = filt.shape[0]
    pad = F // 2
    padded = jnp.pad(img, ((pad, F - 1 - pad), (pad, F - 1 - pad)))
    bt = min(block_rows, H)
    while H % bt:
        bt -= 1
    return pl.pallas_call(
        functools.partial(_conv_kernel, F=F, bt=bt, W=W, shift=shift),
        grid=(H // bt,),
        in_specs=[
            pl.BlockSpec(padded.shape, lambda i: (0, 0)),   # SPM-resident img
            pl.BlockSpec((F, F), lambda i: (0, 0)),         # filter constants
        ],
        out_specs=pl.BlockSpec((bt, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), img.dtype),
        interpret=INTERPRET if interpret is None else interpret,
    )(padded, filt)
