"""Pure-jnp oracles for every kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
Where the model zoo already defines the math (attention, SSD), the oracle
delegates to it so the kernel, the XLA dry-run path and the tests share ONE
definition of the semantics.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.kvi_vops import VOp, apply_vop
from repro.models import ssm as ssm_lib
from repro.models.layers import attention_ref


def matmul_ref(a, b, out_dtype=None):
    if a.dtype == jnp.int8:
        return (a.astype(jnp.int32) @ b.astype(jnp.int32))
    acc = a.astype(jnp.float32) @ b.astype(jnp.float32)
    return acc.astype(out_dtype or a.dtype)


def conv2d_ref(img, filt, *, shift: int = 0):
    H, W = img.shape
    F = filt.shape[0]
    pad = F // 2
    acc_dtype = jnp.int32 if img.dtype == jnp.int32 else jnp.float32
    padded = jnp.pad(img, ((pad, F - 1 - pad), (pad, F - 1 - pad)))
    acc = jnp.zeros((H, W), acc_dtype)
    for fr in range(F):
        for fc in range(F):
            acc = acc + padded[fr:fr + H, fc:fc + W].astype(acc_dtype) * \
                filt[fr, fc].astype(acc_dtype)
    if shift and jnp.issubdtype(acc_dtype, jnp.integer):
        acc = acc >> shift
    return acc.astype(img.dtype)


def fft_ref(re, im):
    x = re.astype(jnp.float32) + 1j * im.astype(jnp.float32)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Kernel layout [B, H, S, hd] -> delegates to models.layers oracle."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = attention_ref(qt, kt, vt, causal=causal, window=window,
                        q_offset=q_offset)
    return out.transpose(0, 2, 1, 3)


def ssd_scan_ref(x, da, dt, B, C):
    """Kernel signature (head-broadcast B/C, da = dt*A) -> models.ssm math.
    Returns (y, state [Bz,H,N,P])."""
    f32 = jnp.float32
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    state = jnp.zeros((Bz, H, P, N), f32)
    ys = []
    for t in range(S):
        a = jnp.exp(da[:, t].astype(f32))                       # [Bz,H]
        upd = (dt[:, t].astype(f32)[..., None] * x[:, t].astype(f32)
               )[..., None] * B[:, t].astype(f32)[:, :, None, :]
        state = state * a[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, C[:, t].astype(f32))
        ys.append(y)
    y = jnp.stack(ys, axis=1).astype(x.dtype)                   # [Bz,S,H,P]
    return y, state.swapaxes(-1, -2)                            # [Bz,H,N,P]


def vops_ref(program: Sequence[VOp], inputs, out_slot: Optional[int] = None,
             n_slots: Optional[int] = None):
    program = tuple(program)
    if n_slots is None:
        n_slots = max([len(inputs)] + [o[1] + 1 for o in program])
    if out_slot is None:
        out_slot = program[-1][1]
    slots = [None] * n_slots
    for i, x in enumerate(inputs):
        slots[i] = x
    for op, dst, s1, s2, imm in program:
        slots[dst] = apply_vop(op, slots[s1],
                               slots[s2] if s2 is not None else None, imm)
    return slots[out_slot]


def kdotp_ref(a, b, shift: int = 0):
    if jnp.issubdtype(a.dtype, jnp.integer):
        s = jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32))
        return s >> shift if shift else s
    s = jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
    return s / (2.0 ** shift) if shift else s


def kvred_ref(a):
    acc = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    return jnp.sum(a.astype(acc))
