"""Fused element-wise KVI vector programs in VMEM (the paper's Table-1 ISA,
TPU-native).

The Klessydra insight: vector operands live in the SPM across a whole
*sequence* of vector instructions — no round-trip to main memory between
kaddv/kvmul/krelu/... . The TPU analogue: one pallas_call executes a small
KVI *program* over VMEM-resident tiles; intermediate "SPM regions" are
registers inside the kernel, HBM is touched once per input and once per
output regardless of program length.

Program encoding: tuple of (op, dst, src1, src2, imm) acting on a slot
file; slots [0..n_inputs) are preloaded with the input tiles.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INTERPRET, pick_block

# (op, dst_slot, src1_slot, src2_slot_or_None, immediate)
VOp = Tuple[str, int, int, Optional[int], int]

_ELEMWISE = {"kaddv", "ksubv", "kvmul", "ksvaddsc", "ksvmulsc", "ksrlv",
             "ksrav", "krelu", "kvslt", "ksvslt", "kvcp"}


def apply_vop(op: str, a, b, imm: int):
    """Shared semantics (used by both the kernel body and the jnp oracle).
    int32 wrap-around arithmetic like the Klessydra MFU."""
    if op == "kaddv":
        return a + b
    if op == "ksubv":
        return a - b
    if op == "kvmul":
        return a * b
    if op == "ksvaddsc":
        return a + jnp.asarray(imm, a.dtype)
    if op == "ksvmulsc":
        return a * jnp.asarray(imm, a.dtype)
    if op == "ksrlv":
        ua = a.astype(jnp.uint32)
        return (ua >> jnp.uint32(imm)).astype(a.dtype)
    if op == "ksrav":
        return a >> jnp.asarray(imm, a.dtype)
    if op == "krelu":
        return jnp.maximum(a, jnp.asarray(0, a.dtype))
    if op == "kvslt":
        return (a < b).astype(a.dtype)
    if op == "ksvslt":
        return (a < jnp.asarray(imm, a.dtype)).astype(a.dtype)
    if op == "kvcp":
        return a
    raise ValueError(op)


def _vops_kernel(*refs, program: Tuple[VOp, ...], n_in: int, n_slots: int,
                 out_slot: int):
    in_refs, out_ref = refs[:n_in], refs[n_in]
    slots: List = [None] * n_slots
    for i, r in enumerate(in_refs):
        slots[i] = r[...]
    for op, dst, s1, s2, imm in program:
        a = slots[s1]
        b = slots[s2] if s2 is not None else None
        slots[dst] = apply_vop(op, a, b, imm)
    out_ref[...] = slots[out_slot]


def run_vops(program: Sequence[VOp], inputs: Sequence[jax.Array],
             out_slot: Optional[int] = None, n_slots: Optional[int] = None,
             block: int = 1024, interpret: bool = None) -> jax.Array:
    """Execute a KVI element-wise program over equal-shaped input vectors.

    All inputs are reshaped to (n/block, block) tiles; the program runs
    fused per tile (one HBM read per input, one write total)."""
    program = tuple(program)
    for op, *_ in program:
        if op not in _ELEMWISE:
            raise ValueError(f"{op} is not an element-wise KVI op")
    x0 = inputs[0]
    n = x0.size
    flat = [jnp.ravel(x) for x in inputs]
    if n_slots is None:
        n_slots = max([len(inputs)] + [o[1] + 1 for o in program])
    if out_slot is None:
        out_slot = program[-1][1]
    bl = pick_block(n, block, align=8)
    assert n % bl == 0, (n, bl)

    out = pl.pallas_call(
        functools.partial(_vops_kernel, program=program, n_in=len(inputs),
                          n_slots=n_slots, out_slot=out_slot),
        grid=(n // bl,),
        in_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0)) for _ in flat],
        out_specs=pl.BlockSpec((1, bl), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n // bl, bl), x0.dtype),
        interpret=INTERPRET if interpret is None else interpret,
    )(*[x.reshape(n // bl, bl) for x in flat])
    return out.reshape(x0.shape)
