"""DEPRECATED — the untyped tuple protocol for fused element-wise KVI
programs. Superseded by the typed IR in ``repro.kvi`` (author programs
with :class:`repro.kvi.KviProgramBuilder`, run them on the ``pallas``
backend) and, at this level, by
:func:`repro.kvi.pallas_backend.fused_elementwise_call`.

Kept for one release so existing call sites keep working; ``run_vops``
now just adapts the tuple encoding onto the new executor and warns.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple

import jax

from repro.kvi.pallas_backend import apply_vop, fused_elementwise_call

# (op, dst_slot, src1_slot, src2_slot_or_None, immediate)
VOp = Tuple[str, int, int, Optional[int], int]

_ELEMWISE = {"kaddv", "ksubv", "kvmul", "ksvaddsc", "ksvmulsc", "ksrlv",
             "ksrav", "krelu", "kvslt", "ksvslt", "kvcp"}

__all__ = ["VOp", "apply_vop", "run_vops"]


def run_vops(program: Sequence[VOp], inputs: Sequence[jax.Array],
             out_slot: Optional[int] = None, n_slots: Optional[int] = None,
             block: int = 1024, interpret: bool = None) -> jax.Array:
    """Execute a KVI element-wise program over equal-shaped input vectors.

    .. deprecated:: use ``repro.kvi`` (typed IR + pallas backend); this
       shim forwards to
       :func:`repro.kvi.pallas_backend.fused_elementwise_call`.
    """
    warnings.warn(
        "repro.kernels.kvi_vops.run_vops is deprecated; build a typed "
        "program with repro.kvi.KviProgramBuilder or call "
        "repro.kvi.pallas_backend.fused_elementwise_call directly",
        DeprecationWarning, stacklevel=2)
    program = tuple(program)
    for op, *_ in program:
        if op not in _ELEMWISE:
            raise ValueError(f"{op} is not an element-wise KVI op")
    if n_slots is None:
        n_slots = max([len(inputs)] + [o[1] + 1 for o in program])
    if out_slot is None:
        out_slot = program[-1][1]
    x0 = inputs[0]
    out, = fused_elementwise_call(program, list(enumerate(inputs)),
                                  [out_slot], n_slots=n_slots, block=block,
                                  interpret=interpret)
    return out.reshape(x0.shape)
