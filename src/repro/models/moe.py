"""Mixture-of-Experts with gather/scatter (FLOP-free) capacity dispatch.

GShard-style einsum dispatch costs G*S*E*C*D matmul FLOPs — at 32k sequences
that *dwarfs* the expert FLOPs, so we dispatch with integer scatter/gather
instead: FLOPs stay proportional to tokens x top_k x 3 x D x F (true MoE
scaling, capacity overhead = capacity_factor).

Token groups are per-sequence ([B, S, D] with B sharded over data/pod), so
routing cumsums never cross shards — SPMD-friendly by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def capacity(seq_len: int, num_experts: int, top_k: int, factor: float) -> int:
    c = int(np.ceil(seq_len * top_k * factor / num_experts))
    return max(8, int(np.ceil(c / 8)) * 8)   # pad to 8 for TPU-friendly tiles


def route(x, w_router, num_experts: int, top_k: int):
    """x: [B, S, D] -> (weights [B,S,k] f32, idx [B,S,k] int32, aux_loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss
    me = probs.mean(axis=(0, 1))                       # [E]
    ce = jnp.zeros_like(me).at[idx.reshape(-1)].add(
        1.0 / idx.size)                                # fraction routed per e
    aux = num_experts * jnp.sum(me * ce)
    return weights, idx, aux


def dispatch_indices(idx, num_experts: int, cap: int):
    """Per-group slot assignment.

    idx: [B, S, k] expert choice per token. Returns
      slot_token [B, E, C] int32 — which flat token (s*k+j expanded) fills
        each (expert, slot); 0 where empty (masked separately),
      slot_valid [B, E, C] bool,
      token_slot [B, S, k] int32 — the slot each (token, choice) landed in
        (>= C means dropped).
    """
    B, S, k = idx.shape
    flat = idx.reshape(B, S * k)                               # expert per entry
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # [B, S*k, E]
    pos = jnp.cumsum(onehot, axis=1) - 1                       # pos within expert
    token_slot = jnp.take_along_axis(pos, flat[..., None], axis=-1)[..., 0]
    keep = token_slot < cap
    # scatter: slot_token[b, e, c] = entry index t where (flat[t]==e, pos==c)
    entry_ids = jnp.broadcast_to(jnp.arange(S * k)[None], (B, S * k))
    slot_token = jnp.zeros((B, num_experts, cap), jnp.int32)
    slot_valid = jnp.zeros((B, num_experts, cap), jnp.bool_)
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    e_ix = flat
    c_ix = jnp.where(keep, token_slot, cap - 1)  # clamp; masked by valid
    slot_token = slot_token.at[b_ix, e_ix, c_ix].max(
        jnp.where(keep, entry_ids, 0), mode="drop")
    slot_valid = slot_valid.at[b_ix, e_ix, c_ix].max(keep, mode="drop")
    return slot_token, slot_valid, token_slot.reshape(B, S, k)


def moe_ffn(x, params, *, num_experts: int, top_k: int, cap_factor: float,
            rules=None, whole_batch_group: bool = False):
    """x: [B, S, D]. params: router [D,E], gate/up [E,D,F], down [E,F,D].
    Returns (y [B,S,D], aux_loss).

    ``whole_batch_group`` (§Perf, decode): with S=1 the per-sequence groups
    pay the per-expert capacity floor E times per token (32x padding for
    mixtral). Regrouping the whole local batch into ONE routing group makes
    capacity ~= tokens*top_k*cf/E — a ~8x decode FLOP cut. Exact (same
    routing, same combine), just a different dispatch layout."""
    if whole_batch_group and x.shape[1] == 1 and x.shape[0] > 1:
        y, aux = moe_ffn(x.reshape(1, -1, x.shape[-1]), params,
                         num_experts=num_experts, top_k=top_k,
                         cap_factor=cap_factor, rules=rules)
        return y.reshape(x.shape), aux
    B, S, D = x.shape
    dtype = x.dtype
    cap = capacity(S, num_experts, top_k, cap_factor)
    weights, idx, aux = route(x, params["router"], num_experts, top_k)
    slot_token, slot_valid, token_slot = dispatch_indices(idx, num_experts, cap)

    # gather tokens into [B, E, C, D] (token index = entry // k)
    tok_of_entry = slot_token // top_k
    xg = jnp.take_along_axis(
        x[:, :, None, :],                                    # [B,S,1,D]
        tok_of_entry.reshape(B, num_experts * cap)[:, :, None, None],
        axis=1).reshape(B, num_experts, cap, D)
    xg = jnp.where(slot_valid[..., None], xg, 0).astype(dtype)
    if rules is not None:
        xg = rules.constrain(xg, "batch", "experts", "capacity", None)

    g = jnp.einsum("becd,edf->becf", xg, params["w_gate"].astype(dtype))
    u = jnp.einsum("becd,edf->becf", xg, params["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    if rules is not None:
        h = rules.constrain(h, "batch", "experts", "capacity", "mlp")
    y_slots = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dtype))
    if rules is not None:
        y_slots = rules.constrain(y_slots, "batch", "experts", "capacity",
                                  None)

    # combine: y[b,s] = sum_j w[b,s,j] * y_slots[b, e_j, slot_j]
    flat_slot = (idx * cap + jnp.minimum(token_slot, cap - 1)
                 ).reshape(B, S * top_k)                      # [B, S*k]
    ys = jnp.take_along_axis(
        y_slots.reshape(B, num_experts * cap, D),
        flat_slot[..., None], axis=1).reshape(B, S, top_k, D)
    dropped = (token_slot >= cap)[..., None]
    ys = jnp.where(dropped, 0, ys)
    y = jnp.einsum("bskd,bsk->bsd", ys.astype(jnp.float32),
                   weights).astype(dtype)
    return y, aux
