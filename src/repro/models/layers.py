"""Core NN layers: RMSNorm, RoPE, chunked (flash-style) attention on the XLA
path, decode attention over full / ring (sliding-window) KV caches, SwiGLU.

The chunked attention here is the *oracle semantics* shared with the Pallas
``flash_attention`` kernel (kernels/flash_attention.py): online softmax over
KV blocks, f32 accumulators, optional causal & sliding-window masking. The
dry-run lowers this XLA path so cost_analysis reflects the true math; real
TPU execution swaps in the Pallas kernel (same math, VMEM-tiled like the
paper's SPM-resident vector ops).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: silu(x@Wg) * (x@Wu) @ Wd; weights may be bf16-cast."""
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(dtype))


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs
    # angles: [..., S, 1, hd/2] broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash-style attention (XLA path / kernel oracle)
# ---------------------------------------------------------------------------

def _block_mask(q_pos, k_pos, causal: bool, window: int):
    """[Qb, Kb] bool valid mask from absolute positions."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 1024, kv_block: int = 1024,
                        q_offset: int = 0, swa_block_skip: bool = False,
                        repeat_kv: bool = False):
    """Online-softmax attention, chunked over Q and KV blocks.

    q: [B, Sq, H, hd]; k, v: [B, Skv, KV, hd] with H = KV * G (GQA).
    Returns [B, Sq, H, hd]. All softmax state in f32.
    ``q_offset``: absolute position of q[0] (prefill continuation).

    ``swa_block_skip`` (§Perf): with a sliding window, each query block
    only attends to the last ``window + q_block`` keys — slice that range
    per query block instead of scanning the full sequence (exact: masking
    still applies; a true FLOP reduction of Skv/(window+q_block)).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    if repeat_kv and G > 1:
        # §Perf: materialize K/V at H heads so the score einsum stays
        # head-sharded end to end (the [KV, G] reshape otherwise makes the
        # SPMD partitioner reshard per KV block: all-to-all inside the scan)
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        KV, G = H, 1
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0
    nq, nk = Sq // q_block, Skv // kv_block
    scale = 1.0 / np.sqrt(hd)

    skip = (swa_block_skip and window and causal and
            window + q_block < Skv)
    if skip:
        span = int(np.ceil((window + q_block) / kv_block)) * kv_block
        nk_eff = span // kv_block
    else:
        nk_eff = nk

    qb = q.reshape(B, nq, q_block, KV, G, hd)
    kb = k.reshape(B, nk, kv_block, KV, hd)
    vb = v.reshape(B, nk, kv_block, KV, hd)

    def per_qblock(qi, q_tile):
        # q_tile: [B, Qb, KV, G, hd]
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)
        if skip:
            # only the last `span` keys can be visible to this query block
            start = jnp.clip(qi * q_block + q_block - span, 0, Skv - span)
            k_span = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            v_span = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kb_l = k_span.reshape(B, nk_eff, kv_block, KV, hd)
            vb_l = v_span.reshape(B, nk_eff, kv_block, KV, hd)
            pos0 = start
        else:
            kb_l, vb_l = kb, vb
            pos0 = 0

        def kv_step(carry, inputs):
            m_prev, l_prev, acc = carry
            ki, k_tile, v_tile = inputs
            k_pos = pos0 + ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_tile.astype(jnp.float32),
                           k_tile.astype(jnp.float32)) * scale
            mask = _block_mask(q_pos, k_pos, causal, window)  # [Qb, Kb] 2D
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            # guard fully-masked blocks: exp(NEG_INF - NEG_INF) would be 1
            p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
            correction = jnp.exp(m_prev - m_new)
            l_new = l_prev * correction + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p, v_tile.astype(jnp.float32))
            acc = acc * correction[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk_eff),
                                    kb_l.swapaxes(0, 1),
                                    vb_l.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # [B,KV,G,Qb,hd]
        return out.transpose(0, 3, 1, 2, 4)               # [B,Qb,KV,G,hd]

    outs = jax.lax.map(lambda args: per_qblock(*args),
                       (jnp.arange(nq), qb.swapaxes(0, 1)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Quadratic reference (small shapes only) — oracle for tests."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qr, k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = _block_mask(q_pos, k_pos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cache_positions, pos, *,
                     window: int = 0):
    """q: [B, 1, H, hd]; caches: [B, S, KV, hd];
    cache_positions: [B, S] int32 absolute token position per slot (-1 =
    empty); pos: [B] int32 per-sequence current position (continuous
    batching: slots decode at different depths). Works for both full caches
    (slot i holds position i) and ring buffers (slot = pos % window)."""
    B, _, H, hd = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    qr = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qr, k_cache.astype(jnp.float32))
    s = s / np.sqrt(hd)
    valid = (cache_positions >= 0) & (cache_positions <= pos[:, None])
    if window:
        valid &= cache_positions > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, cache_positions, k_new, v_new, pos, *,
                 window: int = 0):
    """Insert one token's K/V per sequence at that sequence's slot.
    pos: [B]. Full cache: slot = pos. Ring (SWA): slot = pos % window."""
    B, S = k_cache.shape[:2]
    slot = (pos % window) if window else pos
    slot = jnp.clip(slot.astype(jnp.int32), 0, S - 1)
    b_ix = jnp.arange(B)
    k_cache = k_cache.at[b_ix, slot].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[b_ix, slot].set(v_new[:, 0].astype(v_cache.dtype))
    cache_positions = cache_positions.at[b_ix, slot].set(
        pos.astype(jnp.int32))
    return k_cache, v_cache, cache_positions
