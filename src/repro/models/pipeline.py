"""GPipe-style pipeline parallelism over a mesh axis (the `pod` axis).

The multi-pod default in this framework is DP-over-pod; this module
provides the PP alternative for models whose weights outgrow one pod:
layers are split into S contiguous stages (stage s owned by pipeline rank
s), a batch is split into M microbatches, and the classic GPipe schedule
runs M + S - 1 ticks: each tick every rank applies its stage to the
microbatch it holds, then activations rotate one rank forward with
`ppermute`. Bubble fraction = (S-1)/(M+S-1).

Implementation: `jax.shard_map` over the pipeline axis. Stage parameters
arrive stacked on a leading axis of size S (sharded over the pipeline
axis, so each rank holds exactly its stage's slice). Works under jit,
composes with in-stage TP/DP sharding on the other mesh axes.

Validated in tests/test_pipeline.py (8 fake devices, vs the unpipelined
reference) — exactness, not an approximation.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh,
                   axis: str = "pod", num_microbatches: int = None):
    """Run x through all pipeline stages.

    stage_fn(params_slice, microbatch) -> microbatch   (one stage's layers)
    stage_params: pytree with leading dim = n_stages (sharded over `axis`)
    x: [B, ...] the batch, replicated over the pipeline axis (it flows
       through every stage; DP/TP sharding lives on the OTHER mesh axes)

    Returns the final activations (replicated over the pipeline axis).
    """
    S = mesh.shape[axis]
    M = num_microbatches or S
    B = x.shape[0]
    assert B % M == 0, (B, M)

    def ranked(params_local, x_local):
        # params_local: this rank's stage slice (leading dim 1) — unstack
        p = jax.tree_util.tree_map(lambda a: a[0], params_local)
        rank = jax.lax.axis_index(axis)
        mb = x_local.reshape((M, x_local.shape[0] // M) + x_local.shape[1:])

        # GPipe schedule: a circular buffer of in-flight microbatches.
        # state[i] = activations currently held; after each tick, pass to
        # the next rank. Microbatch m enters rank 0 at tick m, exits rank
        # S-1 at tick m + S - 1.
        n_ticks = M + S - 1
        buf = jnp.zeros_like(mb[0])
        out = jnp.zeros_like(mb)

        def tick(carry, t):
            buf, out = carry
            # rank 0 injects microbatch t (if any left)
            inject = jnp.clip(t, 0, M - 1)
            buf = jnp.where(rank == 0,
                            mb[inject].astype(buf.dtype), buf)
            # every rank applies its stage to what it holds
            y = stage_fn(p, buf)
            # last rank retires microbatch t - (S - 1)
            retire = t - (S - 1)
            ok = (retire >= 0) & (retire < M)
            out = jax.lax.cond(
                ok,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y.astype(o.dtype), jnp.clip(retire, 0, M - 1), 0),
                lambda o: o, out)
            # rotate activations forward one rank
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, out), None

        (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(n_ticks))
        # `out` is only valid on the LAST rank; broadcast it back so every
        # rank returns its own batch shard (psum of masked contributions)
        mine = jnp.where(rank == S - 1, out, jnp.zeros_like(out))
        out = jax.lax.psum(mine, axis)
        return out.reshape(x_local.shape)

    pspec = jax.tree_util.tree_map(lambda _: PS(axis), stage_params)
    from repro.compat import shard_map
    fn = shard_map(ranked, mesh=mesh,
                   in_specs=(pspec, PS()), out_specs=PS(),
                   check_vma=False)
    return fn(stage_params, x)


def unpipelined_reference(stage_fn: Callable, stage_params, x):
    """Sequentially apply all stages (oracle for tests)."""
    S = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    for s in range(S):
        p = jax.tree_util.tree_map(lambda a, s=s: a[s], stage_params)
        x = stage_fn(p, x)
    return x
