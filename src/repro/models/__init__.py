from repro.models import layers, model_zoo, moe, params, sharding, ssm, steps
