"""Model zoo: parameter templates + forward passes for all assigned families.

Families: dense (llama/deepseek/stablelm/phi3), moe (mixtral/grok),
ssm (mamba2), hybrid (hymba: parallel attn+SSM heads), audio (enc-dec,
frame-embedding stub frontend), vlm (decoder + patch-embedding stub).

All decoders share one scanned block driver; the per-family block bodies
dispatch on cfg.family. Layers are stacked along a leading "layers" axis and
consumed as `lax.scan` xs (compact HLO => fast 512-device SPMD compiles).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, Parallelism, ShapeConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_rope, cache_update, decode_attention,
                                 flash_attention_xla, rms_norm, swiglu)
from repro.models.params import P
from repro.models.sharding import Rules

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# parameter templates
# ---------------------------------------------------------------------------

def _attn_template(cfg: ModelConfig, L: int, prefix_dims=()) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lay = ("layers",) + tuple(None for _ in prefix_dims[1:])
    pd = (L,) + tuple(prefix_dims[1:])
    return {
        "wq": P(pd + (D, H, hd), lay + ("embed", "heads", "head_dim"),
                "fanin", fan_in=D),
        "wk": P(pd + (D, KV, hd), lay + ("embed", "kv_heads", "head_dim"),
                "fanin", fan_in=D),
        "wv": P(pd + (D, KV, hd), lay + ("embed", "kv_heads", "head_dim"),
                "fanin", fan_in=D),
        "wo": P(pd + (H, hd, D), lay + ("heads", "head_dim", "embed"),
                "fanin", fan_in=H * hd),
    }


def _ffn_template(cfg: ModelConfig, L: int) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": P((L, D, F), ("layers", "embed", "mlp"), "fanin", fan_in=D),
        "w_up": P((L, D, F), ("layers", "embed", "mlp"), "fanin", fan_in=D),
        "w_down": P((L, F, D), ("layers", "mlp", "embed"), "fanin", fan_in=F),
    }


def _moe_template(cfg: ModelConfig, L: int) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": P((L, D, E), ("layers", "embed", None), "fanin", fan_in=D),
        "w_gate": P((L, E, D, F), ("layers", "experts", "embed", "mlp"),
                    "fanin", fan_in=D),
        "w_up": P((L, E, D, F), ("layers", "experts", "embed", "mlp"),
                  "fanin", fan_in=D),
        "w_down": P((L, E, F, D), ("layers", "experts", "mlp", "embed"),
                    "fanin", fan_in=F),
    }


def _ssm_template(cfg: ModelConfig, L: int) -> dict:
    D, di = cfg.d_model, cfg.d_inner
    H, N, G, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    gn = G * N
    return {
        "w_z": P((L, D, di), ("layers", "embed", "ssm_dim"), "fanin", fan_in=D),
        "w_x": P((L, D, di), ("layers", "embed", "ssm_dim"), "fanin", fan_in=D),
        "w_B": P((L, D, gn), ("layers", "embed", None), "fanin", fan_in=D),
        "w_C": P((L, D, gn), ("layers", "embed", None), "fanin", fan_in=D),
        "w_dt": P((L, D, H), ("layers", "embed", "ssm_heads"), "fanin",
                  fan_in=D),
        "conv_x": P((L, K, di), ("layers", "conv", "ssm_dim"), "normal"),
        "conv_B": P((L, K, gn), ("layers", "conv", None), "normal"),
        "conv_C": P((L, K, gn), ("layers", "conv", None), "normal"),
        "A_log": P((L, H), ("layers", "ssm_heads"), "ssm_a"),
        "dt_bias": P((L, H), ("layers", "ssm_heads"), "ssm_dt"),
        "D_skip": P((L, H), ("layers", "ssm_heads"), "ones"),
        "gate_norm": P((L, di), ("layers", "ssm_dim"), "zeros"),
        "w_out": P((L, di, D), ("layers", "ssm_dim", "embed"), "fanin"),
    }


def block_template(cfg: ModelConfig, L: Optional[int] = None) -> dict:
    L = cfg.num_layers if L is None else L
    D = cfg.d_model
    t = {"ln1": P((L, D), ("layers", None), "zeros")}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        t["attn"] = _attn_template(cfg, L, (L,))
        t["ln2"] = P((L, D), ("layers", None), "zeros")
        t["ffn" if fam != "moe" else "moe"] = (
            _moe_template(cfg, L) if fam == "moe" else _ffn_template(cfg, L))
    elif fam == "ssm":
        t["ssm"] = _ssm_template(cfg, L)
    elif fam == "hybrid":
        t["attn"] = _attn_template(cfg, L, (L,))
        t["ssm"] = _ssm_template(cfg, L)
        t["attn_scale"] = P((L, D), ("layers", None), "zeros")
        t["ssm_scale"] = P((L, D), ("layers", None), "zeros")
        t["ln2"] = P((L, D), ("layers", None), "zeros")
        t["ffn"] = _ffn_template(cfg, L)
    else:
        raise ValueError(fam)
    return t


def encdec_block_template(cfg: ModelConfig) -> dict:
    """Decoder block with cross-attention (audio family)."""
    L, D = cfg.num_layers, cfg.d_model
    return {
        "ln1": P((L, D), ("layers", None), "zeros"),
        "attn": _attn_template(cfg, L, (L,)),
        "ln_x": P((L, D), ("layers", None), "zeros"),
        "xattn": _attn_template(cfg, L, (L,)),
        "ln2": P((L, D), ("layers", None), "zeros"),
        "ffn": _ffn_template(cfg, L),
    }


def _apply_param_dtype(t, dtype: str):
    """Templates default to f32; serving cells store bf16 weights."""
    if dtype == "float32":
        return t
    return jax.tree_util.tree_map(
        lambda p: P(p.shape, p.axes, p.init, dtype, p.fan_in)
        if p.dtype == "float32" else p,
        t, is_leaf=lambda x: isinstance(x, P))


def param_template(cfg: ModelConfig) -> dict:
    D, Vp = cfg.d_model, padded_vocab(cfg.vocab_size)
    t = {"embed": P((Vp, D), ("vocab", "embed"), "embed"),
         "final_norm": P((D,), (None,), "zeros")}
    if cfg.family == "audio":
        t["frontend_adapter"] = P((D, D), ("embed", None), "fanin")
        enc = {
            "ln1": P((cfg.encoder_layers, D), ("layers", None), "zeros"),
            "attn": _attn_template(cfg, cfg.encoder_layers, (cfg.encoder_layers,)),
            "ln2": P((cfg.encoder_layers, D), ("layers", None), "zeros"),
            "ffn": {k: P((cfg.encoder_layers,) + v.shape[1:], v.axes, v.init,
                         v.dtype, v.fan_in)
                    for k, v in _ffn_template(cfg, cfg.encoder_layers).items()},
        }
        t["enc_blocks"] = enc
        t["enc_norm"] = P((D,), (None,), "zeros")
        t["blocks"] = encdec_block_template(cfg)
    else:
        t["blocks"] = block_template(cfg)
        if cfg.family == "vlm":
            t["patch_adapter"] = P((D, D), ("embed", None), "fanin")
    if not cfg.tie_embeddings:
        t["unembed"] = P((D, Vp), ("embed", "vocab"), "fanin")
    return _apply_param_dtype(t, cfg.param_dtype)


def param_count(cfg: ModelConfig) -> int:
    from repro.models.params import count_params
    return count_params(param_template(cfg))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top-k of E experts)."""
    n = param_count(cfg)
    if cfg.num_experts:
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.num_layers
        n -= (cfg.num_experts - cfg.num_experts_per_tok) * expert
    return n


# ---------------------------------------------------------------------------
# block forward bodies
# ---------------------------------------------------------------------------

def _cast(w, dtype):
    return w.astype(dtype)


def _attn_forward(lp, x, positions, cfg: ModelConfig, rules: Rules, par,
                  *, causal=True, window=0, kv_override=None):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, _cast(lp["wq"], dtype))
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, _cast(lp["wk"], dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, _cast(lp["wv"], dtype))
        k = apply_rope(k, positions, cfg.rope_theta)
    else:  # cross-attention: kv computed from encoder output
        enc = kv_override
        k = jnp.einsum("bsd,dhk->bshk", enc, _cast(lp["wk"], dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc, _cast(lp["wv"], dtype))
    q = apply_rope(q, positions, cfg.rope_theta) if kv_override is None else q
    q = rules.constrain(q, "batch", "seq", "heads", "head_dim")
    k = rules.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    out = flash_attention_xla(
        q, k, v, causal=causal, window=window,
        q_block=par.attn_q_block, kv_block=par.attn_kv_block,
        swa_block_skip=par.swa_block_skip, repeat_kv=par.attn_repeat_kv)
    out = jnp.einsum("bshk,hkd->bsd", out, _cast(lp["wo"], dtype))
    return out, (k, v)


def _ffn_forward(lp, x, cfg, rules):
    h = swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"])
    return h


def _ssm_forward(lp, x, cfg: ModelConfig, rules: Rules, conv_state=None,
                 ssd_state=None, decode=False):
    """Full mamba2 mixer. x: [B,S,D]. Returns (y, (conv_state, ssd_state))."""
    dtype = x.dtype
    B_, S, D = x.shape
    H, Pd, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups
    z = jnp.einsum("bsd,de->bse", x, _cast(lp["w_z"], dtype))
    xin = jnp.einsum("bsd,de->bse", x, _cast(lp["w_x"], dtype))
    Bp = jnp.einsum("bsd,de->bse", x, _cast(lp["w_B"], dtype))
    Cp = jnp.einsum("bsd,de->bse", x, _cast(lp["w_C"], dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, _cast(lp["w_dt"], dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))

    cs_x = cs_B = cs_C = None
    if conv_state is not None:
        di, gn = cfg.d_inner, G * N
        cs_x, cs_B, cs_C = (conv_state[..., :di], conv_state[..., di:di + gn],
                            conv_state[..., di + gn:])
    xin, ns_x = ssm_lib.causal_conv(xin, lp["conv_x"], cs_x)
    Bp, ns_B = ssm_lib.causal_conv(Bp, lp["conv_B"], cs_B)
    Cp, ns_C = ssm_lib.causal_conv(Cp, lp["conv_C"], cs_C)
    xin, Bp, Cp = jax.nn.silu(xin), jax.nn.silu(Bp), jax.nn.silu(Cp)
    new_conv = jnp.concatenate([ns_x, ns_B, ns_C], axis=-1)

    xh = xin.reshape(B_, S, H, Pd)
    xh = rules.constrain(xh, "batch", "seq", "ssm_heads", None)
    Bh = Bp.reshape(B_, S, G, N)
    Ch = Cp.reshape(B_, S, G, N)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))

    if decode:
        y, new_state = ssm_lib.ssd_decode_step(
            ssd_state, xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0])
        y = y[:, None]
    else:
        y, new_state = ssm_lib.ssd_chunked(
            xh, dt, A, Bh, Ch, chunk=min(cfg.ssm_chunk, S),
            initial_state=ssd_state)
    y = y + xh * lp["D_skip"].astype(jnp.float32)[None, None, :, None].astype(dtype)
    y = y.reshape(B_, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype),
                 lp["gate_norm"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, _cast(lp["w_out"], dtype))
    return y, (new_conv.astype(x.dtype), new_state)


# ---------------------------------------------------------------------------
# decoder driver (train / prefill / decode) for non-encdec families
# ---------------------------------------------------------------------------

def _decoder_block(lp, x, positions, cfg, rules, par, cache_in=None,
                   decode=False):
    """One block. Returns (x, cache_out, aux)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window
    cache_out = {}
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)

    if cfg.family == "ssm":
        y, (conv_s, ssd_s) = _ssm_forward(
            lp["ssm"], h, cfg, rules,
            conv_state=None if cache_in is None else cache_in["conv"],
            ssd_state=None if cache_in is None else cache_in["state"],
            decode=decode)
        x = x + y
        cache_out = {"conv": conv_s, "state": ssd_s}
        x = rules.constrain(x, "batch", "seq_sp", None)
        return x, cache_out, aux

    # --- attention path (dense / moe / vlm / hybrid) ---
    if decode:
        dtype = h.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wq"], dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wk"], dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wv"], dtype))
        pos = positions[:, 0]                          # [B] per-slot position
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc, vc, cpos = cache_update(
            cache_in["k"], cache_in["v"], cache_in["cpos"], k, v, pos,
            window=window)
        att = decode_attention(q, kc, vc, cpos, pos, window=window)
        attn_out = jnp.einsum("bshk,hkd->bsd", att,
                              _cast(lp["attn"]["wo"], dtype))
        cache_out = {"k": kc, "v": vc, "cpos": cpos}
        kv = None
    else:
        attn_out, kv = _attn_forward(lp["attn"], h, positions, cfg, rules,
                                     par, causal=True, window=window)

    if cfg.family == "hybrid":
        ssm_cache = None if cache_in is None else cache_in
        y_ssm, (conv_s, ssd_s) = _ssm_forward(
            lp["ssm"], h, cfg, rules,
            conv_state=None if cache_in is None else cache_in["conv"],
            ssd_state=None if cache_in is None else cache_in["state"],
            decode=decode)
        # parallel heads: average of per-path normalized outputs
        y = 0.5 * (rms_norm(attn_out, lp["attn_scale"], cfg.norm_eps) +
                   rms_norm(y_ssm, lp["ssm_scale"], cfg.norm_eps))
        cache_out.update({"conv": conv_s, "state": ssd_s})
    else:
        y = attn_out

    x = x + y
    x = rules.constrain(x, "batch", "seq_sp", None)
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        ff, aux = moe_lib.moe_ffn(
            h2, lp["moe"], num_experts=cfg.num_experts,
            top_k=cfg.num_experts_per_tok, cap_factor=cfg.capacity_factor,
            rules=rules, whole_batch_group=par.moe_decode_group and decode)
    else:
        ff = _ffn_forward(lp["ffn"], h2, cfg, rules)
    x = x + ff
    x = rules.constrain(x, "batch", "seq_sp", None)

    if not decode and kv is not None and cache_in is not None:
        # prefill: store kv into the cache — last `window` tokens for ring
        # caches, or all tokens + empty headroom slots for full caches
        S_slots = cache_in["k"].shape[1]
        S = kv[0].shape[1]
        B = kv[0].shape[0]
        k, v = kv
        if S_slots <= S:               # ring (SWA) cache: keep the tail,
            # placed so that position p sits at slot p % W (the decode
            # eviction invariant; matters when W does not divide S)
            shift = (S - S_slots) % S_slots
            kk = jnp.roll(k[:, -S_slots:], shift, axis=1)
            vv = jnp.roll(v[:, -S_slots:], shift, axis=1)
            cpos = jnp.broadcast_to(
                jnp.roll(jnp.arange(S, dtype=jnp.int32)[-S_slots:], shift),
                (B, S_slots))
        else:                          # full cache with generation headroom
            pad = S_slots - S
            kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.broadcast_to(jnp.concatenate(
                [jnp.arange(S, dtype=jnp.int32),
                 jnp.full((pad,), -1, jnp.int32)]), (B, S_slots))
        cache_out.update({"k": kk.astype(cache_in["k"].dtype),
                          "v": vv.astype(cache_in["v"].dtype),
                          "cpos": cpos})
    return x, cache_out, aux


def decoder_forward(params, cfg: ModelConfig, rules: Rules, par: Parallelism,
                    x, positions, cache=None, decode=False):
    """x: [B,S,D] embedded input. Returns (hidden, new_layer_cache, aux)."""
    blocks = params["blocks"]

    def body(carry, xs):
        xcur, aux_acc = carry
        lp, cache_l = xs if cache is not None else (xs, None)
        xcur, cache_out, aux = _decoder_block(
            lp, xcur, positions, cfg, rules, par, cache_in=cache_l,
            decode=decode)
        return (xcur, aux_acc + aux), cache_out

    if par.remat == "full":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif par.remat == "block":
        body = jax.checkpoint(body)

    xs = (blocks, cache["layers"]) if cache is not None else blocks
    (x, aux), new_layer_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_layer_cache, aux


# ---------------------------------------------------------------------------
# encoder-decoder driver (audio family)
# ---------------------------------------------------------------------------

def encoder_forward(params, cfg, rules, par, frames):
    """frames: [B, S_enc, D] stub embeddings -> encoder hidden states."""
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bsd,de->bse", frames.astype(dtype),
                   params["frontend_adapter"].astype(dtype))
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                                 frames.shape[:2])

    def body(xcur, lp):
        h = rms_norm(xcur, lp["ln1"], cfg.norm_eps)
        att, _ = _attn_forward(lp["attn"], h, positions, cfg, rules, par,
                               causal=False)
        xcur = xcur + att
        h2 = rms_norm(xcur, lp["ln2"], cfg.norm_eps)
        xcur = xcur + _ffn_forward(lp["ffn"], h2, cfg, rules)
        xcur = rules.constrain(xcur, "batch", "seq_sp", None)
        return xcur, None

    if par.remat in ("block", "full"):
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def encdec_decoder_forward(params, cfg, rules, par, x, positions, enc_out,
                           cache=None, decode=False):
    """Decoder with self + cross attention. enc_out: [B,S_enc,D] (train) or
    None (decode: cross K/V live in the cache)."""

    def body(carry, xs):
        xcur, aux = carry
        lp, cache_l = xs if cache is not None else (xs, None)
        cache_out = {}
        h = rms_norm(xcur, lp["ln1"], cfg.norm_eps)
        if decode:
            dtype = h.dtype
            q = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wq"], dtype))
            k = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wk"], dtype))
            v = jnp.einsum("bsd,dhk->bshk", h, _cast(lp["attn"]["wv"], dtype))
            pos = positions[:, 0]                      # [B]
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            kc, vc, cpos = cache_update(cache_l["k"], cache_l["v"],
                                        cache_l["cpos"], k, v, pos)
            att = decode_attention(q, kc, vc, cpos, pos)
            att = jnp.einsum("bshk,hkd->bsd", att, _cast(lp["attn"]["wo"], dtype))
            cache_out.update({"k": kc, "v": vc, "cpos": cpos})
            xcur = xcur + att
            # cross-attention against cached encoder K/V
            hx = rms_norm(xcur, lp["ln_x"], cfg.norm_eps)
            qx = jnp.einsum("bsd,dhk->bshk", hx, _cast(lp["xattn"]["wq"], dtype))
            B_, n_enc = q.shape[0], cache_l["xk"].shape[1]
            xpos = jnp.broadcast_to(jnp.arange(n_enc, dtype=jnp.int32),
                                    (B_, n_enc))
            attx = decode_attention(qx, cache_l["xk"], cache_l["xv"], xpos,
                                    jnp.full((B_,), n_enc, jnp.int32))
            attx = jnp.einsum("bshk,hkd->bsd", attx,
                              _cast(lp["xattn"]["wo"], dtype))
            cache_out.update({"xk": cache_l["xk"], "xv": cache_l["xv"]})
            xcur = xcur + attx
        else:
            att, kv = _attn_forward(lp["attn"], h, positions, cfg, rules, par,
                                    causal=True)
            xcur = xcur + att
            hx = rms_norm(xcur, lp["ln_x"], cfg.norm_eps)
            attx, xkv = _attn_forward(lp["xattn"], hx, positions, cfg, rules,
                                      par, causal=False, kv_override=enc_out)
            xcur = xcur + attx
            if cache_l is not None:
                B_, Sd = kv[0].shape[:2]
                pad = cache_l["k"].shape[1] - Sd
                cache_out.update({
                    "k": jnp.pad(kv[0], ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).astype(cache_l["k"].dtype),
                    "v": jnp.pad(kv[1], ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).astype(cache_l["v"].dtype),
                    "cpos": jnp.broadcast_to(jnp.concatenate(
                        [jnp.arange(Sd, dtype=jnp.int32),
                         jnp.full((pad,), -1, jnp.int32)]), (B_, Sd + pad)),
                    "xk": xkv[0].astype(cache_l["xk"].dtype),
                    "xv": xkv[1].astype(cache_l["xv"].dtype)})
        h2 = rms_norm(xcur, lp["ln2"], cfg.norm_eps)
        xcur = xcur + _ffn_forward(lp["ffn"], h2, cfg, rules)
        xcur = rules.constrain(xcur, "batch", "seq_sp", None)
        return (xcur, aux), cache_out

    if par.remat in ("block", "full"):
        body = jax.checkpoint(body)
    xs = (params["blocks"], cache["layers"]) if cache is not None \
        else params["blocks"]
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg, tokens):
    dtype = jnp.dtype(cfg.dtype)
    return params["embed"].astype(dtype)[tokens]


def logits_fn(params, cfg, hidden):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.tie_embeddings:
        w = params["embed"].astype(dtype)
        logits = jnp.einsum("bsd,vd->bsv", hidden, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden, params["unembed"].astype(dtype))
    Vp, V = padded_vocab(cfg.vocab_size), cfg.vocab_size
    if Vp != V:
        mask = jnp.arange(Vp) < V
        logits = jnp.where(mask[None, None], logits, -1e30)
    return logits
