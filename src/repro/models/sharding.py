"""Logical-axis sharding: the paper's TLP/DLP split mapped onto mesh axes.

Every parameter / activation dimension carries a *logical* axis name; a
``Rules`` table maps logical names to mesh axes.  TLP (the paper's harts)
lands on ``pod``/``data``; DLP (the paper's vector lanes D) lands on
``model``.  A divisibility guard silently downgrades to replication when a
dimension does not divide the mesh axis (e.g. hymba's 25 heads on a 16-way
model axis) and records the downgrade for DESIGN/EXPERIMENTS notes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.configs.base import ModelConfig, Parallelism


@dataclass
class Rules:
    """logical axis -> mesh axis (str), tuple of mesh axes, or None."""

    mesh: Optional[Mesh]
    mapping: dict
    downgrades: list = field(default_factory=list)

    def axis_size(self, mesh_axes) -> int:
        if self.mesh is None or mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        return int(np.prod([self.mesh.shape[a] for a in mesh_axes]))

    def spec(self, logical_axes, shape=None) -> PS:
        """PartitionSpec for a tensor with the given logical axes; if
        ``shape`` is given, apply the divisibility guard per dimension."""
        out = []
        for i, name in enumerate(logical_axes):
            mesh_axes = self.mapping.get(name)
            if mesh_axes is None:
                out.append(None)
                continue
            size = self.axis_size(mesh_axes)
            if shape is not None and shape[i] % size != 0:
                self.downgrades.append((name, shape[i], mesh_axes))
                out.append(None)
            else:
                out.append(mesh_axes)
        return PS(*out)

    def sharding(self, logical_axes, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical_axes, x.shape)))


def make_rules(mesh: Optional[Mesh], cfg: ModelConfig, par: Parallelism) -> Rules:
    """Build the logical->mesh table for one (arch, mesh) pair."""
    has_pod = mesh is not None and "pod" in mesh.axis_names
    ep = has_pod and par.expert_parallel
    # EP consumes the pod axis for the expert dim; batch then stays on data
    batch_axes = ("pod", "data") if has_pod and not ep else "data"
    msize = mesh.shape.get("model", 1) if mesh is not None else 1

    if par.pure_dp:
        # §Perf TLP/DLP rebalance (the paper's Fig-2 lesson at rack scale):
        # for models whose per-shard matmuls are too small to pay for TP
        # all-reduces, fold the model axis into data parallelism and shard
        # the optimizer state ZeRO-style over both axes.
        dp_axes = ("pod", "data", "model") if has_pod else ("data", "model")
        return Rules(mesh=mesh, mapping={
            "batch": dp_axes, "seq": None, "seq_sp": None, "embed_act": None,
            "heads": None, "kv_heads": None, "head_dim": None, "window": None,
            "cache_seq": None, "embed": ("data", "model"), "mlp": None,
            "vocab": None, "layers": None, "experts": None, "capacity": None,
            "ssm_heads": None, "ssm_state": None, "ssm_dim": None,
            "conv": None, None: None,
        })

    # KV cache: shard heads over "model" when divisible; otherwise shard the
    # cache sequence dim (flash-decoding style — XLA inserts the softmax-sum
    # all-reduce). Avoids replicated multi-GiB caches for kv=8 archs.
    kv_shardable = cfg.num_kv_heads and msize and \
        cfg.num_kv_heads % max(msize, 1) == 0

    mapping = {
        # activations
        "batch": batch_axes,
        "seq": None,
        "seq_sp": "model" if par.sequence_parallel else None,
        "embed_act": None,
        # attention
        "heads": "model",
        "kv_heads": "model" if kv_shardable else None,
        "head_dim": None,
        "window": None,
        "cache_seq": None if kv_shardable else "model",
        # params
        "embed": "data" if par.fsdp else None,
        "mlp": None if par.moe_capacity_sharding else "model",
        "vocab": "model",
        "layers": None,
        # moe
        "experts": ("pod" if ep else None),
        "capacity": "model" if par.moe_capacity_sharding else None,
        # ssm
        "ssm_heads": "model",
        "ssm_state": None,
        "ssm_dim": "model",
        "conv": None,
        # scalars / misc
        None: None,
    }
    return Rules(mesh=mesh, mapping=mapping)


def named_sharding(rules: Rules, logical_axes, shape=None):
    return rules.sharding(logical_axes, shape)
