"""Parameter templates: one declarative tree per model family.

A template is a nested dict whose leaves are ``P`` specs (shape, logical
axes, init law).  From one template we derive:

  * ``abstract(template)``   -> ShapeDtypeStruct tree (dry-run: NO allocation)
  * ``initialize(template)`` -> materialized param tree (training)
  * ``shardings(template)``  -> NamedSharding tree via the logical-axis Rules

keeping shapes, shardings and init in lockstep by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import Rules


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | embed | fanin | neg1
    dtype: str = "float32"
    fan_in: Optional[int] = None   # explicit fan-in for "fanin" init (4D
    #                                weights: shape[-2] is NOT the fan-in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, P)


def tree_map(fn, template):
    return jax.tree_util.tree_map(fn, template, is_leaf=_is_leaf)


def abstract(template, rules: Optional[Rules] = None):
    """ShapeDtypeStruct tree; attaches NamedShardings when rules has a mesh."""
    def leaf(p: P):
        sharding = rules.sharding(p.axes, p.shape) if rules and rules.mesh else None
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype), sharding=sharding)
    return tree_map(leaf, template)


def shardings(template, rules: Rules):
    return tree_map(lambda p: rules.sharding(p.axes, p.shape), template)


def specs(template, rules: Rules):
    return tree_map(lambda p: rules.spec(p.axes, p.shape), template)


def _init_leaf(p: P, key):
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "neg1":
        return jnp.full(p.shape, -1, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        return jax.random.normal(key, p.shape, p.dtype) * 0.02
    if p.init == "fanin":
        fan_in = p.fan_in or (p.shape[-2] if len(p.shape) >= 2
                              else p.shape[-1])
        return jax.random.normal(key, p.shape, p.dtype) / np.sqrt(fan_in)
    if p.init == "normal":
        return jax.random.normal(key, p.shape, p.dtype) * 0.02
    if p.init == "ssm_a":
        # mamba2: A_log init so that -exp(A_log) in [-1, -H]
        row = jnp.log(jnp.arange(1, p.shape[-1] + 1, dtype=p.dtype))
        return jnp.broadcast_to(row, p.shape)
    if p.init == "ssm_dt":
        # dt bias: softplus^-1 of dt in [1e-3, 1e-1], log-uniform
        u = jnp.linspace(np.log(1e-3), np.log(1e-1), num=int(np.prod(p.shape)))
        dt = jnp.exp(u).reshape(p.shape).astype(p.dtype)
        return dt + jnp.log(-jnp.expm1(-dt))
    raise ValueError(f"unknown init {p.init!r}")


def initialize(template, rng):
    """Materialize params; per-leaf keys derived from the tree path."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_leaf)
    out = []
    for path, p in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        key = jax.random.fold_in(rng, hash(name) % (2**31))
        out.append(_init_leaf(p, key))
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=_is_leaf)
    return int(sum(np.prod(p.shape) for p in leaves))


def bytes_params(template) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=_is_leaf)
    return int(sum(np.prod(p.shape) * jnp.dtype(p.dtype).itemsize for p in leaves))
