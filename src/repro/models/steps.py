"""Step functions (train / prefill / decode) + cache & input templates.

Everything here is shape-polymorphic over (arch, shape) cells and mesh-
agnostic: shardings come from the logical-axis Rules, so the same code path
serves the CPU smoke tests (mesh=None), the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, Parallelism, ShapeConfig
from repro.models import model_zoo as zoo
from repro.models.params import P, abstract as abstract_tree
from repro.models.sharding import Rules
from repro.optim.optimizer import OptimizerConfig, adamw_init, adamw_update

LABEL_IGNORE = -100


# ---------------------------------------------------------------------------
# cache templates
# ---------------------------------------------------------------------------

DECODE_HEADROOM = 64    # extra slots a prefill leaves for generation


def cache_slots(cfg: ModelConfig, shape: ShapeConfig,
                extra_slots: int = 0) -> int:
    """KV slots: full seq (+headroom) for dense attention, window for SWA
    (ring buffers never overflow — eviction handles capacity)."""
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len + extra_slots


def cache_template(cfg: ModelConfig, shape: ShapeConfig,
                   extra_slots: int = 0) -> dict:
    """P-spec tree for the decode cache of one (arch, shape)."""
    L, B = cfg.num_layers, shape.global_batch
    layers = {}
    if cfg.family == "audio":
        S_self = shape.seq_len // 2 + extra_slots
        S_cross = shape.seq_len // 2
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        layers = {
            "k": P((L, B, S_self, KV, hd),
                   ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                   "zeros", cfg.dtype),
            "v": P((L, B, S_self, KV, hd),
                   ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                   "zeros", cfg.dtype),
            "cpos": P((L, B, S_self), ("layers", "batch", "cache_seq"),
                      "neg1", "int32"),
            "xk": P((L, B, S_cross, KV, hd),
                    ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                    "zeros", cfg.dtype),
            "xv": P((L, B, S_cross, KV, hd),
                    ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                    "zeros", cfg.dtype),
        }
    else:
        if cfg.num_heads:  # attention caches (dense/moe/vlm/hybrid)
            S = cache_slots(cfg, shape, extra_slots)
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            layers.update({
                "k": P((L, B, S, KV, hd),
                       ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                       "zeros", cfg.dtype),
                "v": P((L, B, S, KV, hd),
                       ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                       "zeros", cfg.dtype),
                "cpos": P((L, B, S), ("layers", "batch", "cache_seq"),
                          "neg1", "int32"),
            })
        if cfg.ssm_state:  # ssm caches (ssm/hybrid)
            C = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            layers.update({
                "conv": P((L, B, cfg.ssm_conv - 1, C),
                          ("layers", "batch", None, None), "zeros", cfg.dtype),
                "state": P((L, B, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                           ("layers", "batch", "ssm_heads", None, None),
                           "zeros", "float32"),
            })
    return {"layers": layers,
            "pos": P((B,), ("batch",), "zeros", "int32")}


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs for the dry-run / data templates)
# ---------------------------------------------------------------------------

def batch_template(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """P-spec tree for one step's data batch."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if cfg.family == "audio":
        Se = Sd = S // 2
        if kind == "train":
            return {"frames": P((B, Se, cfg.d_model), ("batch", "seq", None),
                                "normal", cfg.dtype),
                    "tokens": P((B, Sd), ("batch", "seq"), "zeros", "int32"),
                    "labels": P((B, Sd), ("batch", "seq"), "zeros", "int32")}
        if kind == "prefill":
            return {"frames": P((B, Se, cfg.d_model), ("batch", "seq", None),
                                "normal", cfg.dtype),
                    "tokens": P((B, Sd), ("batch", "seq"), "zeros", "int32")}
        return {"tokens": P((B, 1), ("batch", None), "zeros", "int32")}
    if cfg.family == "vlm":
        Fl = cfg.frontend_len
        if kind == "train":
            return {"patch_embeds": P((B, Fl, cfg.d_model),
                                      ("batch", "seq", None), "normal", cfg.dtype),
                    "tokens": P((B, S - Fl), ("batch", "seq"), "zeros", "int32"),
                    "labels": P((B, S), ("batch", "seq"), "zeros", "int32")}
        if kind == "prefill":
            return {"patch_embeds": P((B, Fl, cfg.d_model),
                                      ("batch", "seq", None), "normal", cfg.dtype),
                    "tokens": P((B, S - Fl), ("batch", "seq"), "zeros", "int32")}
        return {"tokens": P((B, 1), ("batch", None), "zeros", "int32")}
    # plain decoder families
    if kind == "train":
        return {"tokens": P((B, S), ("batch", "seq"), "zeros", "int32"),
                "labels": P((B, S), ("batch", "seq"), "zeros", "int32")}
    if kind == "prefill":
        return {"tokens": P((B, S), ("batch", "seq"), "zeros", "int32")}
    return {"tokens": P((B, 1), ("batch", None), "zeros", "int32")}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, vocab_size: int):
    """logits [B,S,Vp] (any float dtype), labels [B,S] int32 with
    LABEL_IGNORE masked. Returns (mean_nll, z_loss_term)."""
    logits = logits.astype(jnp.float32)
    mask = (labels != LABEL_IGNORE) & (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * mask
    denom = jnp.maximum(mask.sum(), 1)
    z_loss = jnp.sum(jnp.square(lse) * mask) / denom
    return nll.sum() / denom, z_loss


# ---------------------------------------------------------------------------
# forward dispatch
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, rules: Rules, batch, kind: str):
    """Returns (x [B,S,D], positions [B,S])."""
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        patches = jnp.einsum("bsd,de->bse", batch["patch_embeds"].astype(dtype),
                             params["patch_adapter"].astype(dtype))
        toks = zoo.embed_tokens(params, cfg, batch["tokens"])
        x = jnp.concatenate([patches, toks], axis=1)
    else:
        x = zoo.embed_tokens(params, cfg, batch["tokens"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = rules.constrain(x, "batch", "seq_sp", None)
    return x, positions


def forward_train(params, cfg, rules, par, batch):
    """Returns (logits, labels, aux)."""
    if cfg.family == "audio":
        enc = zoo.encoder_forward(params, cfg, rules, par, batch["frames"])
        x = zoo.embed_tokens(params, cfg, batch["tokens"])
        B, Sd = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32)[None], (B, Sd))
        hid, _, aux = zoo.encdec_decoder_forward(params, cfg, rules, par, x,
                                                 pos, enc)
    else:
        x, pos = _embed_inputs(params, cfg, rules, batch, "train")
        hid, _, aux = zoo.decoder_forward(params, cfg, rules, par, x, pos)
    logits = zoo.logits_fn(params, cfg, hid)
    return logits, batch["labels"], aux


def make_loss_fn(cfg: ModelConfig, rules: Rules, par: Parallelism):
    def loss_fn(params, batch):
        logits, labels, aux = forward_train(params, cfg, rules, par, batch)
        nll, z = softmax_xent(logits, labels, cfg.vocab_size)
        loss = nll + 1e-4 * z + 1e-2 * aux
        return loss, {"loss": nll, "z_loss": z, "aux_loss": aux}
    return loss_fn


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, rules: Rules, par: Parallelism,
                    opt_cfg: OptimizerConfig):
    loss_fn = make_loss_fn(cfg, rules, par)

    if par.mixed_precision:
        # bf16 compute params (cotangents — and therefore the backward's
        # data-parallel reductions — run in bf16, halving collective bytes);
        # the f32 params stay the master copy updated by AdamW.
        base_loss_fn = loss_fn

        def loss_fn(params, batch):  # noqa: F811 — deliberate wrap
            p_bf16 = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.bfloat16)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
            return base_loss_fn(p_bf16, batch)

    def train_step(params, opt_state, batch):
        if par.grad_accum > 1:
            B = jax.tree_util.tree_leaves(batch)[0].shape[0]
            micro = B // par.grad_accum

            def acc_step(carry, mb):
                (l_acc, g_acc) = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (l_acc + l, g_acc), m

            batch_r = jax.tree_util.tree_map(
                lambda x: x.reshape((par.grad_accum, micro) + x.shape[1:]),
                batch)
            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), ms = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros_g), batch_r)
            loss = loss / par.grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / par.grad_accum, grads)
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(grads, opt_state, params,
                                                      opt_cfg)
        metrics = dict(metrics, total_loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Rules, par: Parallelism,
                      shape: ShapeConfig):
    # leave generation headroom so decode never overwrites live slots
    cache_t = cache_template(cfg, shape, extra_slots=DECODE_HEADROOM)

    def prefill_step(params, batch):
        cache0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, p.dtype),
            cache_t["layers"],
            is_leaf=lambda x: isinstance(x, P))
        if cfg.family == "audio":
            enc = zoo.encoder_forward(params, cfg, rules, par, batch["frames"])
            x = zoo.embed_tokens(params, cfg, batch["tokens"])
            B, Sd = batch["tokens"].shape
            pos = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32)[None],
                                   (B, Sd))
            hid, layer_cache, _ = zoo.encdec_decoder_forward(
                params, cfg, rules, par, x, pos, enc,
                cache={"layers": cache0}, decode=False)
            S_total = Sd
        else:
            x, pos = _embed_inputs(params, cfg, rules, batch, "prefill")
            hid, layer_cache, _ = zoo.decoder_forward(
                params, cfg, rules, par, x, pos,
                cache={"layers": cache0}, decode=False)
            S_total = x.shape[1]
        logits = zoo.logits_fn(params, cfg, hid[:, -1:])
        B = hid.shape[0]
        cache = {"layers": layer_cache,
                 "pos": jnp.full((B,), S_total, jnp.int32)}
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Rules, par: Parallelism,
                     shape: ShapeConfig):
    def decode_step(params, cache, batch):
        tokens = batch["tokens"]                       # [B, 1]
        x = zoo.embed_tokens(params, cfg, tokens)
        B = x.shape[0]
        pos = cache["pos"][:, None]                    # [B, 1] per-slot
        if cfg.family == "audio":
            hid, layer_cache, _ = zoo.encdec_decoder_forward(
                params, cfg, rules, par, x, pos, None, cache=cache,
                decode=True)
        else:
            hid, layer_cache, _ = zoo.decoder_forward(
                params, cfg, rules, par, x, pos, cache=cache, decode=True)
        logits = zoo.logits_fn(params, cfg, hid)
        new_cache = {"layers": layer_cache, "pos": cache["pos"] + 1}
        return logits, new_cache

    return decode_step


def make_step(cfg, rules, par, shape, opt_cfg: Optional[OptimizerConfig] = None):
    if shape.kind == "train":
        return make_train_step(cfg, rules, par, opt_cfg or OptimizerConfig(
            moment_dtype=par.moment_dtype))
    if shape.kind == "prefill":
        return make_prefill_step(cfg, rules, par, shape)
    return make_decode_step(cfg, rules, par, shape)
