"""Mamba-2 SSD (state-space duality) layer: chunked quadratic-within-chunk /
recurrent-across-chunk training path, O(1)-state decode path.

The chunked algorithm is the oracle for kernels/ssd_scan.py (same math).
Shapes: x [B,S,H,P] heads x headdim, B/C [B,S,G,N] (G groups, GQA-style),
dt [B,S,H] (post-softplus), A [H] negative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _segsum_decay(a):
    """a: [..., cs] per-step log-decay (<=0).
    Returns [..., cs, cs] matrix exp(sum_{t=j+1..i} a_t) for i>=j else 0."""
    cs = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]       # [..., i, j]
    tril = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)


def ssd_chunked(x, dt, A, B, C, *, chunk: int, initial_state=None):
    """Returns (y [B,S,H,P], final_state [B,H,P,N]). f32 internals.
    S is padded up to a chunk multiple internally (dt=0 padding is exact:
    zero contribution to outputs and decay-neutral for the state)."""
    Bz, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, state = ssd_chunked(x, dt, A, B, C, chunk=chunk,
                               initial_state=initial_state)
        return y[:, :S], state
    nc, cs = S // chunk, chunk
    rep = H // G

    f32 = jnp.float32
    x_ = x.astype(f32).reshape(Bz, nc, cs, H, P)
    dt_ = dt.astype(f32).reshape(Bz, nc, cs, H)
    B_ = B.astype(f32).reshape(Bz, nc, cs, G, N)
    C_ = C.astype(f32).reshape(Bz, nc, cs, G, N)
    a = dt_ * A.astype(f32)                            # [b,c,s,h] <= 0
    a_h = a.transpose(0, 1, 3, 2)                      # [b,c,h,s]
    cum = jnp.cumsum(a_h, axis=-1)                     # [b,c,h,s]
    xdt = x_ * dt_[..., None]                          # [b,c,s,h,p]

    # ---- intra-chunk (quadratic within cs) ----
    seg = _segsum_decay(a_h)                           # [b,c,h,i,j]
    cb = jnp.einsum("bcign,bcjgn->bcgij", C_, B_)      # [b,c,g,i,j]
    cb = jnp.repeat(cb, rep, axis=2)                   # g -> h
    scores = cb * seg
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum[..., -1:] - cum)        # [b,c,h,s]
    Bh = jnp.repeat(B_, rep, axis=3).transpose(0, 1, 3, 2, 4)  # [b,c,h,s,n]
    states = jnp.einsum("bchj,bchjn,bcjhp->bchpn",
                        decay_to_end, Bh, xdt)         # [b,c,h,p,n]

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cum[..., -1])                # [b,c,h]
    h0 = (jnp.zeros((Bz, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h_prev, inp):
        st, dec = inp                                  # [b,h,p,n], [b,h]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h_final, h_prevs = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                   # [b,c,h,p,n]

    Ch = jnp.repeat(C_, rep, axis=3).transpose(0, 1, 3, 2, 4)  # [b,c,h,s,n]
    y_inter = jnp.einsum("bchin,bchpn->bcihp", Ch * jnp.exp(cum)[..., None],
                         h_prevs)

    y = (y_intra + y_inter).reshape(Bz, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD update. state [B,H,P,N]; x_t [B,H,P]; dt_t [B,H];
    B_t/C_t [B,G,N]. Returns (y [B,H,P], new_state)."""
    f32 = jnp.float32
    Bz, H, P, N = state.shape
    G = B_t.shape[1]
    rep = H // G
    a = jnp.exp(dt_t.astype(f32) * A.astype(f32))      # [B,H]
    Bh = jnp.repeat(B_t.astype(f32), rep, axis=1)      # [B,H,N]
    Ch = jnp.repeat(C_t.astype(f32), rep, axis=1)
    upd = (dt_t.astype(f32)[..., None] * x_t.astype(f32))[..., None] \
        * Bh[..., None, :]                             # [B,H,P,N]
    new_state = state.astype(f32) * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state.astype(state.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Sequential reference recurrence (oracle for tests; small shapes)."""
    f32 = jnp.float32
    Bz, S, H, P = x.shape
    N = B.shape[-1]
    state = (jnp.zeros((Bz, H, P, N), f32) if initial_state is None
             else initial_state.astype(f32))
    ys = []
    for t in range(S):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                   C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), state


# ---------------------------------------------------------------------------
# depthwise causal conv (the mamba2 short conv)
# ---------------------------------------------------------------------------

def causal_conv(x, w, state=None):
    """x: [B, S, C]; w: [K, C] depthwise taps. If ``state`` ([B, K-1, C]) is
    given, treat x as a continuation (decode/prefill chunk) and return the
    updated state. Returns (y [B,S,C], new_state)."""
    K = w.shape[0]
    B, S, Cc = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, Cc), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B, S+K-1, C]
    y = sum(xp[:, k:k + S] * w[k].astype(x.dtype) for k in range(K))
    new_state = xp[:, S:] if K > 1 else state
    return y, new_state
