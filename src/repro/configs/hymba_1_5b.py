"""hymba-1.5b — [arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5)
d_ff=5504 vocab=32001, ssm_state=16 — parallel attention + mamba heads in
every layer (hybrid head module)."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=2048,         # hymba uses SWA in all but a few layers
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_groups=1,
)

# SWA + SSM => sub-quadratic decode => long_500k runs.
# 25 heads are not divisible by the 16-way model axis: attention shards over
# batch only (DP); FFN/vocab still use tensor parallelism (see sharding rules).
PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=False,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2411.13676; hf]")
