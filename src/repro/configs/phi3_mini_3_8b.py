"""phi3-mini-3.8b — [arXiv:2404.14219; unverified] 32L d_model=3072 32H
(kv=32, MHA) d_ff=8192 vocab=32064, RoPE + SwiGLU."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
)

PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2404.14219; unverified]")
