"""grok-1-314b — [hf:xai-org/grok-1; unverified] 64L d_model=6144 48H
(GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
)

# 314B params: fp32 master + Adam moments don't fit 256 x 16GiB chips, so this
# arch uses int8 (error-compensated) moment storage + FSDP + SP + full remat.
# Full attention => long_500k skipped (quadratic), see DESIGN.md.
PARALLELISM = Parallelism(
    fsdp=True,
    sequence_parallel=True,
    remat="full",
    moment_dtype="int8",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[hf:xai-org/grok-1; unverified]")
