"""deepseek-7b — [arXiv:2401.02954; hf] 30L d_model=4096 32H (kv=32, i.e. MHA)
d_ff=11008 vocab=102400, llama-style."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
)

PARALLELISM = Parallelism(
    fsdp=True,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2401.02954; hf]")
