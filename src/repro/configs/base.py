"""Configuration dataclasses for the repro framework.

Two worlds share this module:
  * ModelConfig / ShapeConfig / Parallelism — the TPU-scale LM framework
    (assigned architectures × input shapes, multi-pod dry-run).
  * KlessydraConfig — the paper's coprocessor taxonomy (M, F, D, N) used by
    the cycle-accurate simulator in ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# LM framework configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (one instance per assigned arch)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0               # query heads (0 for attention-free)
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # --- attention flavor ---
    sliding_window: int = 0          # 0 => full causal attention
    rope_theta: float = 10_000.0

    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0          # >0 => enc-dec model

    # --- modality frontend stub ---
    frontend: str = "none"           # none | patch | frames
    frontend_len: int = 0            # patches / frames prepended (vlm) or enc input (audio)

    # --- numerics ---
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (name, kind, seq_len, global_batch)."""

    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


# The four assigned LM shapes (identical sets for all 10 archs).
SHAPES: dict = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class Parallelism:
    """How an arch maps onto the mesh. Follows the paper's TLP/DLP lens:
    ``data``(+``pod``) axes carry thread-level parallelism, ``model`` carries
    data-level parallelism (tensor sharding + kernel lanes)."""

    fsdp: bool = False               # shard param d_model dim over "data"
    sequence_parallel: bool = False  # shard residual seq dim over "model"
    expert_parallel: bool = False    # shard experts over "pod" when divisible
    remat: str = "block"             # none | block | full
    scan_layers: bool = True
    moment_dtype: str = "float32"    # Adam moment storage (int8 => compressed)
    grad_accum: int = 1
    attn_q_block: int = 2048         # XLA flash attention block sizes
    attn_kv_block: int = 2048
    # --- beyond-paper perf knobs (§Perf hillclimbs; defaults = baseline) ---
    swa_block_skip: bool = False     # sliding-window: only visit KV blocks
    #                                  inside the window (true FLOP cut)
    moe_decode_group: bool = False   # decode MoE: one routing group per
    #                                  local batch (kills capacity padding)
    pure_dp: bool = False            # small models: use the model axis as
    #                                  extra data parallelism + ZeRO sharding
    #                                  (the paper's TLP/DLP rebalance)
    mixed_precision: bool = False    # bf16 compute params + f32 master:
    #                                  backward collectives go bf16 (halved)
    attn_repeat_kv: bool = False     # GQA: repeat K/V to H heads instead of
    #                                  grouped-q reshape — keeps the score
    #                                  einsum head-sharded (no per-block
    #                                  all-to-all resharding)
    moe_capacity_sharding: bool = False  # shard MoE dispatch slots (C) over
    #                                  "model" instead of expert width (F):
    #                                  w_down contraction becomes local (no
    #                                  [B,E,C,D] all-reduce per layer)
    # Which shape cells run for this arch ("long_500k" only for sub-quadratic).
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def replace(self, **kw) -> "Parallelism":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ArchSpec:
    """Everything the launcher needs for one --arch id."""

    model: ModelConfig
    parallelism: Parallelism
    source: str = ""                 # provenance note [paper/hf; tier]


# ---------------------------------------------------------------------------
# Klessydra (paper) configs
# ---------------------------------------------------------------------------

# Internal MFU functional units (contended individually by the
# heterogeneous-MIMD scheme; see repro.core.isa.Unit — kept as string
# literals here so configs stay import-light).
MFU_UNITS = ("adder", "multiplier", "shifter", "cmp", "move")


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


@dataclass(frozen=True)
class KlessydraConfig:
    """The paper's coprocessor design space: SPMI count M, MFU count F,
    lanes D, SPMs N, plus SPM capacity and hart count.

    Degenerate combinations are rejected at construction time (M < 1,
    F > M, non-power-of-two D, zero-byte SPMs, ...) with a ``ValueError``
    naming the offending field — the design-space sweeps rely on this
    being the single validation point.
    """

    name: str
    M: int = 1                       # number of SPM interfaces
    F: int = 1                       # number of MFUs
    D: int = 1                       # lanes per MFU (= SPM banks)
    N: int = 4                       # number of SPMs per SPMI
    harts: int = 3                   # IMT hardware threads
    spm_kbytes: int = 4              # capacity of each SPM (KiB)
    elem_bytes: int = 4              # 32-bit fixed point (paper default)
    mem_port_bytes: int = 4          # 32-bit main-memory port
    vector_setup_cycles: int = 5     # "initial latency between 4 and 8 cycles"
    mem_latency_cycles: int = 2      # main memory access latency
    # Narrowest SIMD lane the MFU datapath can split a 32-bit bank into:
    # 8 => full sub-word SIMD (4x8-bit or 2x16-bit per bank, the paper's
    # sub-word extension and the simulator's historical behavior);
    # 32 => no sub-word hardware (narrow elements stream one per lane).
    subword_bits: int = 8
    # Per-internal-unit FU replication inside each MFU, as ("unit", count)
    # overrides, e.g. (("multiplier", 2),). Units not listed have one
    # instance. Only the heterogeneous-MIMD scheme (shared MFU contended
    # per internal unit) can exploit counts > 1.
    fu_counts: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self):
        def bad(fieldname: str, why: str):
            raise ValueError(
                f"KlessydraConfig({self.name!r}): field {fieldname!r} "
                f"{why}")
        if self.M < 1:
            bad("M", f"must be >= 1 SPM interface, got {self.M}")
        if self.F < 1:
            bad("F", f"must be >= 1 MFU, got {self.F}")
        if self.F > self.M:
            bad("F", f"cannot exceed M (more MFUs than SPM interfaces "
                     f"to feed them), got F={self.F} > M={self.M}")
        if not _is_pow2(self.D):
            bad("D", f"must be a power of two >= 1 (SPM bank count), "
                     f"got {self.D}")
        if self.N < 1:
            bad("N", f"must be >= 1 SPM per interface, got {self.N}")
        if self.harts < 1:
            bad("harts", f"must be >= 1, got {self.harts}")
        if self.spm_kbytes < 1:
            bad("spm_kbytes", f"must be >= 1 KiB (a zero-byte SPM can "
                              f"hold no vector), got {self.spm_kbytes}")
        if self.elem_bytes not in (1, 2, 4):
            bad("elem_bytes", f"must be 1, 2 or 4, got {self.elem_bytes}")
        if self.mem_port_bytes < 1:
            bad("mem_port_bytes", f"must be >= 1, got {self.mem_port_bytes}")
        if self.vector_setup_cycles < 0:
            bad("vector_setup_cycles",
                f"must be >= 0, got {self.vector_setup_cycles}")
        if self.mem_latency_cycles < 0:
            bad("mem_latency_cycles",
                f"must be >= 0, got {self.mem_latency_cycles}")
        if self.subword_bits not in (8, 16, 32):
            bad("subword_bits", f"must be 8, 16 or 32, got "
                                f"{self.subword_bits}")
        seen = set()
        for entry in self.fu_counts:
            if (not isinstance(entry, tuple)) or len(entry) != 2:
                bad("fu_counts", f"entries must be (unit, count) pairs, "
                                 f"got {entry!r}")
            unit, count = entry
            if unit not in MFU_UNITS:
                bad("fu_counts", f"unknown MFU unit {unit!r} "
                                 f"(valid: {MFU_UNITS})")
            if unit in seen:
                bad("fu_counts", f"duplicate unit {unit!r}")
            seen.add(unit)
            if not isinstance(count, int) or count < 1:
                bad("fu_counts", f"count for {unit!r} must be an int >= 1, "
                                 f"got {count!r}")

    def fu_count(self, unit: str) -> int:
        """How many instances of one internal functional unit each MFU
        carries (1 unless overridden in ``fu_counts``)."""
        for u, c in self.fu_counts:
            if u == unit:
                return c
        return 1

    @property
    def spm_capacity_bytes(self) -> int:
        """Unified SPM address space per interface: N SPMs of spm_kbytes."""
        return self.N * self.spm_kbytes * 1024

    @property
    def scheme(self) -> str:
        if self.M == 1 and self.F == 1:
            return "SISD" if self.D == 1 else f"SIMD"
        if self.M > 1 and self.F == self.M:
            return "SymMIMD" if self.D == 1 else "SymMIMD+SIMD"
        if self.M > 1 and self.F == 1:
            return "HetMIMD" if self.D == 1 else "HetMIMD+SIMD"
        return "custom"

    def replace(self, **kw) -> "KlessydraConfig":
        return dataclasses.replace(self, **kw)


def klessydra_taxonomy() -> dict:
    """The exact configuration sweep of the paper's Table 2."""
    out = {}
    for D in (1, 2, 4, 8):
        out[f"sisd" if D == 1 else f"simd_d{D}"] = KlessydraConfig(
            name="SISD" if D == 1 else f"SIMD D={D}", M=1, F=1, D=D)
        out[f"sym_mimd_d{D}" if D > 1 else "sym_mimd"] = KlessydraConfig(
            name=f"Sym MIMD D={D}", M=3, F=3, D=D)
        out[f"het_mimd_d{D}" if D > 1 else "het_mimd"] = KlessydraConfig(
            name=f"Het MIMD D={D}", M=3, F=1, D=D)
    return out
