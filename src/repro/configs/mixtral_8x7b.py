"""mixtral-8x7b — [arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, MoE 8 experts top-2, sliding-window attention."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    rope_theta=1e6,
)

# SWA => KV cache bounded by the window => long_500k decode is sub-quadratic.
PARALLELISM = Parallelism(
    fsdp=True,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2401.04088; hf]")
