"""llama3.2-1b — [hf:meta-llama/Llama-3.2-1B; unverified] 16L d_model=2048
32H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=True,
)

PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=False,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[hf:meta-llama/Llama-3.2-1B; unverified]")
