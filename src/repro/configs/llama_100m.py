"""llama-100m — in-house ~100M-parameter llama-style config used by the
end-to-end training example (examples/train_lm.py). Not one of the 10
assigned architectures; included so the example trains a REAL (non-reduced)
model on CPU in reasonable wall time."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="llama100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=3072,
    vocab_size=32000,
    tie_embeddings=True,
)

PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=False,
    remat="none",
    shapes=("train_4k",),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[in-house example config]")
