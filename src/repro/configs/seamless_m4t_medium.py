"""seamless-m4t-medium — [arXiv:2308.11596; hf] 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206, encoder-decoder, audio frontend stubbed
(input_specs provides precomputed frame embeddings)."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,               # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    frontend="frames",
)

PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=False,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2308.11596; hf]")
