"""pixtral-12b — [hf:mistralai/Pixtral-12B-2409; unverified] 40L d_model=5120
32H (GQA kv=8) d_ff=14336 vocab=131072; pixtral-ViT frontend stubbed
(input_specs provides precomputed patch embeddings), mistral-nemo backbone."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    frontend="patch",
    frontend_len=1024,           # image patch tokens prepended to the text
)

PARALLELISM = Parallelism(
    fsdp=True,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[hf:mistralai/Pixtral-12B-2409; unverified]")
