"""stablelm-12b — [hf:stabilityai/stablelm-2-1_6b family; hf] 40L d_model=5120
32H (GQA kv=8) d_ff=13824 vocab=100352."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
)

PARALLELISM = Parallelism(
    fsdp=True,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[hf:stabilityai/stablelm-2-12b; hf]")
