"""mamba2-1.3b — [arXiv:2405.21060; unverified] 48L d_model=2048 attention-free
SSD (state-space duality), ssm_state=128, vocab=50280."""
from repro.configs.base import ArchSpec, ModelConfig, Parallelism

MODEL = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_groups=1,
)

# Attention-free => O(1)-state decode => long_500k runs.
# SP shards the residual stream's seq dim: 48 layers of saved carries at
# 4k x gb256 would otherwise cost 12 GiB/chip of remat checkpoints.
PARALLELISM = Parallelism(
    fsdp=False,
    sequence_parallel=True,
    remat="block",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)

SPEC = ArchSpec(MODEL, PARALLELISM, source="[arXiv:2405.21060; unverified]")
