"""Config registry: ``--arch <id>`` → ArchSpec.

Arch ids use the exact names from the assignment (dots and dashes); module
files use underscores.
"""
from __future__ import annotations

import dataclasses
from importlib import import_module

from repro.configs.base import (
    ArchSpec,
    KlessydraConfig,
    ModelConfig,
    Parallelism,
    ShapeConfig,
    SHAPES,
    klessydra_taxonomy,
)

# example-only configs (not part of the assigned 10 / the dry-run sweep)
_EXTRA_MODULES = {
    "llama100m": "repro.configs.llama_100m",
}

_ARCH_MODULES = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
}


def list_archs() -> list:
    return sorted(_ARCH_MODULES)


def get_spec(arch: str) -> ArchSpec:
    mod = _ARCH_MODULES.get(arch) or _EXTRA_MODULES.get(arch)
    if mod is None:
        raise KeyError(f"unknown arch {arch!r}; known: "
                       f"{list_archs() + sorted(_EXTRA_MODULES)}")
    return import_module(mod).SPEC


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def arch_cells(arch: str) -> list:
    """All (arch, shape) cells assigned to this arch (long_500k only where
    the decode path is sub-quadratic — see DESIGN.md §Arch-applicability)."""
    spec = get_spec(arch)
    return [(arch, s) for s in spec.parallelism.shapes]


def all_cells() -> list:
    return [c for a in list_archs() for c in arch_cells(a)]


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests — same family/topology, tiny dims.
# ---------------------------------------------------------------------------

def reduced_model(cfg: ModelConfig) -> ModelConfig:
    """Shrink a ModelConfig to CPU-smoke scale, preserving the family and
    every structural feature (MoE, GQA ratio, SWA, SSM, enc-dec, frontend)."""
    kw = dict(
        num_layers=2,
        d_model=64,
        vocab_size=512,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads // cfg.num_heads),
                  head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.num_experts:
        # ample capacity: smoke tests compare decode (dropless) vs forward
        # (capacity-dropped) — at tiny scale drops would differ, not a bug
        kw.update(num_experts=4, num_experts_per_tok=min(2, cfg.num_experts_per_tok),
                  capacity_factor=4.0)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2)
    if cfg.frontend_len:
        kw.update(frontend_len=8)
    return cfg.replace(**kw)


def reduced_shape(shape: ShapeConfig, seq_len: int = 64, batch: int = 2) -> ShapeConfig:
    if shape.kind == "decode":
        return shape.replace(seq_len=seq_len, global_batch=batch)
    return shape.replace(seq_len=seq_len, global_batch=batch)


__all__ = [
    "ArchSpec", "KlessydraConfig", "ModelConfig", "Parallelism", "ShapeConfig",
    "SHAPES", "klessydra_taxonomy", "list_archs", "get_spec", "get_shape",
    "arch_cells", "all_cells", "reduced_model", "reduced_shape",
]
