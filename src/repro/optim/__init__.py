from repro.optim.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   global_norm, lr_schedule)
