"""AdamW with optional int8-quantized moment storage.

Moment quantization (per-row absmax scales) is the memory trick that lets the
314B grok arch train on 256 x 16 GiB chips: fp32 m+v would be 2.5 TB; int8
(+f32 scales) is ~0.63 TB. Quantization error behaves like a tiny amount of
moment noise; we validate convergence parity on small models in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # float32 | bfloat16 | int8


def lr_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(np.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# int8 moment codec (per-row absmax)
# ---------------------------------------------------------------------------

def _q_scale_shape(shape):
    return shape[:-1] + (1,) if len(shape) >= 1 else shape


def quantize_i8(x):
    if x.ndim == 0:
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
        return {"q": jnp.round(x / scale).astype(jnp.int8), "s": scale}
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.float32)}


def dequantize_i8(qs):
    return qs["q"].astype(jnp.float32) * qs["s"]


def _moment_zeros(leaf, dtype: str):
    if dtype == "int8":
        return {"q": jnp.zeros(leaf.shape, jnp.int8),
                "s": jnp.ones(_q_scale_shape(leaf.shape), jnp.float32)}
    return jnp.zeros(leaf.shape, jnp.dtype(dtype))


def _moment_read(m, dtype: str):
    return dequantize_i8(m) if dtype == "int8" else m.astype(jnp.float32)


def _moment_write(x, dtype: str):
    return quantize_i8(x) if dtype == "int8" else x.astype(jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, cfg: OptimizerConfig):
    dt = cfg.moment_dtype
    return {
        "count": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(lambda p: _moment_zeros(p, dt), params),
        "v": jax.tree_util.tree_map(lambda p: _moment_zeros(p, dt), params),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    dt = cfg.moment_dtype
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, opt_state["count"])
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0

    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    is_moment = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = cfg.b1 * _moment_read(m, dt) + (1 - cfg.b1) * g
        v_f = cfg.b2 * _moment_read(v, dt) + (1 - cfg.b2) * jnp.square(g)
        step = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (step + decay)).astype(p.dtype)
        return new_p, _moment_write(m_f, dt), _moment_write(v_f, dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"count": count, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
