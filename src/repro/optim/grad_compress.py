"""int8 error-feedback gradient compression for cross-pod reduction.

At 2 pods x 256 chips the pod-to-pod links are the scarcest bandwidth; the
classic trick is to all-reduce 8-bit gradients with an error-feedback
buffer so the quantization error is re-injected next step (convergence
neutral to first order). Implemented with shard_map + explicit psum over
the ``pod`` axis so the wire format really is int8 — XLA's automatic
reductions would otherwise run in f32.

Used by launch/train.py when --grad-compress is set; validated in tests
(error feedback => exact mean gradient recovered over repeated steps).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def quantize_block(x, *, axis=-1):
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_block(q, scale):
    return q.astype(jnp.float32) * scale


def compress_residual(g, err):
    """(quantized, scale, new_error) with error feedback."""
    x = g.astype(jnp.float32) + err
    q, s = quantize_block(x)
    new_err = x - dequantize_block(q, s)
    return q, s, new_err


def cross_pod_mean(grads, errors, mesh, axis_name: str = "pod"):
    """All-reduce (mean) a gradient pytree across the pod axis with int8
    wire format + error feedback. grads/errors: matching pytrees of f32
    arrays already sharded over the in-pod axes."""

    def leaf_fn(g, e):
        q, s, new_e = compress_residual(g, e)
        # int8 payload summed across pods (wire bytes = 1/4 of f32)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_max = jax.lax.pmax(s, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = q_sum.astype(jnp.float32) * s_max / n
        return mean, new_e

    def sharded(g_tree, e_tree):
        return jax.tree_util.tree_map(leaf_fn, g_tree, e_tree)

    spec = jax.tree_util.tree_map(lambda _: PS(), grads)
    from repro.compat import shard_map
    fn = shard_map(sharded, mesh=mesh,
                   in_specs=(spec, spec), out_specs=(spec, spec),
                   check_vma=False)
    return fn(grads, errors)
