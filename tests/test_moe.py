"""MoE dispatch invariants: capacity respected, routing correct,
FLOP-free dispatch equals dense mixture when capacity is ample."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.models.moe import capacity, dispatch_indices, moe_ffn, route


def dense_moe_ref(x, params, num_experts, top_k):
    """Oracle: run every expert on every token, combine with router
    weights (no capacity drops)."""
    w, idx, _ = route(x, params["router"], num_experts, top_k)
    dtype = x.dtype
    outs = []
    for e in range(num_experts):
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"][e].astype(dtype))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"][e].astype(dtype))
        o = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                       params["w_down"][e].astype(dtype))
        outs.append(o)
    stack = jnp.stack(outs, axis=2)                  # [B,S,E,D]
    sel = jnp.take_along_axis(stack, idx[..., None], axis=2)
    return jnp.einsum("bskd,bsk->bsd", sel.astype(jnp.float32), w)


def make_params(rng, D=32, F=64, E=4):
    return {
        "router": jnp.asarray(rng.normal(0, 0.5, (D, E)), jnp.float32),
        "w_gate": jnp.asarray(rng.normal(0, 0.1, (E, D, F)), jnp.float32),
        "w_up": jnp.asarray(rng.normal(0, 0.1, (E, D, F)), jnp.float32),
        "w_down": jnp.asarray(rng.normal(0, 0.1, (E, F, D)), jnp.float32),
    }


def test_moe_matches_dense_reference_with_ample_capacity(rng):
    D, E, k = 32, 4, 2
    params = make_params(rng, D=D, E=E)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, D)), jnp.float32)
    y, aux = moe_ffn(x, params, num_experts=E, top_k=k, cap_factor=4.0)
    want = dense_moe_ref(x, params, E, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
    assert float(aux) > 0


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_dispatch_invariants(seed):
    rng = np.random.default_rng(seed)
    B, S, E, k = 2, 16, 4, 2
    idx = jnp.asarray(rng.integers(0, E, (B, S, k)), jnp.int32)
    cap = capacity(S, E, k, 1.0)
    slot_token, slot_valid, token_slot = map(
        np.asarray, dispatch_indices(idx, E, cap))
    # every valid slot holds a token actually routed to that expert
    for b in range(B):
        for e in range(E):
            for c in range(cap):
                if slot_valid[b, e, c]:
                    t = slot_token[b, e, c]
                    assert np.asarray(idx)[b, t // k, t % k] == e
    # no slot is used twice
    for b in range(B):
        for e in range(E):
            used = slot_token[b, e][slot_valid[b, e]]
            assert len(set(used.tolist())) == len(used)
    # capacity respected by construction (shape) + kept entries in range
    assert (token_slot[token_slot < cap] >= 0).all()


def test_capacity_formula():
    assert capacity(4096, 8, 2, 1.25) >= 4096 * 2 * 1.25 / 8
    assert capacity(4096, 8, 2, 1.25) % 8 == 0
