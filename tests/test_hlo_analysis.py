"""Trip-count-aware HLO accounting vs jax's own cost analysis (loop-free)
and vs hand-computed FLOPs (loops)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    acct = analyze_hlo(c.as_text())
    want = 2 * 128 * 256 * 64
    assert acct["dot_flops"] == want
    # agrees with XLA's own analysis on loop-free programs
    xla = xla_cost_analysis(c)["flops"]
    assert abs(acct["dot_flops"] - xla) / xla < 0.05


def test_scan_multiplies_by_trip_count():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    c = _compile(f, jnp.zeros((64, 64), jnp.float32))
    acct = analyze_hlo(c.as_text())
    want = 10 * 2 * 64 ** 3
    assert abs(acct["dot_flops"] - want) / want < 0.05
    # XLA's builtin counts the body once — exactly the bug we fix
    xla = xla_cost_analysis(c)["flops"]
    assert xla < acct["dot_flops"] / 5


def test_nested_scan():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    c = _compile(f, jnp.zeros((32, 32), jnp.float32))
    acct = analyze_hlo(c.as_text())
    want = 12 * 2 * 32 ** 3
    assert abs(acct["dot_flops"] - want) / want < 0.05


def test_hbm_bytes_reasonable():
    a = jnp.zeros((1024, 1024), jnp.float32)
    c = _compile(lambda x: x @ x + 1.0, a)
    acct = analyze_hlo(c.as_text())
    four_mb = 4 * 1024 * 1024
    # at least: read a (as two operands) + write result + elementwise pass
    assert acct["hbm_bytes"] >= 3 * four_mb
    assert acct["hbm_bytes"] <= 20 * four_mb


def test_no_collectives_on_single_device():
    a = jnp.zeros((64,), jnp.float32)
    c = _compile(lambda x: x * 2, a)
    acct = analyze_hlo(c.as_text())
    assert acct["collective_bytes"]["total"] == 0
