"""Serving engine: continuous batching, slot reuse, latency accounting."""
import numpy as np
import pytest
import jax

from repro.configs import get_spec, reduced_model
from repro.models import model_zoo as zoo
from repro.models import params as params_lib
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def small_engine_parts():
    spec = get_spec("llama3.2-1b")
    cfg = reduced_model(spec.model)
    params = params_lib.initialize(zoo.param_template(cfg),
                                   jax.random.PRNGKey(0))
    return cfg, params


def test_drains_more_requests_than_slots(small_engine_parts, rng):
    cfg, params = small_engine_parts
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(1, 90, 4 + i).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run_until_drained(max_steps=500)
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(r.first_token_at is not None and r.done_at is not None
               for r in done)


def test_slot_reuse_is_deterministic(small_engine_parts, rng):
    cfg, params = small_engine_parts
    prompt = rng.integers(1, 90, 6).astype(np.int32)
    eng = ServingEngine(cfg, params, slots=2, max_seq=64)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=prompt.copy(), max_new_tokens=5))
    done = eng.run_until_drained(max_steps=500)
    outs = {tuple(r.out_tokens) for r in done}
    assert len(outs) == 1, outs


def test_greedy_matches_decode_loop(small_engine_parts, rng):
    """Engine output == manual prefill+argmax-decode for a single request."""
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.models import steps as steps_lib
    from repro.models.sharding import make_rules
    from repro.configs.base import Parallelism

    cfg, params = small_engine_parts
    par = Parallelism(remat="none")
    rules = make_rules(None, cfg, par)
    prompt = rng.integers(1, 90, 7).astype(np.int32)

    eng = ServingEngine(cfg, params, slots=1, max_seq=64)
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=4))
    got = eng.run_until_drained(max_steps=200)[0].out_tokens

    # manual: teacher-forced decode through the same decode step
    dshape = ShapeConfig("d", "decode", 64, 1)
    decode = jax.jit(steps_lib.make_decode_step(cfg, rules, par, dshape))
    cache = eng._init_cache()
    cache = jax.tree_util.tree_map(lambda x: x, cache)
    toks = list(prompt)
    out = []
    cur = None
    from repro.models import params as params_lib2
    cache = ServingEngine(cfg, params, slots=1, max_seq=64).cache
    for t in toks:
        logits, cache = decode(params, cache,
                               {"tokens": jnp.asarray([[t]], jnp.int32)})
    for _ in range(4):
        nxt = int(np.asarray(jnp.argmax(logits[:, -1], axis=-1))[0])
        out.append(nxt)
        logits, cache = decode(params, cache,
                               {"tokens": jnp.asarray([[nxt]], jnp.int32)})
    assert got == out
