"""Unified telemetry layer tests: tracer + Chrome export, metrics
registry, trace schema validation, the shared volatile-key scrubber,
cyclesim trace integrity (emitted spans sum to the ``HartStats``
breakdown), determinism of canonical traces, the pinned disabled-path
overhead, serving view-vs-report cross-checks and the DSE sweep's
telemetry/progress/SVG satellites.

The acceptance bar for the observability tentpole:
  * every producer's trace passes ``validate_trace`` (kvi-trace-v1),
  * ``obs view`` reproduces the serving report's makespan and latency
    percentiles from the flow events alone,
  * canonical reports stay byte-identical with observability enabled,
  * the disabled path allocates nothing and stays within 2% of the
    pre-instrumentation runtime.
"""
import copy
import json
import time

import numpy as np
import pytest

from repro.kvi.cyclesim import CycleSimBackend
from repro.kvi.dse import DesignSpace, build_report, render_markdown, sweep
from repro.kvi.obs import (DSE_VOLATILE, NULL_METRICS, NULL_OBS,
                           NULL_TRACER, SERVE_VOLATILE, MetricsRegistry,
                           Obs, Tracer, canonical_trace, scrub,
                           validate_metrics, validate_trace)
from repro.kvi.obs.__main__ import flow_summary, stall_attribution, view
from repro.kvi.obs.svg import line_chart, scatter_chart
from repro.kvi.programs import conv2d_program, fft_program
from repro.kvi.serving import (SMOKE_MIX, ServeEngine, canonical_report,
                               make_templates, poisson_arrivals)
from repro.kvi.workload import KviWorkload


def _track_names(trace):
    """(pid, tid) -> (process, lane) from the metadata events."""
    procs, lanes = {}, {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") != "M":
            continue
        if ev["name"] == "process_name":
            procs[ev["pid"]] = ev["args"]["name"]
        else:
            lanes[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    return {k: (procs[k[0]], v) for k, v in lanes.items()}


def _small_prog(seed=3):
    rng = np.random.default_rng(seed)
    img = rng.integers(-8, 8, (8, 8)).astype(np.int32)
    filt = rng.integers(-4, 4, (3, 3)).astype(np.int32)
    return conv2d_program(img, filt, shift=2)


def _tiny_kernels(precision_bits):
    eb = precision_bits // 8
    rng = np.random.default_rng(11)
    img = rng.integers(-8, 8, (8, 8)).astype(np.int32)
    filt = rng.integers(-4, 4, (3, 3)).astype(np.int32)
    return {
        "conv": conv2d_program(img, filt, shift=2, elem_bytes=eb),
        "fft": fft_program(rng.integers(-64, 64, 32).astype(np.int32),
                           rng.integers(-64, 64, 32).astype(np.int32),
                           elem_bytes=eb),
    }


# ---------------------------------------------------------------------------
# Tracer + Chrome export
# ---------------------------------------------------------------------------


class TestTracer:
    def test_export_shape_and_metadata(self):
        tr = Tracer()
        tr.span(("sim", "hart0"), "vadd", 0, 4, args={"engine": "mfu"})
        tr.instant(("sim", "hart0"), "mark", 2)
        tr.counter(("sim", "queue"), "depth", 1, {"n": 3})
        tr.flow_start(("serve", "arrivals"), "req0", 0, 7)
        tr.flow_end(("serve", "hart1"), "req0", 9, 7)
        trace = tr.to_chrome()
        assert trace["displayTimeUnit"] == "ms"
        assert validate_trace(trace) == []
        names = _track_names(trace)
        assert ("sim", "hart0") in names.values()
        assert ("serve", "arrivals") in names.values()
        # pids/tids are stable 1-based first-use ids
        assert sorted({ev["pid"] for ev in trace["traceEvents"]}) == [1, 2]

    def test_events_sorted_per_track(self):
        tr = Tracer()
        tr.span(("p", "l"), "b", 10, 1)
        tr.span(("p", "l"), "a", 0, 1)
        trace = tr.to_chrome()
        xs = [ev["ts"] for ev in trace["traceEvents"]
              if ev["ph"] == "X"]
        assert xs == sorted(xs)
        assert validate_trace(trace) == []

    def test_null_tracer_collects_nothing(self):
        assert NULL_TRACER.enabled is False
        NULL_TRACER.span(("p", "l"), "x", 0, 1)
        NULL_TRACER.flow_start(("p", "l"), "x", 0, 1)
        assert NULL_TRACER.events == []
        assert NULL_TRACER.wall_us() == 0.0

    def test_obs_bundle_enable_states(self):
        assert NULL_OBS.enabled is False
        assert Obs().enabled is False
        live = Obs.on()
        assert live.enabled is True
        assert live.tracer is not Obs.on().tracer

    def test_canonical_trace_drops_wall_and_scrubs(self):
        tr = Tracer()
        tr.span(("p", "l"), "cyc", 0, 4, args={"wall_s": 1.25, "n": 2})
        t0 = tr.wall_us()
        tr.wall_span(("p", "wall"), "compile", t0)
        trace = tr.to_chrome()
        assert any(ev.get("clock") == "wall"
                   for ev in trace["traceEvents"])
        canon = canonical_trace(trace)
        evs = [ev for ev in canon["traceEvents"] if ev["ph"] != "M"]
        assert all(ev["clock"] != "wall" for ev in evs)
        assert all("wall_s" not in ev.get("args", {}) for ev in evs)
        assert any(ev.get("args", {}).get("n") == 2 for ev in evs)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_roundtrip(self):
        m = MetricsRegistry()
        m.counter("a.b").inc()
        m.counter("a.b").inc(3)
        m.gauge("g").set(17)
        snap = m.snapshot()
        assert snap["schema"] == "kvi-metrics-v1"
        assert snap["counters"] == {"a.b": 4}
        assert snap["gauges"] == {"g": 17}
        assert validate_metrics(snap) == []

    def test_histogram_percentiles_match_raw_nearest_rank(self):
        rng = np.random.default_rng(5)
        xs = rng.integers(0, 500, 237).tolist()
        m = MetricsRegistry()
        h = m.histogram("lat")
        for x in xs:
            h.observe(x)
        arr = np.sort(np.asarray(xs))

        def rank(q):
            return int(arr[min(len(arr) - 1,
                               max(0, int(np.ceil(q * len(arr))) - 1))])

        s = h.summary()
        assert s["count"] == len(xs)
        assert s["sum"] == sum(xs)
        assert (s["p50"], s["p95"], s["p99"]) == \
            (rank(0.50), rank(0.95), rank(0.99))
        assert validate_metrics(m.snapshot()) == []

    def test_absorb_skips_non_ints_and_bools(self):
        m = MetricsRegistry()
        m.absorb("cache", {"hits": 5, "misses": 2, "rate": 0.7,
                           "warm": True, "label": "x"})
        snap = m.snapshot()
        assert snap["counters"] == {"cache.hits": 5, "cache.misses": 2}

    def test_null_metrics_allocates_nothing(self):
        assert NULL_METRICS.enabled is False
        c = NULL_METRICS.counter("x")
        c.inc(100)
        assert c is NULL_METRICS.histogram("y")
        assert NULL_METRICS.snapshot()["counters"] == {}

    def test_validate_metrics_negatives(self):
        assert validate_metrics([]) == ["snapshot is not a dict"]
        assert validate_metrics({"schema": "nope"})
        bad = {"schema": "kvi-metrics-v1", "counters": {"c": -1},
               "gauges": {}, "histograms": {}}
        assert any("non-negative" in e for e in validate_metrics(bad))
        bad = {"schema": "kvi-metrics-v1", "counters": {}, "gauges": {},
               "histograms": {"h": {"count": 3, "sum": 1, "min": 0,
                                    "max": 1, "p50": 0, "p95": 1,
                                    "p99": 1, "buckets": {"0": 1}}}}
        assert any("bucket total" in e for e in validate_metrics(bad))


# ---------------------------------------------------------------------------
# Trace schema validation (negatives)
# ---------------------------------------------------------------------------


def _valid_trace():
    tr = Tracer()
    tr.span(("p", "l"), "a", 0, 4)
    tr.counter(("p", "l"), "c", 2, {"v": 1})
    tr.flow_start(("p", "l"), "r", 1, 7)
    tr.flow_end(("p", "l2"), "r", 3, 7)
    return tr.to_chrome()


class TestSchemaNegatives:
    def test_base_is_valid(self):
        assert validate_trace(_valid_trace()) == []

    def _first(self, trace, ph):
        return next(ev for ev in trace["traceEvents"] if ev["ph"] == ph)

    def test_unknown_phase(self):
        t = copy.deepcopy(_valid_trace())
        self._first(t, "X")["ph"] = "Z"
        assert any("unknown phase" in e for e in validate_trace(t))

    def test_unknown_clock(self):
        t = copy.deepcopy(_valid_trace())
        self._first(t, "X")["clock"] = "lunar"
        assert any("unknown clock" in e for e in validate_trace(t))

    def test_non_integral_cycle_ts(self):
        t = copy.deepcopy(_valid_trace())
        self._first(t, "X")["ts"] = 0.5
        assert any("not integral" in e for e in validate_trace(t))

    def test_x_without_dur(self):
        t = copy.deepcopy(_valid_trace())
        del self._first(t, "X")["dur"]
        assert any("needs dur" in e for e in validate_trace(t))

    def test_decreasing_ts_on_track(self):
        t = copy.deepcopy(_valid_trace())
        self._first(t, "X")["ts"] = 99      # X sits first on its track
        assert any("decreases" in e for e in validate_trace(t))

    def test_flow_without_end(self):
        t = copy.deepcopy(_valid_trace())
        t["traceEvents"] = [ev for ev in t["traceEvents"]
                            if ev["ph"] != "f"]
        assert any("exactly one start" in e for e in validate_trace(t))

    def test_counter_without_numeric_args(self):
        t = copy.deepcopy(_valid_trace())
        self._first(t, "C")["args"] = {"v": "high"}
        assert any("counter args" in e for e in validate_trace(t))

    def test_unbalanced_be(self):
        t = copy.deepcopy(_valid_trace())
        t["traceEvents"].append({"ph": "B", "pid": 1, "tid": 1,
                                 "name": "open", "ts": 5,
                                 "clock": "cycles"})
        assert any("unclosed" in e for e in validate_trace(t))


# ---------------------------------------------------------------------------
# The shared scrubber
# ---------------------------------------------------------------------------


class TestScrub:
    def test_sweep_aliases_point_at_shared_sets(self):
        from repro.kvi.dse.sweep import VOLATILE_KEYS, scrub_volatile
        assert VOLATILE_KEYS is DSE_VOLATILE
        obj = {"wall_s": 1.0, "cycles": 5,
               "meta": {"executor": "thread", "n": 2}}
        assert scrub_volatile(obj) == scrub(obj, DSE_VOLATILE) == \
            {"cycles": 5, "meta": {"n": 2}}

    def test_serve_volatile_extends_dse(self):
        assert DSE_VOLATILE < SERVE_VOLATILE
        assert "req_per_s" in SERVE_VOLATILE

    def test_scrub_recurses_into_lists(self):
        obj = {"rows": [{"wall_s": 1, "d": 2}, {"cached": True, "d": 3}]}
        assert scrub(obj) == {"rows": [{"d": 2}, {"d": 3}]}


# ---------------------------------------------------------------------------
# Cyclesim trace integrity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_traced():
    obs = Obs.on()
    wl = KviWorkload.replicate(_small_prog(), 3)
    res = CycleSimBackend(obs=obs).run_workload(wl, functional=False)
    return obs, res


class TestCycleSimTrace:
    def test_trace_validates(self, sim_traced):
        obs, _ = sim_traced
        assert validate_trace(obs.tracer.to_chrome()) == []
        assert validate_metrics(obs.metrics.snapshot()) == []

    def test_spans_reproduce_hartstats_breakdown(self, sim_traced):
        """Per scheme per hart: emitted stall spans sum to
        ``stall_cycles``, idle spans to ``idle_cycles`` — so busy
        follows from the busy+stall+idle == total invariant."""
        obs, res = sim_traced
        trace = obs.tracer.to_chrome()
        names = _track_names(trace)
        sums = {}                       # (scheme, hart) -> {cat: cycles}
        for ev in trace["traceEvents"]:
            if ev["ph"] != "X":
                continue
            proc, lane = names[(ev["pid"], ev["tid"])]
            if not proc.startswith("cyclesim:") or \
                    not lane.startswith("hart"):
                continue
            key = (proc[len("cyclesim:"):], int(lane[4:]))
            d = sums.setdefault(key, {})
            d[ev["cat"]] = d.get(ev["cat"], 0) + ev["dur"]
            assert 0 <= ev["ts"] <= ev["ts"] + ev["dur"] <= \
                res.timing[key[0]].cycles
        assert sums, "no cyclesim hart spans emitted"
        for scheme, sim in res.timing.items():
            for h, st in enumerate(sim.per_hart):
                d = sums.get((scheme, h), {})
                assert d.get("stall", 0) == st.stall_cycles, (scheme, h)
                assert d.get("idle", 0) == st.idle_cycles, (scheme, h)

    def test_fu_hold_lanes_present(self, sim_traced):
        obs, _ = sim_traced
        names = _track_names(obs.tracer.to_chrome())
        assert any(lane.startswith("fu:") for _, lane in names.values())

    def test_metrics_match_simresult(self, sim_traced):
        obs, res = sim_traced
        snap = obs.metrics.snapshot()
        for scheme, sim in res.timing.items():
            assert snap["counters"][f"cyclesim.{scheme}.instructions"] \
                == sum(h.instructions for h in sim.per_hart)
            assert snap["gauges"][f"cyclesim.{scheme}.cycles"] \
                == sim.cycles

    def test_canonical_trace_deterministic(self):
        def once():
            obs = Obs.on()
            wl = KviWorkload.replicate(_small_prog(), 3)
            CycleSimBackend(obs=obs).run_workload(wl, functional=False)
            return json.dumps(canonical_trace(obs.tracer.to_chrome()),
                              sort_keys=True)
        assert once() == once()

    def test_disabled_path_allocates_nothing(self):
        wl = KviWorkload.replicate(_small_prog(), 3)
        CycleSimBackend(obs=NULL_OBS).run_workload(wl, functional=False)
        assert NULL_TRACER.events == []
        assert NULL_OBS.metrics.snapshot()["counters"] == {}

    def test_disabled_overhead_within_2pct(self):
        """obs=None (the pre-instrumentation path) vs obs=NULL_OBS (the
        disabled bundle): both skip the recorder entirely, so their
        runtimes must agree within the pinned 2% bound. Measured as the
        *minimum* of back-to-back paired ratios: ambient machine drift
        swings individual samples by far more than 2%, but a pair runs
        ~20 ms apart so drift hits both sides alike — and a genuine
        systematic overhead would shift every pair, including the
        minimum, past the bound. Each sample batches several
        run_workload calls so timer granularity stays negligible."""
        wl = KviWorkload.replicate(_small_prog(), 3)
        base = CycleSimBackend()
        nul = CycleSimBackend(obs=NULL_OBS)
        for b in (base, nul):                       # warm caches/JIT
            b.run_workload(wl, functional=False)

        def sample(backend, batch=10):
            t0 = time.perf_counter()
            for _ in range(batch):
                backend.run_workload(wl, functional=False)
            return time.perf_counter() - t0

        ratios = [sample(nul) / sample(base) for _ in range(15)]
        assert min(ratios) <= 1.02, ratios


# ---------------------------------------------------------------------------
# Serving telemetry: flows, view-vs-report, byte-identity
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def templates():
    return make_templates(SMOKE_MIX, smoke=True, seed=0)


@pytest.fixture(scope="module")
def specs(templates):
    return poisson_arrivals(templates, 24, 80.0, n_clients=40, seed=0)


@pytest.fixture(scope="module")
def served(templates, specs):
    obs = Obs.on()
    engine = ServeEngine(templates, n_harts=3, backend=None, seed=0,
                         obs=obs)
    report = engine.run(specs)
    return obs, report


class TestServingTelemetry:
    def test_trace_and_metrics_validate(self, served):
        obs, _ = served
        assert validate_trace(obs.tracer.to_chrome()) == []
        assert validate_metrics(obs.metrics.snapshot()) == []

    def test_view_reproduces_report(self, served, tmp_path):
        """The ISSUE acceptance: ``obs view`` recomputes makespan and
        latency percentiles from the flow events alone, matching the
        engine's report exactly."""
        obs, report = served
        path = tmp_path / "kvi_trace.json"
        obs.tracer.save(str(path))
        summary = view(str(path), out=lambda *_: None)
        assert summary["requests"] == \
            report["throughput"]["requests"]
        assert summary["makespan_cycles"] == \
            report["throughput"]["makespan_cycles"]
        for q in ("p50", "p95", "p99", "mean", "max"):
            assert summary["latency_cycles"][q] == \
                report["latency_cycles"][q], q

    def test_flow_summary_counts_every_request(self, served, specs):
        obs, _ = served
        flows = flow_summary(obs.tracer.to_chrome()["traceEvents"])
        assert flows["requests"] == len(specs)

    def test_scheduler_ticket_spans_present(self, served):
        obs, _ = served
        names = _track_names(obs.tracer.to_chrome())
        harts = {lane for proc, lane in names.values()
                 if proc == "scheduler"}
        assert {"hart0", "hart1", "hart2"} <= harts

    def test_latency_histogram_matches_report(self, served, specs):
        obs, report = served
        h = obs.metrics.snapshot()["histograms"]["serving.latency_cycles"]
        assert h["count"] == len(specs)
        assert h["p99"] == report["latency_cycles"]["p99"]

    def test_canonical_report_byte_identical_with_obs(self, templates,
                                                      specs, served):
        _, traced_report = served
        plain = ServeEngine(templates, n_harts=3, backend=None,
                            seed=0).run(specs)
        assert canonical_report(plain) == canonical_report(traced_report)

    def test_repeated_runs_keep_flow_ids_unique(self, templates, specs):
        obs = Obs.on()
        engine = ServeEngine(templates, n_harts=3, backend=None, seed=0,
                             obs=obs)
        engine.run(specs)
        engine.run(specs)
        assert validate_trace(obs.tracer.to_chrome()) == []
        flows = flow_summary(obs.tracer.to_chrome()["traceEvents"])
        assert flows["requests"] == 2 * len(specs)

    def test_stall_attribution_rows_sorted(self, sim_traced):
        obs, _ = sim_traced
        rows = stall_attribution(obs.tracer.to_chrome()["traceEvents"])
        durs = [d for _, d, _ in rows]
        assert durs == sorted(durs, reverse=True)


# ---------------------------------------------------------------------------
# DSE sweep telemetry, progress logging and SVG plots
# ---------------------------------------------------------------------------


TINY_SPACE = DesignSpace(lanes=(2, 8), precisions=(8,))


@pytest.fixture(scope="module")
def tiny_obs_sweep():
    obs = Obs.on()
    lines = []
    result = sweep(TINY_SPACE, _tiny_kernels, max_workers=1,
                   executor="serial", emit=lines.append, obs=obs,
                   progress_every=1)
    return obs, lines, result


class TestSweepTelemetry:
    def test_progress_lines_stream_per_point(self, tiny_obs_sweep):
        _, lines, result = tiny_obs_sweep
        prog = [ln for ln in lines if ln.startswith("progress ")]
        n = len(result.records)
        assert len(prog) == n
        assert f"{n}/{n} fresh points" in prog[-1]
        assert "pts/s" in prog[-1] and "eta" in prog[-1]

    def test_quiet_suppresses_progress(self):
        result = sweep(TINY_SPACE.points()[:1], _tiny_kernels,
                       max_workers=1, executor="serial", emit=None,
                       progress_every=1)
        assert result.records[0].ok

    def test_sweep_trace_and_metrics(self, tiny_obs_sweep):
        obs, _, result = tiny_obs_sweep
        trace = obs.tracer.to_chrome()
        assert validate_trace(trace) == []
        snap = obs.metrics.snapshot()
        assert validate_metrics(snap) == []
        assert snap["counters"]["dse.points"] == len(result.records)
        names = _track_names(trace)
        assert ("dse", "points") in names.values()

    def test_canonical_json_byte_identical_with_obs(self, tiny_obs_sweep):
        _, _, traced = tiny_obs_sweep
        plain = sweep(TINY_SPACE, _tiny_kernels, max_workers=1,
                      executor="serial")
        assert plain.canonical_json() == traced.canonical_json()


class TestSvgPlots:
    def test_line_chart_deterministic_svg(self):
        series = {"shared/8b": [(2, 1.0), (8, 3.1)],
                  "sym_mimd/8b": [(2, 1.0), (8, 3.9)]}
        svg = line_chart("t", "D", "speedup", series, log_x=True)
        assert svg.startswith("<svg")
        assert "shared/8b" in svg and "sym_mimd/8b" in svg
        assert svg == line_chart("t", "D", "speedup", series, log_x=True)

    def test_scatter_chart_with_front(self):
        svg = scatter_chart("t", "area", "cycles",
                            {"shared": [(10, 100), (20, 60)]},
                            front=[(10, 100), (20, 60)])
        assert "pareto front" in svg

    def test_write_plots_and_markdown_links(self, tiny_obs_sweep,
                                            tmp_path):
        from repro.kvi.dse.plots import write_plots
        _, _, result = tiny_obs_sweep
        report = build_report(result)
        plots = write_plots(result, report, str(tmp_path))
        assert plots, "no figures written"
        for kern, files in plots.items():
            for fname in files:
                body = (tmp_path / fname).read_text()
                assert body.startswith("<svg"), fname
        md = render_markdown(report, plots=plots)
        fname = next(iter(plots.values()))[0]
        assert f"]({fname})" in md
