"""Serving-engine tests: load generation, continuous admission,
signature batching, compiled-kernel reuse and the serving report.

The acceptance bar for the serving tentpole:
  * seeded arrival streams and instantiated request data are
    deterministic (and the canonical serving report byte-identical
    across runs),
  * continuous admission has no head-of-line blocking — a long matmul
    on one hart does not delay conv latencies on the others,
  * with prewarming, the serving loop itself never compiles: the
    kernel-cache steady-state hit rate is exactly 1.0,
  * batched execution is bit-identical to the scalar oracle and at
    least 2x faster (wall) than one-request-at-a-time dispatch.
"""
import numpy as np
import pytest

from repro.kvi.scheduler import HartScheduler
from repro.kvi.serving import (SMOKE_MIX, RequestSpec, ServeEngine,
                               bucket_sizes, canonical_report, load_trace,
                               make_templates, poisson_arrivals, save_trace,
                               template_key)
from repro.kvi.workload import structural_signature


@pytest.fixture(scope="module")
def templates():
    return make_templates(SMOKE_MIX, smoke=True, seed=0)


@pytest.fixture(scope="module")
def specs(templates):
    return poisson_arrivals(templates, 32, 80.0, n_clients=50, seed=0)


# ---------------------------------------------------------------------------
# Load generation
# ---------------------------------------------------------------------------


class TestLoad:
    def test_poisson_arrivals_deterministic(self, templates):
        a = poisson_arrivals(templates, 40, 50.0, seed=7)
        b = poisson_arrivals(templates, 40, 50.0, seed=7)
        assert a == b
        c = poisson_arrivals(templates, 40, 50.0, seed=8)
        assert a != c
        assert all(x.t <= y.t for x, y in zip(a, a[1:]))

    def test_template_instances_share_structure(self, templates):
        tpl = templates[template_key("conv", 4)]
        p1 = tpl.instantiate(seed=0, rid=1)
        p2 = tpl.instantiate(seed=0, rid=2)
        # same structural signature (batchable), different data
        assert structural_signature(p1) == structural_signature(p2)
        assert structural_signature(p1) == tpl.signature
        assert p1.items is tpl.program.items      # structure shared
        img = next(m for m in p1.mems if m.name in tpl.data_mems)
        assert not np.array_equal(p1.mem_init[img.id],
                                  p2.mem_init[img.id])

    def test_instantiate_deterministic_and_order_free(self, templates):
        tpl = templates[template_key("matmul", 2)]
        a = tpl.instantiate(seed=3, rid=5)
        b = tpl.instantiate(seed=3, rid=5)
        for m in tpl.program.mems:
            assert np.array_equal(a.mem_init[m.id], b.mem_init[m.id])

    def test_constants_and_outputs(self, templates):
        tpl = templates[template_key("conv", 4)]
        p = tpl.instantiate(seed=0, rid=9)
        for m in tpl.program.mems:
            if m.is_output:
                assert not p.mem_init[m.id].any()
            elif m.name not in tpl.data_mems:
                assert np.array_equal(p.mem_init[m.id],
                                      tpl.program.mem_init[m.id])

    def test_trace_roundtrip(self, templates, specs, tmp_path):
        path = str(tmp_path / "trace.json")
        save_trace(specs, path)
        assert load_trace(path) == sorted(specs, key=lambda s: s.t)

    def test_template_profile_nonzero(self, templates):
        for tpl in templates.values():
            assert tpl.est_cycles > 0
            assert tpl.profile["busy"] > 0


# ---------------------------------------------------------------------------
# Continuous admission (scheduler.admit)
# ---------------------------------------------------------------------------


class TestAdmit:
    def test_admit_earliest_finish_first(self, templates):
        sched = HartScheduler(n_harts=3, estimator=lambda p: 100)
        prog = templates[template_key("conv", 4)].program
        tickets = [sched.admit(prog, now=0) for _ in range(5)]
        assert [t.hart for t in tickets] == [0, 1, 2, 0, 1]
        assert [t.start_est for t in tickets] == [0, 0, 0, 100, 100]
        assert sched.hart_free == [200, 200, 100]

    def test_admit_respects_arrival_time(self):
        sched = HartScheduler(n_harts=2, estimator=lambda p: 10)
        t1 = sched.admit(None, now=0)
        t2 = sched.admit(None, now=50)    # machine idle until arrival
        assert t1.finish_est == 10
        assert t2.start_est == 50 and t2.finish_est == 60

    def test_no_head_of_line_blocking(self):
        # one long program occupies hart 0; short ones flow through the
        # other harts without queueing behind it
        ests = iter([10_000, 10, 10, 10, 10])
        sched = HartScheduler(n_harts=3,
                              estimator=lambda p: next(ests))
        long = sched.admit(None, now=0)
        shorts = [sched.admit(None, now=0) for _ in range(4)]
        assert long.hart == 0
        assert all(s.hart != 0 for s in shorts)
        assert max(s.finish_est for s in shorts) == 20


# ---------------------------------------------------------------------------
# Engine (schedule-only: no jax)
# ---------------------------------------------------------------------------


class TestEngineScheduleOnly:
    def test_bucket_sizes(self):
        assert bucket_sizes(13, 8) == [8, 4, 1]
        assert bucket_sizes(8, 8) == [8]
        assert bucket_sizes(3, 8) == [2, 1]
        assert bucket_sizes(5, 2) == [2, 2, 1]
        assert bucket_sizes(0, 8) == []
        assert sum(bucket_sizes(117, 16)) == 117

    def test_max_batch_must_be_power_of_two(self, templates):
        with pytest.raises(ValueError, match="power of two"):
            ServeEngine(templates, max_batch=6)

    def test_unknown_template_rejected(self, templates):
        eng = ServeEngine(templates, backend=None)
        with pytest.raises(KeyError, match="fft@64"):
            eng.run([RequestSpec(0, "fft", 8)])

    def test_report_deterministic(self, templates, specs):
        a = ServeEngine(templates, backend=None, seed=0).run(specs)
        b = ServeEngine(templates, backend=None, seed=0).run(specs)
        assert canonical_report(a) == canonical_report(b)

    def test_latency_and_throughput_fields(self, templates, specs):
        rep = ServeEngine(templates, backend=None, seed=0).run(specs)
        assert rep["throughput"]["requests"] == len(specs)
        lat = rep["latency_cycles"]
        assert 0 < lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
        # every request is accounted to exactly one template
        assert sum(v["n"] for v in rep["per_template"].values()) \
            == len(specs)
        # wave sizes partition the stream
        assert sum(int(k) * v for k, v in rep["wave_sizes"].items()) \
            == len(specs)

    def test_utilization_invariant(self, templates, specs):
        rep = ServeEngine(templates, backend=None, seed=0).run(specs)
        makespan = rep["throughput"]["makespan_cycles"]
        assert makespan > 0
        for h in rep["hart_utilization"]:
            assert h["busy"] + h["stall"] + h["idle"] == makespan
            assert 0.0 <= h["utilization"] <= 1.0

    def test_batching_flag_does_not_change_schedule(self, templates,
                                                    specs):
        # batching only changes wall execution; the virtual-time
        # schedule (latencies, utilization, waves) is identical
        a = ServeEngine(templates, backend=None, batching=True,
                        seed=0).run(specs)
        b = ServeEngine(templates, backend=None, batching=False,
                        seed=0).run(specs)
        for k in ("latency_cycles", "hart_utilization", "wave_sizes",
                  "throughput"):
            assert a[k] == b[k]
        assert b["batch_sizes"] == {"1": len(specs)}

    def test_conv_p99_unharmed_by_long_matmul(self, templates):
        # head-of-line regression gate: convs keep flowing while a
        # long-running matmul occupies one hart. Every conv must beat
        # the matmul's own completion — with 3 harts and one matmul in
        # front, queueing convs behind it would violate this wildly.
        conv = templates[template_key("conv", 4)]
        mm = templates[template_key("matmul", 2)]
        long_est = 50 * conv.est_cycles
        orig_profile = mm.profile
        mm.profile = dict(mm.profile, cycles=long_est)
        try:
            stream = [RequestSpec(0, "matmul", 2)] + [
                RequestSpec(1 + i, "conv", 4) for i in range(8)]
            rep = ServeEngine(templates, n_harts=3,
                              backend=None, seed=0).run(stream)
            conv_p99 = rep["per_template"][conv.name][
                "latency_cycles"]["p99"]
            assert conv_p99 < long_est
            # 8 convs over 2 remaining harts: 4 rounds of solo latency
            assert conv_p99 <= 4 * conv.est_cycles + 1
        finally:
            mm.profile = orig_profile

    def test_idle_machine_advances_to_next_arrival(self, templates):
        # widely spaced arrivals: each request is its own wave, latency
        # equals the solo estimate (no queueing at all)
        tpl = templates[template_key("conv", 4)]
        stream = [RequestSpec(i * 10 * tpl.est_cycles, "conv", 4)
                  for i in range(4)]
        rep = ServeEngine(templates, backend=None, seed=0).run(stream)
        assert rep["wave_sizes"] == {"1": 4}
        assert rep["latency_cycles"]["max"] == tpl.est_cycles


# ---------------------------------------------------------------------------
# Engine + Pallas backend (execution, cache, speedup)
# ---------------------------------------------------------------------------


class TestEnginePallas:
    @pytest.fixture(scope="class")
    def served(self, templates, specs):
        from repro.kvi.backend import get_backend
        backend = get_backend("pallas", passes=())
        engine = ServeEngine(templates, backend=backend, seed=0)
        report = engine.run(specs)
        return engine, backend, report

    @pytest.mark.slow
    def test_prewarm_means_zero_loop_compiles(self, served):
        _, _, rep = served
        cc = rep["compile_cache"]
        assert cc["loop_misses"] == 0
        assert cc["last_miss_step"] == -1
        assert cc["steady_hit_rate"] == 1.0
        assert cc["hits"] > 0

    @pytest.mark.slow
    def test_batch_sizes_capped_and_cover_stream(self, served, specs):
        engine, _, rep = served
        total = sum(int(k) * v for k, v in rep["batch_sizes"].items())
        assert total == len(specs)
        assert all(int(k) <= engine.max_batch
                   for k in rep["batch_sizes"])
        # power-of-two buckets only
        assert all(int(k) & (int(k) - 1) == 0
                   for k in rep["batch_sizes"])

    @pytest.mark.slow
    def test_outputs_match_oracle(self, templates):
        from repro.kvi.backend import get_backend
        from repro.kvi.workload import KviWorkload
        oracle = get_backend("oracle")
        pallas = get_backend("pallas", passes=())
        tpl = templates[template_key("conv", 4)]
        progs = [tpl.instantiate(seed=0, rid=100 + i) for i in range(4)]
        res = pallas.run_workload(KviWorkload.homogeneous(progs))
        for prog, got in zip(progs, res.entry_results):
            want = oracle.run(prog)
            for k in want.outputs:
                assert np.array_equal(want.outputs[k], got.outputs[k]), k

    @pytest.mark.slow
    def test_batching_speedup_pinned_2x(self, templates, specs):
        # the tentpole gate: signature batching at least doubles wall
        # throughput over one-request-at-a-time at steady state (both
        # sides prewarmed — this compares dispatch, not compilation)
        from repro.kvi.backend import get_backend

        def measure(batching):
            eng = ServeEngine(templates,
                              backend=get_backend("pallas", passes=()),
                              batching=batching, seed=0)
            return eng.run(specs)["throughput"]["execute_s"]

        batched_s = measure(True)
        unbatched_s = measure(False)
        assert unbatched_s >= 2.0 * batched_s, \
            f"batching speedup {unbatched_s / batched_s:.2f}x < 2x"


# ---------------------------------------------------------------------------
# KernelCache unit behaviour
# ---------------------------------------------------------------------------


class TestKernelCache:
    def test_hit_miss_counters(self):
        from repro.kvi.pallas_backend import KernelCache
        cache = KernelCache()
        built = []

        def build():
            built.append(1)
            return lambda: 42

        assert cache.get(("k", 1), build)() == 42
        assert cache.get(("k", 1), build)() == 42
        assert cache.get(("k", 2), build)() == 42
        assert cache.stats == {"hits": 1, "misses": 2, "entries": 2}
        assert len(built) == 2
        assert len(cache) == 2
        cache.clear()
        assert len(cache) == 0
        assert cache.stats["hits"] == 0

    @pytest.mark.slow
    def test_backend_reports_per_call_deltas(self):
        from repro.kvi.backend import get_backend
        from repro.kvi.serving import make_templates
        from repro.kvi.workload import KviWorkload
        tpls = make_templates((("conv", 4),), smoke=True, seed=0)
        tpl = next(iter(tpls.values()))
        progs = [tpl.instantiate(0, i) for i in range(2)]
        backend = get_backend("pallas", passes=())
        first = backend.run_workload(KviWorkload.homogeneous(progs))
        again = backend.run_workload(KviWorkload.homogeneous(progs))
        assert first.meta["compile_cache"]["misses"] > 0
        assert again.meta["compile_cache"]["misses"] == 0
        assert again.meta["compile_cache"]["hits"] > 0
