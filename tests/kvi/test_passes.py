"""Pass-pipeline tests: liveness, dce, copy_prop, fusion planning,
liveness-based SPM allocation, the chaining discount, and the
differential fuzz bar.

The acceptance criteria for the optimizing-pass refactor:
  * every pass combination x {oracle, cyclesim, pallas} produces
    bit-identical outputs to the UNOPTIMIZED oracle (fuzzed),
  * a program whose total vreg footprint exceeds the SPM but whose
    peak-live footprint fits lowers and runs on all three backends,
  * genuine overflow raises SpmOverflowError naming the program, its
    peak-live bytes and the capacity,
  * with the pipeline on, at least one backend gets measurably cheaper
    (fewer pallas_calls / fewer cycles) at identical outputs.
"""
import itertools

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.base import KlessydraConfig
from repro.kvi import (KviProgramBuilder, KviWorkload, SpmOverflowError,
                       get_backend, optimize_program)
from repro.kvi.cyclesim import CycleSimBackend, default_schemes
from repro.kvi.lowering import allocate_vregs, lower
from repro.kvi.passes import (DEFAULT_PASSES, PassPipeline, copy_prop, dce,
                              default_pipeline, fuse_regions,
                              observable_items, peak_live_bytes,
                              plan_fusion_regions, reg_intervals,
                              total_vreg_bytes)
from repro.kvi.programs import (conv2d_program, pipeline_demo_oracle,
                                pipeline_demo_program)

BACKENDS = ("oracle", "cyclesim", "pallas")


def _saxpy(n=16, scalar=3, seed=0):
    x = np.random.default_rng(seed).integers(-100, 100, n).astype(np.int32)
    b = KviProgramBuilder("saxpy")
    v = b.vreg("v", n)
    b.kmemld(v, b.mem_in("x", x))
    b.ksvmulsc(v, v, scalar=scalar)
    b.krelu(v, v)
    b.kmemstr(b.mem_out("y", n), v)
    return b.build(), np.maximum(x * scalar, 0).astype(np.int32)


class TestLiveness:
    def test_reg_intervals_and_peak(self):
        n = 8
        b = KviProgramBuilder("seq")
        hx = b.mem_in("x", np.arange(n, dtype=np.int32))
        a = b.vreg("a", n)
        c = b.vreg("c", n)
        b.kmemld(a, hx)                       # item 0: a born
        b.ksvaddsc(a, a, scalar=1)            # item 1
        b.kvcp(c, a)                          # item 2: a dies, c born
        b.ksvmulsc(c, c, scalar=2)            # item 3
        b.kmemstr(b.mem_out("y", n), c)       # item 4: c dies
        p = b.build()
        iv = reg_intervals(p)
        assert iv[a.id] == (0, 2)
        assert iv[c.id] == (2, 4)
        # a and c overlap only at item 2 -> peak is both alive once
        assert peak_live_bytes(p, align=4) == 2 * n * 4
        assert total_vreg_bytes(p, align=4) == 2 * n * 4

    def test_observable_items_flags_dead_tail(self):
        p, _ = _saxpy()
        b = KviProgramBuilder("dead_tail")
        hx = b.mem_in("x", np.arange(8, dtype=np.int32))
        v = b.vreg("v", 8)
        d = b.vreg("d", 8)
        b.kmemld(v, hx)
        b.kaddv(d, v, v)                      # dead: d never observed
        b.kmemstr(b.mem_out("y", 8), v)
        prog = b.build()
        assert observable_items(p) == [True] * len(p.items)
        flags = observable_items(prog)
        assert flags == [True, False, True]


class TestDce:
    def test_drops_dead_instrs_and_vregs(self):
        rng = np.random.default_rng(1)
        x = rng.integers(-100, 100, 16).astype(np.int32)
        p = pipeline_demo_program(x, stages=3)
        after_cp = copy_prop(p)
        opt = dce(after_cp)
        # 3 dead kvmul products + 3 bypassed kvcp moves are gone
        assert opt.n_instructions == p.n_instructions - 6
        # dead/stranded vregs removed and survivors renumbered densely
        assert len(opt.vregs) < len(p.vregs)
        assert [r.id for r in opt.vregs] == list(range(len(opt.vregs)))
        out = get_backend("oracle", passes=()).run(opt).outputs["y"]
        assert np.array_equal(out, pipeline_demo_oracle(x, 3))

    def test_store_to_scratch_buffer_is_dead_unless_reloaded(self):
        def build(reload_it):
            b = KviProgramBuilder("scratch")
            n = 8
            hx = b.mem_in("x", np.arange(n, dtype=np.int32))
            hs = b.mem_in("scratch", np.zeros(n, np.int32))
            v = b.vreg("v", n)
            b.kmemld(v, hx)
            b.ksvaddsc(v, v, scalar=5)
            b.kmemstr(hs, v)                  # store to non-output buffer
            if reload_it:
                w = b.vreg("w", n)
                b.kmemld(w, hs)
                b.kmemstr(b.mem_out("y", n), w)
            else:
                b.kmemstr(b.mem_out("y", n), v)
            return b.build()

        dead = dce(build(reload_it=False))
        live = dce(build(reload_it=True))
        assert dead.n_instructions == build(False).n_instructions - 1
        assert live.n_instructions == build(True).n_instructions
        want = np.arange(8, dtype=np.int32) + 5
        for prog in (dead, live):
            out = get_backend("oracle", passes=()).run(prog).outputs["y"]
            assert np.array_equal(out, want)

    def test_noop_returns_same_object(self):
        p, _ = _saxpy()
        assert dce(p) is p

    def test_partial_kmemld_does_not_kill_prior_writes(self):
        """Regression: a kmemld into a sub-window writes exactly the
        buffer's elements — liveness must not treat it as a full-register
        def (which would let dce drop an earlier write to the rest of
        the register). The builder also rejects a declared length that
        overstates the buffer."""
        n = 8
        b = KviProgramBuilder("partial_ld")
        hw = b.mem_in("w", np.full(4, 9, np.int32))
        hx = b.mem_in("x", np.array([1, 2, 3, 4], np.int32))
        w = b.vreg("w", 4)
        v = b.vreg("v", n)
        b.kmemld(w, hw)
        b.kvcp(v.view(4, 4), w)          # writes v[4:8]
        b.kmemld(v.view(0, 4), hx)       # writes v[0:4] ONLY
        b.kaddv(v, v, v)
        b.kmemstr(b.mem_out("y", n), v)
        prog = b.build()
        assert observable_items(prog) == [True] * len(prog.items)
        want = np.array([2, 4, 6, 8, 18, 18, 18, 18], np.int32)
        for name in BACKENDS:
            for passes in ((), None):
                out = get_backend(name, passes=passes).run(prog)
                assert np.array_equal(out.outputs["y"], want), \
                    (name, passes)
        # overstating the transfer length is rejected at build time
        with pytest.raises(ValueError, match="exceeds buffer"):
            b2 = KviProgramBuilder("bad")
            h = b2.mem_in("b4", np.arange(4, dtype=np.int32))
            r = b2.vreg("r", n)
            b2.kmemld(r, h, length=n)


class TestCopyProp:
    def test_full_register_copy_chain_bypassed(self):
        rng = np.random.default_rng(2)
        x = rng.integers(-100, 100, 16).astype(np.int32)
        p = pipeline_demo_program(x, stages=4)
        opt = optimize_program(p)
        # no kvcp survives the full pipeline; one maximal fused region
        assert all(i.op.value != "kvcp" for i in opt.items
                   if hasattr(i, "op"))
        plan = opt.meta["fused_regions"]
        assert len(plan.regions) == 1
        out = get_backend("oracle", passes=()).run(opt).outputs["y"]
        assert np.array_equal(out, pipeline_demo_oracle(x, 4))

    def test_partial_copies_untouched(self):
        # bit-reversal-style single-element moves must survive
        n = 8
        b = KviProgramBuilder("partial")
        hx = b.mem_in("x", np.arange(n, dtype=np.int32))
        v = b.vreg("v", n)
        o = b.vreg("o", n)
        b.kmemld(v, hx)
        for i in range(n):
            b.kvcp(o[n - 1 - i], v[i])
        b.kmemstr(b.mem_out("y", n), o)
        p = b.build()
        assert copy_prop(p) is p
        out = get_backend("pallas").run(p).outputs["y"]
        assert np.array_equal(out, np.arange(n, dtype=np.int32)[::-1])

    def test_pallas_call_count_drops(self):
        from repro.kvi.pallas_backend import PallasBackend
        x = np.arange(-32, 32, dtype=np.int32)
        p = pipeline_demo_program(x, stages=4)
        off = PallasBackend(passes=())
        r_off = off.run(p)
        on = PallasBackend()
        r_on = on.run(p)
        assert np.array_equal(r_off.outputs["y"], r_on.outputs["y"])
        assert on.fused_calls < off.fused_calls
        assert on.fused_calls == 1


class TestFusionPlan:
    def test_single_region_covers_chain(self):
        p, _ = _saxpy()
        plan = plan_fusion_regions(p)
        assert len(plan.regions) == 1
        r = plan.regions[0]
        assert [p.items[i].op.value for i in r.items] == \
            ["ksvmulsc", "krelu"]
        assert r.n_slots == 1            # in-place chain: one window

    def test_overlap_hazard_splits_region(self):
        n = 8
        b = KviProgramBuilder("hazard")
        hx = b.mem_in("x", np.arange(2 * n, dtype=np.int32))
        v = b.vreg("v", 2 * n)
        b.kmemld(v, hx)
        b.ksvaddsc(v[:n], v[:n], scalar=1)
        # reads a window overlapping the pending write -> new region
        b.ksvmulsc(v[n // 2:n // 2 + n], v[n // 2:n // 2 + n], scalar=2)
        b.kmemstr(b.mem_out("y", 2 * n), v)
        plan = plan_fusion_regions(b.build())
        assert len(plan.regions) == 2

    def test_max_ops_bound_respected(self):
        n = 8
        b = KviProgramBuilder("long")
        hx = b.mem_in("x", np.arange(n, dtype=np.int32))
        v = b.vreg("v", n)
        b.kmemld(v, hx)
        for _ in range(10):
            b.ksvaddsc(v, v, scalar=1)
        b.kmemstr(b.mem_out("y", n), v)
        plan = plan_fusion_regions(b.build(), max_ops=4)
        assert [len(r.ops) for r in plan.regions] == [4, 4, 2]
        assert plan.max_ops == 4


def _oversubscribed_program(n_stages=8, n=256):
    """Total vreg footprint n_stages x n x 4 B; peak-live footprint ONE
    stage (each stage's register dies before the next is born)."""
    b = KviProgramBuilder("oversubscribed")
    rng = np.random.default_rng(7)
    want = {}
    for s in range(n_stages):
        x = rng.integers(-1000, 1000, n).astype(np.int32)
        h = b.mem_in(f"x{s}", x)
        r = b.vreg(f"r{s}", n)
        b.kmemld(r, h)
        b.ksvaddsc(r, r, scalar=s)
        b.kmemstr(b.mem_out(f"y{s}", n), r)
        want[f"y{s}"] = x + s
    return b.build(), want


class TestSpmAllocation:
    # 4 SPMs x 1 KiB = 4096 B capacity; line = D*4 = 16 B
    CFG = KlessydraConfig("tiny", M=1, F=1, D=4, spm_kbytes=1)

    def test_peak_live_fits_but_total_does_not(self):
        prog, want = _oversubscribed_program()
        cap = self.CFG.N * self.CFG.spm_kbytes * 1024
        assert total_vreg_bytes(prog, 16) == 8 * 1024 > cap
        assert peak_live_bytes(prog, 16) == 1024 <= cap
        trace = lower(prog, self.CFG)
        # dead registers' lines are reused: all eight live at address 0
        assert set(trace.vreg_addr.values()) == {0}
        out = trace.execute()
        for k, v in want.items():
            assert np.array_equal(out[k], v), k

    def test_runs_on_all_three_backends(self):
        prog, want = _oversubscribed_program()
        schemes = default_schemes(D=4, spm_kbytes=1)
        results = {
            "oracle": get_backend("oracle").run(prog),
            "cyclesim": CycleSimBackend(schemes=schemes).run(prog),
            "pallas": get_backend("pallas").run(prog),
        }
        for name, res in results.items():
            for k, v in want.items():
                assert np.array_equal(res.outputs[k], v), (name, k)

    def test_overlapping_lives_do_not_share_lines(self):
        n = 64
        b = KviProgramBuilder("overlap")
        hx = b.mem_in("x", np.arange(n, dtype=np.int32))
        a = b.vreg("a", n)
        c = b.vreg("c", n)
        b.kmemld(a, hx)
        b.kvcp(c, a)
        b.kaddv(c, c, a)                 # a and c simultaneously live
        b.kmemstr(b.mem_out("y", n), c)
        addr = allocate_vregs(b.build(), self.CFG)
        assert addr[a.id] != addr[c.id]

    def test_uninitialized_read_sees_zeros_on_every_backend(self):
        """Regression: a register read before any write must NOT inherit
        another register's recycled SPM lines — every backend agrees its
        elements are zeros (the pre-reuse semantics)."""
        n = 64
        b = KviProgramBuilder("uninit")
        hx = b.mem_in("x", np.arange(1, n + 1, dtype=np.int32))
        a = b.vreg("a", n)
        u = b.vreg("u", n)               # never written
        b.kmemld(a, hx)
        b.kmemstr(b.mem_out("y1", n), a)  # a dies here
        b.kmemstr(b.mem_out("y2", n), u)  # u born as a raw READ
        prog = b.build()
        iv = reg_intervals(prog, pin_uninitialized=True)
        assert iv[u.id][0] == 0          # pinned: cannot reuse a's lines
        addr = allocate_vregs(prog, self.CFG)
        assert addr[a.id] != addr[u.id]
        for name in BACKENDS:
            res = get_backend(name, passes=()).run(prog)
            assert np.array_equal(res.outputs["y2"],
                                  np.zeros(n, np.int32)), name

    def test_partial_first_write_pins_register(self):
        # writing one element then reading the whole register must not
        # expose recycled bytes in the untouched elements
        n = 16
        b = KviProgramBuilder("partial_first")
        hx = b.mem_in("x", np.full(n, 7, np.int32))
        a = b.vreg("a", n)
        p = b.vreg("p", n)
        b.kmemld(a, hx)
        b.kvred(p[0], a)                 # p born by a 1-element write
        b.kmemstr(b.mem_out("y", n), p)
        prog = b.build()
        iv = reg_intervals(prog, pin_uninitialized=True)
        assert iv[p.id][0] == 0
        want = np.zeros(n, np.int32)
        want[0] = 7 * n
        for name in BACKENDS:
            out = get_backend(name, passes=()).run(prog).outputs["y"]
            assert np.array_equal(out, want), name

    def test_overflow_raises_dedicated_error(self):
        n = 600                          # 2400 B each; two live > 4096 B
        b = KviProgramBuilder("too_big")
        hx = b.mem_in("x", np.arange(n, dtype=np.int32))
        a = b.vreg("a", n)
        c = b.vreg("c", n)
        b.kmemld(a, hx)
        b.kvcp(c, a)
        b.kaddv(c, c, a)
        b.kmemstr(b.mem_out("y", n), c)
        prog = b.build()
        with pytest.raises(SpmOverflowError) as ei:
            lower(prog, self.CFG)
        err = ei.value
        assert err.program_name == "too_big"
        assert err.peak_live_bytes == 2 * 2400
        assert err.capacity_bytes == 4096
        for needle in ("too_big", "4800", "4096"):
            assert needle in str(err)
        # the same error surfaces through the backend protocol
        with pytest.raises(SpmOverflowError):
            CycleSimBackend(
                schemes={"tiny": self.CFG}).run(prog)


class TestChainingDiscount:
    def test_chaining_reduces_cycles_preserves_semantics(self, rng):
        img = rng.integers(-128, 128, (8, 8)).astype(np.int32)
        filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
        prog = conv2d_program(img, filt, shift=4)
        off = CycleSimBackend().run(prog)
        on = CycleSimBackend(chaining=True).run(prog)
        for k in off.outputs:
            assert np.array_equal(off.outputs[k], on.outputs[k])
        assert all(on.cycles[s] < off.cycles[s] for s in off.cycles)
        c = on.cycles
        assert c["sym_mimd"] <= c["het_mimd"] <= c["shared"], c

    def test_chaining_needs_fusion_plan(self):
        p, want = _saxpy()
        off = CycleSimBackend(passes=()).run(p)
        on_no_plan = CycleSimBackend(passes=(), chaining=True).run(p)
        assert on_no_plan.cycles == off.cycles
        assert np.array_equal(on_no_plan.outputs["y"], want)


class TestPipelineApi:
    def test_escape_hatch_and_specs(self):
        p, want = _saxpy()
        assert not PassPipeline.from_spec(())
        assert PassPipeline.from_spec(None).names == DEFAULT_PASSES
        assert PassPipeline.from_spec("dce").run(p) is p
        with pytest.raises(KeyError, match="unknown pass"):
            PassPipeline.from_spec(("nope",))
        out = get_backend("oracle", passes=("copy_prop", dce)).run(p)
        assert np.array_equal(out.outputs["y"], want)

    def test_item_rewriting_passes_invalidate_stale_plan(self):
        """Regression: fuse_regions BEFORE copy_prop/dce must not leave a
        stale plan (shifted item indices, remapped vreg ids) for the
        Pallas backend to execute."""
        x = np.arange(-16, 16, dtype=np.int32)
        p = pipeline_demo_program(x, stages=3)
        weird = ("fuse_regions", "copy_prop", "dce")
        opt = optimize_program(p, weird)
        assert "fused_regions" not in opt.meta
        for name in BACKENDS:
            out = get_backend(name, passes=weird).run(p).outputs["y"]
            assert np.array_equal(out, pipeline_demo_oracle(x, 3)), name

    def test_workload_keeps_shared_program_objects(self):
        p, _ = _saxpy()
        wl = KviWorkload.replicate(p, 3)
        opt = get_backend("oracle").optimize_workload(wl)
        assert len({id(e.program) for e in opt.entries}) == 1

    def test_default_pipeline_attaches_plan_only_when_fusable(self):
        p, _ = _saxpy()
        opt = default_pipeline().run(p)
        assert "fused_regions" in opt.meta
        b = KviProgramBuilder("memonly")
        hx = b.mem_in("x", np.arange(4, dtype=np.int32))
        v = b.vreg("v", 4)
        b.kmemld(v, hx)
        b.kmemstr(b.mem_out("y", 4), v)
        memonly = b.build()
        assert default_pipeline().run(memonly) is memonly


# ---------------------------------------------------------------------------
# Differential fuzz: random programs, every pass combination, every
# backend, one ground truth — the unoptimized oracle.
# ---------------------------------------------------------------------------

PASS_COMBOS = [c for k in range(4)
               for c in itertools.combinations(DEFAULT_PASSES, k)]

EW = ["kaddv", "ksubv", "kvmul", "ksvaddsc", "ksvmulsc", "ksrav",
      "krelu", "kvslt", "ksvslt", "kvcp", "kvred"]

rand_op = st.tuples(st.sampled_from(EW), st.integers(0, 3),
                    st.integers(0, 3), st.integers(0, 12))


def _random_program(ops, seed, n=8):
    """Straight-line program over 4 vregs with full-reg kvcp moves (for
    copy_prop), reductions (rf_store spills), and only half the regs
    stored (dead code for dce). Outputs o0/o1 are the observable truth."""
    rng = np.random.default_rng(seed)
    b = KviProgramBuilder("fuzz")
    regs = []
    for i in range(4):
        h = b.mem_in(f"x{i}", rng.integers(-1000, 1000, n).astype(np.int32))
        r = b.vreg(f"v{i}", n)
        b.kmemld(r, h)
        regs.append(r)
    for op, d, s, imm in ops:
        dst, src = regs[d], regs[s]
        if op in ("kaddv", "ksubv", "kvmul", "kvslt"):
            getattr(b, op)(dst, src, regs[(s + 1) % 4])
        elif op == "kvcp":
            b.kvcp(dst, src)
        elif op == "krelu":
            b.krelu(dst, src)
        elif op == "kvred":
            b.kvred(dst[imm % n], src)
        else:
            getattr(b, op)(dst, src, scalar=imm)
    for i in range(2):                   # regs 2/3 stay unobserved
        b.kmemstr(b.mem_out(f"o{i}", n), regs[i])
    return b.build()


@given(st.lists(rand_op, min_size=1, max_size=10),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_fuzz_every_pass_combo_every_backend(ops, seed):
    prog = _random_program(ops, seed)
    truth = get_backend("oracle", passes=()).run(prog).outputs
    for combo in PASS_COMBOS:
        for name in BACKENDS:
            res = get_backend(name, passes=combo).run(prog)
            for o in truth:
                assert np.array_equal(res.outputs[o], truth[o]), \
                    (name, combo, o)
