"""Budget-constrained auto-tuner tests: front-recovery metric,
ε-relaxed layer peeling, the feasible-candidate sampler, the three
strategies' acceptance gates on the smoke space (full exhaustive-front
recovery under half the grid's sims), warm-cache zero-simulation
re-search, seeded byte-determinism, and the >=5000-point synthetic
space returning a budget-feasible best with per-rung accounting."""
import json
import random

import numpy as np
import pytest

from repro.kvi.dse import (DesignPoint, DesignSpace, PointCache,
                           SpaceConstraints, hardware_cost, pareto_front)
from repro.kvi.dse.search import (STRATEGIES, CandidateSampler,
                                  front_recovery, run_search)
from repro.kvi.dse.search.evaluator import LowFidScore
from repro.kvi.dse.search.strategies import eps_peel
from repro.kvi.programs import conv2d_program, matmul_program

# ---------------------------------------------------------------------------
# front_recovery: the acceptance metric
# ---------------------------------------------------------------------------


class TestFrontRecovery:
    REF = [(100.0, 50.0, 10.0), (120.0, 40.0, 12.0)]

    def test_exact_match_is_full_recovery(self):
        assert front_recovery(list(self.REF), self.REF) == 1.0

    def test_empty_reference_is_vacuously_recovered(self):
        assert front_recovery([(1.0, 2.0, 3.0)], []) == 1.0

    def test_missing_member_is_fractional(self):
        assert front_recovery([self.REF[0]], self.REF) == 0.5
        assert front_recovery([], self.REF) == 0.0

    def test_relative_tolerance_absorbs_float_noise(self):
        wiggled = [(c * (1 + 1e-9), a, e) for c, a, e in self.REF]
        assert front_recovery(wiggled, self.REF) == 1.0
        off = [(c * 1.01, a, e) for c, a, e in self.REF]
        assert front_recovery(off, self.REF) == 0.0

    def test_duplicate_reference_metrics_count_once(self):
        # two distinct configs landing on identical metrics are ONE
        # front member for recovery purposes (tie tolerance)
        ref = [self.REF[0], self.REF[0], self.REF[1]]
        assert front_recovery([self.REF[0]], ref) == 0.5

    def test_extra_found_points_never_hurt(self):
        found = list(self.REF) + [(999.0, 999.0, 999.0)]
        assert front_recovery(found, self.REF) == 1.0


# ---------------------------------------------------------------------------
# ε-relaxed layer peeling
# ---------------------------------------------------------------------------


def _scores(objs, feasible=None):
    """LowFidScore fixtures over distinct real points (names matter
    only for deterministic sort order)."""
    pts = DesignSpace().points()
    assert len(objs) <= len(pts)
    out = []
    for i, obj in enumerate(objs):
        ok = feasible[i] if feasible is not None else True
        out.append(LowFidScore(pts[i], ok,
                               objectives=tuple(obj) if ok else None,
                               reason=None if ok else "infeasible"))
    return out


class TestEpsPeel:
    def test_layers_partition_feasible_and_drop_infeasible(self):
        scores = _scores([(10, 5, 1), (11, 5, 1), (20, 4, 2),
                          (30, 6, 3), (9, 9, 9)],
                         feasible=[True, True, True, True, False])
        layers = eps_peel(scores, eps=0.05)
        names = [s.point.name for layer in layers for s in layer]
        feas = [s.point.name for s in scores if s.feasible]
        assert sorted(names) == sorted(feas)      # partition: no loss,
        assert len(names) == len(set(names))      # no duplication

    def test_layer0_contains_exact_front(self):
        # ε-relaxation only ever ADDS near-ties to the first layer
        rng = random.Random(3)
        objs = [(rng.uniform(10, 100), rng.uniform(10, 100),
                 rng.uniform(10, 100)) for _ in range(24)]
        scores = _scores(objs)
        exact = {s.point.name
                 for s in eps_peel(scores, eps=0.0)[0]}
        relaxed = {s.point.name
                   for s in eps_peel(scores, eps=0.05)[0]}
        assert exact <= relaxed

    def test_near_tie_within_eps_survives_layer0(self):
        # b is 1% worse on both estimated axes with equal exact area:
        # inside the 2% error band, so it must not be culled analytically
        scores = _scores([(100.0, 50.0, 10.0), (101.0, 50.0, 10.1),
                          (200.0, 50.0, 20.0)])
        layer0 = {s.point.name for s in eps_peel(scores, eps=0.02)[0]}
        assert {scores[0].point.name, scores[1].point.name} <= layer0
        assert scores[2].point.name not in layer0

    def test_exact_area_gates_domination(self):
        # area is exact at low fidelity: beating a candidate by the
        # error margin on both estimated axes culls it only when the
        # dominator's area is no worse...
        culled = _scores([(100.0, 50.0, 10.0), (200.0, 50.0, 20.0)])
        layer0 = eps_peel(culled, eps=0.02)[0]
        assert [s.point.name for s in layer0] == [culled[0].point.name]
        # ...a larger-area dominator keeps the candidate alive, however
        # lopsided the estimates (it's a genuine area/speed trade-off)
        kept = _scores([(100.0, 51.0, 10.0), (200.0, 50.0, 20.0)])
        layer0 = eps_peel(kept, eps=0.02)[0]
        assert len(layer0) == 2

    def test_layers_sorted_deterministically(self):
        scores = _scores([(20, 4, 2), (10, 5, 1), (10, 5, 1)])
        layers = eps_peel(scores, eps=0.0)
        for layer in layers:
            keys = [(s.objectives[0], s.objectives[1], s.point.name)
                    for s in layer]
            assert keys == sorted(keys)


# ---------------------------------------------------------------------------
# CandidateSampler
# ---------------------------------------------------------------------------


class TestCandidateSampler:
    SPACE = DesignSpace()                     # 36 points

    def test_draws_are_distinct_and_in_space(self):
        s = CandidateSampler(self.SPACE, rng=random.Random(0))
        pts = s.draw(20)
        names = [p.name for p in pts]
        assert len(names) == 20
        assert len(set(names)) == 20
        grid_names = {p.name for p in self.SPACE.points()}
        assert set(names) <= grid_names

    def test_overdraw_exhausts_the_feasible_grid_exactly_once(self):
        s = CandidateSampler(self.SPACE, rng=random.Random(1))
        pts = s.draw(500)
        assert len(pts) == self.SPACE.grid_size
        assert s.draw(10) == []               # nothing left
        assert s.stats["distinct_points"] == self.SPACE.grid_size

    def test_constraints_respected_and_counted(self):
        cons = SpaceConstraints(schemes=("het_mimd",), max_lanes=8)
        s = CandidateSampler(self.SPACE, constraints=cons,
                             rng=random.Random(2))
        pts = s.draw(100)
        assert pts and all(p.scheme == "het_mimd" and p.D <= 8
                           for p in pts)
        expect = [p for p in self.SPACE.points()
                  if cons.feasible(p)]
        assert len(pts) == len(expect)
        assert s.stats["rejections"] > 0

    def test_same_seed_same_sequence(self):
        a = CandidateSampler(self.SPACE, rng=random.Random(7)).draw(36)
        b = CandidateSampler(self.SPACE, rng=random.Random(7)).draw(36)
        assert [p.name for p in a] == [p.name for p in b]

    def test_mutate_moves_one_axis_and_stays_feasible(self):
        cons = SpaceConstraints(max_lanes=8)
        s = CandidateSampler(self.SPACE, constraints=cons,
                             rng=random.Random(5))
        parent = DesignPoint(scheme="sym_mimd", M=3, F=3, D=4,
                             precision_bits=16)
        for _ in range(30):
            child = s.mutate(parent)
            assert child is not None
            assert child.name != parent.name
            assert cons.feasible(child)
            # a scheme move re-draws the coupled (M, F) pair; any other
            # move changes exactly one independent axis
            diffs = sum((child.scheme != parent.scheme,
                         (child.M, child.F) != (parent.M, parent.F),
                         child.D != parent.D,
                         child.precision_bits != parent.precision_bits,
                         child.spm_kbytes != parent.spm_kbytes,
                         child.chaining != parent.chaining,
                         child.passes != parent.passes,
                         child.fu_counts != parent.fu_counts))
            if child.scheme != parent.scheme:
                assert diffs <= 3             # scheme + (M,F) + fu
            else:
                assert diffs == 1

    def test_crossover_yields_valid_feasible_child(self):
        s = CandidateSampler(self.SPACE, rng=random.Random(9))
        a = DesignPoint(scheme="het_mimd", M=3, F=1, D=2,
                        precision_bits=8)
        b = DesignPoint(scheme="shared", M=1, F=1, D=16,
                        precision_bits=32)
        got_child = False
        for _ in range(20):
            child = s.crossover(a, b)
            if child is None:
                continue
            got_child = True
            assert child.name not in (a.name, b.name)
            # scheme-coupled fields travel together (the child must be
            # a VALID DesignPoint, constructed without ValueError)
            assert child.scheme in ("het_mimd", "shared")
            assert child.D in (2, 16)
            assert child.precision_bits in (8, 32)
        assert got_child


# ---------------------------------------------------------------------------
# Strategy acceptance gates on the smoke space (serial, shared cache)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shared_cache_dir(tmp_path_factory):
    """One persistent point-cache dir for every search in this module:
    the first test pays the 36 cold smoke sims, everything after runs
    from the store — exactly the re-search economics being tested."""
    return str(tmp_path_factory.mktemp("search-point-cache"))


def smoke_search(strategy, seed, cache_dir, **kw):
    kw.setdefault("compare_exhaustive", True)
    return run_search(strategy=strategy, smoke=True, seed=seed,
                      executor="serial",
                      cache=PointCache(cache_dir=cache_dir),
                      emit=None, **kw)


class TestStrategiesOnSmokeSpace:
    @pytest.mark.parametrize("strategy", sorted(STRATEGIES))
    def test_recovers_exhaustive_front_within_half_budget(
            self, strategy, shared_cache_dir):
        res = smoke_search(strategy, seed=0, cache_dir=shared_cache_dir)
        rec = res.meta["recovery"]
        # full tie-tolerant Pareto-front recovery...
        assert rec["front_recovery"] == 1.0, rec
        # ...with at most half the exhaustive grid's cycle-accurate
        # evaluations (the persistent-cache-independent count)
        assert res.evaluations["high_evals"] \
            <= 0.5 * res.meta["grid_size"]
        assert res.exhaustive_fraction <= 0.5

    @pytest.mark.parametrize("strategy", sorted(STRATEGIES))
    def test_rungs_account_every_fidelity(self, strategy,
                                          shared_cache_dir):
        res = smoke_search(strategy, seed=0, cache_dir=shared_cache_dir)
        assert res.rungs
        for rung in res.rungs:
            assert {"rung", "requested",
                    "high_evals", "low_evals"} <= set(rung)
        # cumulative counters are monotone and end at the totals
        highs = [r["high_evals"] for r in res.rungs]
        assert highs == sorted(highs)
        assert highs[-1] == res.evaluations["high_evals"]

    @pytest.mark.parametrize("strategy", sorted(STRATEGIES))
    def test_trajectory_best_is_monotone_nonincreasing(
            self, strategy, shared_cache_dir):
        res = smoke_search(strategy, seed=0, cache_dir=shared_cache_dir)
        best = [t["best_mix_cycles"] for t in res.trajectory
                if t["best_mix_cycles"] is not None]
        assert best, res.trajectory
        assert all(b <= a for a, b in zip(best, best[1:]))
        assert res.best is not None and res.best.ok

    def test_search_front_is_confirmed_pareto_consistent(
            self, shared_cache_dir):
        res = smoke_search("successive_halving", seed=0,
                           cache_dir=shared_cache_dir)
        # the reported front must be non-dominated within itself under
        # the high-fidelity metrics recorded in the report
        metrics = [tuple(res.meta["front_metrics"][r.point.name])
                   for r in res.front]
        assert len(pareto_front(metrics)) == len(metrics)

    def test_warm_research_does_zero_cyclesim_work(
            self, shared_cache_dir):
        first = smoke_search("successive_halving", seed=0,
                             cache_dir=shared_cache_dir,
                             compare_exhaustive=False)
        again = smoke_search("successive_halving", seed=0,
                             cache_dir=shared_cache_dir,
                             compare_exhaustive=False)
        # identical (space, strategy, seed, budget) -> every confirmed
        # point served from the persistent store: no fresh simulations,
        # every per-rung cache round pure hits
        assert again.evaluations["fresh_evals"] == 0
        assert again.evaluations["high_evals"] \
            == first.evaluations["high_evals"] > 0
        rounds = again.meta["point_cache"]["rounds"]
        assert rounds and all(r["misses"] == 0 for r in rounds)
        assert sum(r["hits"] for r in rounds) \
            == again.evaluations["high_evals"]

    @pytest.mark.parametrize("seed", [0, 3])
    def test_same_seed_byte_identical_canonical_report(
            self, seed, shared_cache_dir):
        a = smoke_search("successive_halving", seed=seed,
                         cache_dir=shared_cache_dir)
        b = smoke_search("successive_halving", seed=seed,
                         cache_dir=shared_cache_dir)
        assert a.canonical_json() == b.canonical_json()
        # and the canonical form really is volatile-free
        assert "walltime_s" not in json.loads(a.canonical_json())["meta"]

    def test_canonical_bytes_independent_of_cache_temperature(
            self, shared_cache_dir, tmp_path):
        warm = smoke_search("random", seed=1,
                            cache_dir=shared_cache_dir,
                            compare_exhaustive=False)
        cold = smoke_search("random", seed=1,
                            cache_dir=str(tmp_path / "cold"),
                            compare_exhaustive=False)
        assert cold.evaluations["fresh_evals"] > 0
        assert warm.canonical_json() == cold.canonical_json()


# ---------------------------------------------------------------------------
# >=5000-point synthetic space: budget-feasible best in bounded time
# ---------------------------------------------------------------------------


def tiny_kernels(precision_bits, data_seed=7):
    """Two fast kernels (seconds for a handful of sims) so the big-space
    test exercises the search plumbing, not the simulator."""
    eb = precision_bits // 8
    rng = np.random.default_rng(data_seed)
    img = rng.integers(-8, 8, (8, 8)).astype(np.int32)
    filt = rng.integers(-4, 4, (3, 3)).astype(np.int32)
    A = rng.integers(-4, 4, (8, 8)).astype(np.int32)
    B = rng.integers(-4, 4, (8, 8)).astype(np.int32)
    return {
        "conv": conv2d_program(img, filt, shift=2, elem_bytes=eb),
        "matmul": matmul_program(A, B, shift=2, resident=True,
                                 elem_bytes=eb),
    }


def big_space():
    return DesignSpace(
        lanes=(2, 4, 8, 16),
        precisions=(8, 16, 32),
        spm_kbytes=(8, 16, 32, 48, 64, 128),
        chaining=(False, True),
        replication=(2, 3, 4, 5),
        het_fus=(1, 2, 3),
        pipelines=(None, ()),
        fu_counts=((), (("multiplier", 2),)))


class TestSyntheticBigSpace:
    def test_budget_feasible_best_under_constraints(self):
        space = big_space()
        assert space.grid_size >= 5000
        area_cap = hardware_cost(
            DesignPoint(scheme="het_mimd", M=3, F=1, D=8,
                        precision_bits=8).config()).area_luteq
        cons = SpaceConstraints(max_area_luteq=area_cap, max_lanes=8)
        res = run_search(strategy="successive_halving",
                         space=space, constraints=cons,
                         kernel_factory=tiny_kernels,
                         budget=4, pool=64, seed=0,
                         executor="serial", compare_exhaustive=False,
                         emit=None)
        # a budget-feasible best: confirmed cycle-accurate, inside the
        # constraint envelope, found with <=4 sims out of >=5000 cells
        assert res.best is not None and res.best.ok
        assert cons.feasible(res.best.point)
        assert res.evaluations["high_evals"] <= 4
        assert res.evaluations["low_evals"] <= 64
        assert res.exhaustive_fraction < 0.001
        # meta records per-rung evaluations at both fidelities
        assert res.rungs and all(
            {"high_evals", "low_evals"} <= set(r) for r in res.rungs)
        assert res.meta["grid_size"] == space.grid_size
        assert res.meta["constraints"]["max_area_luteq"] == area_cap
        # bounded wall time: the search never touched the other ~5000
        # cells (sampler saw at most the pool, not the grid)
        assert res.evaluations["sampler"]["distinct_points"] <= 64

    def test_big_space_search_is_seed_deterministic(self):
        space = big_space()
        runs = [run_search(strategy="evolutionary", space=space,
                           kernel_factory=tiny_kernels,
                           budget=4, pool=48, seed=11,
                           executor="serial",
                           compare_exhaustive=False, emit=None)
                for _ in range(2)]
        assert runs[0].canonical_json() == runs[1].canonical_json()


# ---------------------------------------------------------------------------
# Driver policy details
# ---------------------------------------------------------------------------


class TestDriverPolicy:
    def test_unknown_strategy_rejected_naming_choices(self):
        with pytest.raises(ValueError, match="evolutionary"):
            run_search(strategy="annealing", smoke=True)

    def test_default_budget_is_half_grid_floored_and_capped(self):
        from repro.kvi.dse.search.driver import default_budget
        assert default_budget(36) == 18
        assert default_budget(96) == 48
        assert default_budget(10) == 8          # floor
        assert default_budget(6624) == 64       # cap

    def test_budget_is_a_hard_ceiling(self, shared_cache_dir):
        res = smoke_search("random", seed=0,
                           cache_dir=shared_cache_dir,
                           budget=5, compare_exhaustive=False)
        assert res.evaluations["high_evals"] == 5
        assert len(res.trajectory) >= 1

    def test_artifacts_written_and_canonical_matches(
            self, shared_cache_dir, tmp_path):
        out = tmp_path / "artifacts"
        res = smoke_search("successive_halving", seed=0,
                           cache_dir=shared_cache_dir,
                           out_dir=str(out))
        for fname in ("dse_search.json", "dse_search_canonical.json",
                      "dse_search.md", "dse_search_trajectory.svg",
                      "BENCH_kvi_search.json"):
            assert (out / fname).exists(), fname
        on_disk = (out / "dse_search_canonical.json").read_text()
        assert on_disk == res.canonical_json() + "\n"
        bench = json.loads((out / "BENCH_kvi_search.json").read_text())
        assert bench["front_recovery"] == 1.0
        md = (out / "dse_search.md").read_text()
        assert "dse_search_trajectory.svg" in md
