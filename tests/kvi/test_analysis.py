"""Static-analysis layer tests: verifier, hazard analyzer, pipeline
verify mode, backend gate, DSE preflight agreement, CLI.

Defects that construction-time validation now rejects (OOB views,
negative offsets) are seeded post-hoc with ``dataclasses.replace`` on
the frozen IR — exactly how a buggy pass would corrupt a program."""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs.base import KlessydraConfig
from repro.kvi import KviInstr, KviOp, KviProgramBuilder
from repro.kvi.analysis import (CODES, Diagnostic, DiagnosticReport,
                                KviVerificationError, Severity,
                                analyze_program, analyze_workload,
                                check_spm_pressure, check_workload,
                                dependence_graph, spm_pressure,
                                verify_program, windows_overlap)
from repro.kvi.analysis.registry import (REGISTERED_TARGETS, build_target,
                                         registered_targets)
from repro.kvi.backend import get_backend
from repro.kvi.ir import KviProgram, Ref, VReg, View
from repro.kvi.passes import (META_KEY, FusionPlan, PassPipeline,
                              PassVerificationError, optimize_program)
from repro.kvi.workload import KviWorkload

CFG = KlessydraConfig("t", M=1, F=1, D=4, spm_kbytes=32)


def small_program(name="demo"):
    b = KviProgramBuilder(name)
    h = b.mem_in("x", np.arange(16, dtype=np.int32))
    v = b.vreg("v", 16)
    w = b.vreg("w", 16)
    b.kmemld(v, h)
    b.ksvmulsc(w, v, scalar=2)
    b.kaddv(w, w, v)
    out = b.mem_out("y", 16)
    b.kmemstr(out, w)
    return b.build()


def replace_instr(program, idx, **fields):
    """``program`` with item ``idx`` rebuilt via dataclasses.replace —
    the defect-seeding path construction validation can't stop."""
    items = list(program.items)
    items[idx] = dataclasses.replace(items[idx], **fields)
    return dataclasses.replace(program, items=tuple(items))


def instr_indices(program, op=None):
    return [i for i, it in enumerate(program.items)
            if isinstance(it, KviInstr) and (op is None or it.op is op)]


# ---------------------------------------------------------------------------
# diagnostics plumbing
# ---------------------------------------------------------------------------


class TestDiagnostics:
    def test_code_table_is_consistent(self):
        for code, (sev, meaning) in CODES.items():
            assert code.startswith("KVI") and len(code) == 6
            assert isinstance(sev, Severity) and meaning

    def test_readme_table_covers_every_code(self):
        import pathlib
        readme = pathlib.Path(__file__).resolve().parents[2] / "README.md"
        text = readme.read_text()
        for code in CODES:
            assert code in text, f"{code} missing from README table"

    def test_unknown_code_rejected(self):
        with pytest.raises(ValueError, match="unregistered"):
            Diagnostic("KVI999", "nope", "p")

    def test_severity_defaults_from_table(self):
        d = Diagnostic("KVI105", "msg", "p")
        assert d.severity is Severity.ERROR
        w = Diagnostic("KVI109", "msg", "p")
        assert w.severity is Severity.WARNING

    def test_report_partitions_and_gates(self):
        rep = DiagnosticReport()
        rep.add("KVI105", "bad window", "p", subject="a")
        rep.add("KVI109", "cold read", "p", subject="b")
        assert len(rep.errors) == 1 and len(rep.warnings) == 1
        assert not rep.ok and not rep.clean
        assert rep.at_least(Severity.WARNING) == list(rep)
        with pytest.raises(KviVerificationError) as ei:
            rep.raise_if()
        assert "KVI105" in str(ei.value)

    def test_render_and_as_dict_are_stable(self):
        d = Diagnostic("KVI105", "msg", "prog", item=3, op="kaddv",
                       subject="item3:dst")
        assert "KVI105" in d.render() and "prog" in d.render()
        dd = d.as_dict()
        assert dd["code"] == "KVI105" and dd["severity"] == "error"


# ---------------------------------------------------------------------------
# structural verifier: one seeded defect per code class
# ---------------------------------------------------------------------------


class TestVerifier:
    def test_stock_program_is_clean(self):
        assert verify_program(small_program()).clean

    def test_oob_window_kvi105(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        it = p.items[idx]
        bad = replace_instr(
            p, idx, src1=dataclasses.replace(it.src1, offset=9))
        rep = verify_program(bad)
        assert "KVI105" in rep.codes and not rep.ok

    def test_elem_bytes_mismatch_kvi106(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        rep = verify_program(replace_instr(p, idx, elem_bytes=2))
        assert "KVI106" in rep.codes

    def test_mem_transfer_extent_kvi107(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KMEMLD)[0]
        rep = verify_program(replace_instr(p, idx, length=8))
        assert "KVI107" in rep.codes

    def test_use_before_def_kvi109_is_warning(self):
        b = KviProgramBuilder("cold")
        v = b.vreg("v", 8)
        w = b.vreg("w", 8)
        b.kaddv(w, v, v)                   # v never written: defined zeros
        out = b.mem_out("y", 8)
        b.kmemstr(out, w)
        rep = verify_program(b.build())
        assert "KVI109" in rep.codes
        assert rep.ok                      # warning, not error
        assert not rep.clean

    def test_output_never_written_kvi110(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KMEMSTR)[0]
        items = tuple(it for i, it in enumerate(p.items) if i != idx)
        rep = verify_program(dataclasses.replace(p, items=items))
        assert "KVI110" in rep.codes

    def test_duplicate_vreg_name_kvi111(self):
        p = small_program()
        vregs = list(p.vregs)
        clash = VReg(vregs[0].name, vregs[1].id, vregs[1].length,
                     vregs[1].elem_bytes)
        rep = verify_program(
            dataclasses.replace(p, vregs=(vregs[0], clash)))
        assert "KVI111" in rep.codes

    def test_dangling_ref_kvi103(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        it = p.items[idx]
        rep = verify_program(replace_instr(
            p, idx, src2=dataclasses.replace(it.src2, id=77)))
        assert "KVI103" in rep.codes

    def test_wrong_space_kvi104(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        rep = verify_program(replace_instr(
            p, idx, src2=Ref("mem", 0, 0)))
        assert "KVI104" in rep.codes

    def test_degenerate_length_kvi102(self):
        # KviInstr/VReg construction rejects length <= 0 outright, so
        # the only seedable degenerate item left is a ScalarBlock
        from repro.kvi.ir import ScalarBlock
        p = small_program()
        rep = verify_program(dataclasses.replace(
            p, items=p.items + (ScalarBlock(0),)))
        assert "KVI102" in rep.codes

    def test_ignored_mem_offset_kvi113(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KMEMLD)[0]
        it = p.items[idx]
        rep = verify_program(replace_instr(
            p, idx, src1=dataclasses.replace(it.src1, offset=4)))
        assert "KVI113" in rep.codes
        assert rep.ok                      # the MFU ignores it: warning

    def test_mem_init_mismatch_kvi108(self):
        p = small_program()
        bad_init = dict(p.mem_init)
        bad_init[0] = np.arange(4, dtype=np.int32)    # declared 16
        rep = verify_program(dataclasses.replace(p, mem_init=bad_init))
        assert "KVI108" in rep.codes


# ---------------------------------------------------------------------------
# hazard analyzer: dependence graph, fusion audit, SPM pressure, races
# ---------------------------------------------------------------------------


class TestDependenceGraph:
    def test_window_overlap(self):
        assert windows_overlap((0, 0, 8), (0, 4, 8))
        assert not windows_overlap((0, 0, 4), (0, 4, 4))
        assert not windows_overlap((0, 0, 8), (1, 0, 8))   # different vreg

    def test_raw_war_waw_edges(self):
        b = KviProgramBuilder("dep")
        h = b.mem_in("x", np.arange(8, dtype=np.int32))
        v = b.vreg("v", 8)
        w = b.vreg("w", 8)
        b.kmemld(v, h)                       # i1 writes v
        b.kaddv(w, v, v)                     # i2: RAW on v, writes w
        b.ksvmulsc(v, w, scalar=3)           # i3: RAW on w, WAR+WAW on v
        out = b.mem_out("y", 8)
        b.kmemstr(out, v)                    # i4: RAW on v
        g = dependence_graph(b.build())
        kinds = g.counts
        assert kinds["RAW"] >= 3 and kinds["WAR"] >= 1 and kinds["WAW"] >= 1

    def test_disjoint_windows_no_edge(self):
        b = KviProgramBuilder("disjoint")
        h = b.mem_in("x", np.arange(16, dtype=np.int32))
        v = b.vreg("v", 16)
        w = b.vreg("w", 16)
        b.kmemld(v, h)
        b.kaddv(w.view(0, 8), v.view(0, 8), v.view(0, 8))
        b.kaddv(w.view(8, 8), v.view(8, 8), v.view(8, 8))   # disjoint halves
        out = b.mem_out("y", 16)
        b.kmemstr(out, w)
        g = dependence_graph(b.build())
        halves = [e for e in g.edges
                  if e.src_window[1] != e.dst_window[1]
                  and e.src_window[0] == e.dst_window[0]
                  and e.kind != "RAW"]
        assert halves == []

    def test_stock_kernels_build_quickly(self):
        # frontier pruning keeps paper-size graphs tractable
        g = dependence_graph(build_target("conv32"))
        assert len(g.edges) > 0


class TestFusionAudit:
    def optimized(self):
        p = optimize_program(small_program())
        assert isinstance(p.meta.get(META_KEY), FusionPlan)
        return p

    def test_planner_output_is_legal(self):
        assert analyze_program(self.optimized()).clean

    def test_weld_of_mem_op_kvi201(self):
        p = self.optimized()
        plan = p.meta[META_KEY]
        mem_idx = instr_indices(p, KviOp.KMEMLD)[0]
        region = plan.regions[0]
        bad_region = dataclasses.replace(
            region, items=tuple(sorted(region.items + (mem_idx,))))
        bad_plan = dataclasses.replace(
            plan, regions=(bad_region,) + plan.regions[1:])
        meta = dict(p.meta)
        meta[META_KEY] = bad_plan
        rep = analyze_program(dataclasses.replace(p, meta=meta))
        assert "KVI201" in rep.codes

    def test_invalid_indices_kvi204(self):
        p = self.optimized()
        plan = p.meta[META_KEY]
        region = plan.regions[0]
        bad_region = dataclasses.replace(region, items=(999,))
        meta = dict(p.meta)
        meta[META_KEY] = dataclasses.replace(
            plan, regions=(bad_region,))
        rep = analyze_program(dataclasses.replace(p, meta=meta))
        assert "KVI204" in rep.codes

    def test_stale_read_weld_kvi203(self):
        # w[0:8] written, then read at the overlapping window w[4:8]:
        # legal sequentially, illegal inside one gather-first region
        b = KviProgramBuilder("weld")
        h = b.mem_in("x", np.arange(16, dtype=np.int32))
        v = b.vreg("v", 16)
        w = b.vreg("w", 16)
        u = b.vreg("u", 16)
        b.kmemld(v, h)
        b.kaddv(w.view(0, 8), v.view(0, 8), v.view(0, 8))
        b.kaddv(u.view(0, 8), w.view(4, 8), v.view(4, 8))
        out = b.mem_out("y", 16)
        b.kmemstr(out, u)
        p = b.build()
        i1, i2 = instr_indices(p, KviOp.KADDV)
        from repro.kvi.passes.fusion import FusedRegion
        region = FusedRegion(items=(i1, i2), length=8, elem_bytes=4,
                             ops=(), inputs=(), outputs=(), n_slots=0)
        meta = dict(p.meta)
        meta[META_KEY] = FusionPlan(regions=(region,))
        rep = analyze_program(dataclasses.replace(p, meta=meta))
        assert "KVI203" in rep.codes


class TestSpmPressure:
    def test_estimate_matches_allocator_decision(self):
        from repro.kvi.lowering import SpmOverflowError, allocate_vregs
        progs = [small_program(), build_target("conv32"),
                 build_target("fft256")]
        for kb in (1, 2, 4, 8, 64):
            cfg = KlessydraConfig("t", M=1, F=1, D=4, spm_kbytes=kb)
            for p in progs:
                est = spm_pressure(p, cfg)
                try:
                    allocate_vregs(p, cfg)
                    fits = True
                except SpmOverflowError:
                    fits = False
                assert est.fits == fits, (p.name, kb)

    def test_over_pressure_kvi301(self):
        tiny = KlessydraConfig("t", M=1, F=1, D=4, spm_kbytes=1)
        rep = check_spm_pressure(build_target("conv32"), tiny)
        assert "KVI301" in rep.codes
        assert not rep.ok


def _writer(name, value, out_name="y", n=8):
    b = KviProgramBuilder(name)
    h = b.mem_in("x_" + name, np.full(n, value, dtype=np.int32))
    v = b.vreg("v", n)
    b.kmemld(v, h)
    if name.endswith("_mul"):              # structurally distinct pair
        b.ksvmulsc(v, v, scalar=3)
    out = b.mem_out(out_name, n)
    b.kmemstr(out, v)
    return b.build()


class TestWorkloadChecks:
    def test_write_write_race_kvi210(self):
        wl = KviWorkload.composite(
            {0: [_writer("a", 1)], 1: [_writer("b_mul", 2)]})
        rep = check_workload(wl)
        assert "KVI210" in rep.codes

    def test_same_hart_is_sequential_not_a_race(self):
        wl = KviWorkload.composite(
            {0: [_writer("a", 1), _writer("b_mul", 2)]})
        assert check_workload(wl).clean

    def test_homogeneous_instances_exempt(self):
        # equal structural signatures = data instances; the workload
        # model gives each its own output slot
        wl = KviWorkload.replicate(_writer("a", 1), 3)
        assert check_workload(wl).clean

    def test_non_shared_scheme_downgrades(self):
        wl = KviWorkload.composite(
            {0: [_writer("a", 1)], 1: [_writer("b_mul", 2)]})
        rep = check_workload(wl, shared_scheme=False)
        assert "KVI210" not in rep.codes

    def test_read_write_sharing_kvi211(self):
        writer = _writer("a", 1, out_name="shared_buf")
        b = KviProgramBuilder("reader")
        h = b.mem_in("shared_buf", np.zeros(8, dtype=np.int32))
        v = b.vreg("v", 8)
        b.kmemld(v, h)
        out = b.mem_out("z", 8)
        b.kmemstr(out, v)
        wl = KviWorkload.composite({0: [writer], 1: [b.build()]})
        rep = check_workload(wl)
        assert "KVI211" in rep.codes
        assert rep.ok                      # warning severity

    def test_hart_pin_oob_kvi302(self):
        wl = KviWorkload.composite({5: [_writer("a", 1)]})
        cfg = KlessydraConfig("t", M=1, F=1, D=4, spm_kbytes=32)
        rep = check_workload(wl, config=cfg)
        assert "KVI302" in rep.codes

    def test_analyze_workload_aggregates(self):
        wl = KviWorkload.composite(
            {0: [_writer("a", 1)], 1: [_writer("b_mul", 2)]})
        rep = analyze_workload(wl)
        assert "KVI210" in rep.codes


# ---------------------------------------------------------------------------
# stock cleanliness: the zero-false-positive contract
# ---------------------------------------------------------------------------


class TestStockCleanliness:
    @pytest.mark.parametrize("name", sorted(REGISTERED_TARGETS))
    def test_registered_target_is_clean(self, name):
        target = build_target(name)
        cfg = KlessydraConfig("lint", M=1, F=1, D=4, spm_kbytes=64)
        if isinstance(target, KviProgram):
            rep = analyze_program(target, config=cfg)
        else:
            rep = analyze_workload(target, config=cfg)
        assert rep.clean, rep.render_text()

    def test_optimized_programs_stay_clean(self):
        for name in ("conv32", "fft256", "matmul64"):
            p = optimize_program(build_target(name))
            rep = analyze_program(p)
            assert rep.clean, rep.render_text()

    def test_registry_listing(self):
        names = registered_targets()
        assert "conv32" in names and "composite_paper" in names
        with pytest.raises(KeyError, match="unknown lint target"):
            build_target("nope")


# ---------------------------------------------------------------------------
# pipeline verify mode: pass attribution
# ---------------------------------------------------------------------------


def _clobber_window(program):
    """A 'pass' that miscompiles: shifts a vector op's dst off the end
    of its vreg."""
    items = list(program.items)
    for k, it in enumerate(items):
        if (isinstance(it, KviInstr) and it.op is KviOp.KADDV):
            items[k] = dataclasses.replace(
                it, dst=dataclasses.replace(it.dst, offset=10 ** 6))
            break
    return dataclasses.replace(program, items=tuple(items))


class TestPipelineVerify:
    def test_attributes_injected_bug_to_the_pass(self):
        pipe = PassPipeline.from_spec(
            ("copy_prop", _clobber_window, "dce"), verify=True)
        with pytest.raises(PassVerificationError) as ei:
            pipe.run(small_program())
        assert ei.value.pass_name == "_clobber_window"
        assert "KVI105" in ei.value.report.codes

    def test_clean_program_passes_verified_pipeline(self):
        out = PassPipeline.from_spec(None, verify=True).run(
            small_program())
        assert analyze_program(out).clean

    def test_broken_input_attributed_to_input(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        bad = replace_instr(
            p, idx,
            src1=dataclasses.replace(p.items[idx].src1, offset=10 ** 6))
        with pytest.raises(PassVerificationError) as ei:
            PassPipeline.from_spec(None, verify=True).run(bad)
        assert ei.value.pass_name == "<input>"

    def test_from_spec_upgrades_existing_pipeline(self):
        base = PassPipeline.from_spec(None)
        assert not base.verify
        up = PassPipeline.from_spec(base, verify=True)
        assert up.verify and up.passes == base.passes


# ---------------------------------------------------------------------------
# backend gate
# ---------------------------------------------------------------------------


class TestBackendVerifyGate:
    def bad_program(self):
        p = small_program()
        idx = instr_indices(p, KviOp.KADDV)[0]
        return replace_instr(
            p, idx,
            src1=dataclasses.replace(p.items[idx].src1, offset=10 ** 6))

    def test_ctor_gate_rejects(self):
        be = get_backend("oracle", verify=True)
        with pytest.raises(KviVerificationError) as ei:
            be.run(self.bad_program())
        assert "KVI105" in str(ei.value)

    def test_per_call_override(self):
        be = get_backend("oracle")
        wl = KviWorkload.single(self.bad_program())
        with pytest.raises(KviVerificationError):
            be.run_workload(wl, verify=True)

    def test_clean_program_runs_verified(self):
        be = get_backend("oracle", verify=True)
        res = be.run(small_program())
        x = np.arange(16, dtype=np.int32)
        np.testing.assert_array_equal(res.outputs["y"], x * 2 + x)

    def test_cyclesim_gate(self):
        be = get_backend("cyclesim", verify=True)
        with pytest.raises(KviVerificationError):
            be.run_workload(KviWorkload.single(self.bad_program()))


# ---------------------------------------------------------------------------
# DSE preflight integration
# ---------------------------------------------------------------------------


class TestDsePreflight:
    def test_static_rejection_mentions_kvi301(self):
        from repro.kvi.dse.space import DesignPoint, preflight_point
        tiny = DesignPoint("shared", 1, 1, 4, spm_kbytes=1)
        reason = preflight_point(tiny, [build_target("conv32")])
        assert reason is not None and "KVI301" in reason

    def test_point_record_carries_static_spm(self):
        from repro.kvi.dse.space import DesignPoint
        from repro.kvi.dse.sweep import run_point
        pt = DesignPoint("shared", 1, 1, 4, spm_kbytes=64)
        rec = run_point(pt, {"demo": small_program()}, composite=False)
        assert rec.ok
        spm = rec.kernels["demo"]["static_spm"]
        assert spm["fits"] and spm["peak_live_bytes"] > 0
        assert "static_spm" in json.dumps(rec.as_dict())

    def test_estimate_agrees_on_smoke_points(self):
        # acceptance criterion: static estimate == allocator verdict on
        # every smoke-space point, for every smoke kernel
        from repro.kvi.dse.report import smoke_space
        from repro.kvi.dse.sweep import paper_kernel_factory
        from repro.kvi.lowering import SpmOverflowError, allocate_vregs
        factory = paper_kernel_factory(smoke=True)
        kernels_by_prec = {}
        for pt in smoke_space().points():
            cfg = pt.config()
            kernels = kernels_by_prec.setdefault(
                pt.precision_bits, factory(pt.precision_bits))
            for name, prog in kernels.items():
                est = spm_pressure(prog, cfg)
                try:
                    allocate_vregs(prog, cfg)
                    fits = True
                except SpmOverflowError:
                    fits = False
                assert est.fits == fits, (pt.name, name)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCli:
    def run_cli(self, argv, capsys):
        from repro.kvi.analysis.__main__ import main
        code = main(argv)
        return code, capsys.readouterr().out

    def test_list(self, capsys):
        code, out = self.run_cli(["--list"], capsys)
        assert code == 0 and "conv32" in out

    def test_all_text_clean(self, capsys):
        code, out = self.run_cli(["--all"], capsys)
        assert code == 0
        assert "clean" in out and "0 error(s)" in out

    def test_json_format(self, capsys):
        code, out = self.run_cli(
            ["conv32", "--format", "json"], capsys)
        assert code == 0
        payload = json.loads(out)
        assert payload["n_errors"] == 0
        assert "conv32" in payload["targets"]

    def test_unknown_target_usage_error(self, capsys):
        from repro.kvi.analysis.__main__ import main
        with pytest.raises(SystemExit) as ei:
            main(["definitely_not_registered"])
        assert ei.value.code == 2

    def test_fail_on_warning_gate(self, capsys):
        # a tiny SPM makes every target over-pressure: exit 1 on error
        code, out = self.run_cli(
            ["conv32", "--spm-kbytes", "1"], capsys)
        assert code == 1 and "KVI301" in out

    def test_fail_on_never_always_exits_zero(self, capsys):
        code, _ = self.run_cli(
            ["conv32", "--spm-kbytes", "1", "--fail-on", "never"],
            capsys)
        assert code == 0
