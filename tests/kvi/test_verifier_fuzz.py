"""Fuzz-oracle coverage for the static verifier.

Property: for any mutant of a known-good program, if executing it makes
a backend raise, or makes the oracle and cycle-sim backends disagree on
functional outputs, the verifier must flag it (one-directional — the
verifier may also flag mutants the backends happen to tolerate). And on
every stock program the verifier is silent.

Mutations are applied through ``dataclasses.replace`` on the frozen IR
(construction-time validation blocks building these directly) — exactly
the corruption surface a buggy pass has. A deterministic sweep runs the
whole catalog always; a hypothesis variant samples the same catalog
when hypothesis is installed (it degrades to a skip otherwise)."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.configs.base import KlessydraConfig
from repro.kvi import KviInstr, KviProgramBuilder
from repro.kvi.analysis import verify_program
from repro.kvi.backend import get_backend
from repro.kvi.ir import VReg
from repro.kvi.workload import KviWorkload

CFG = KlessydraConfig("fuzz", M=1, F=1, D=4, spm_kbytes=64)


def base_programs():
    progs = []

    b = KviProgramBuilder("mix")
    h = b.mem_in("x", np.arange(16, dtype=np.int32))
    v = b.vreg("v", 16)
    w = b.vreg("w", 16)
    acc = b.vreg("acc", 4)
    b.kmemld(v, h)
    b.ksvmulsc(w, v, scalar=3)
    b.kaddv(w, w, v)
    b.kdotp(acc[0], w, v)
    out = b.mem_out("y", 16)
    b.kmemstr(out, w)
    progs.append(b.build())

    from repro.kvi.programs import conv2d_program, fft_program
    rng = np.random.default_rng(7)
    progs.append(conv2d_program(
        rng.integers(-64, 64, (6, 6)).astype(np.int32),
        rng.integers(-8, 8, (3, 3)).astype(np.int32), shift=3))
    progs.append(fft_program(
        rng.integers(-64, 64, 16).astype(np.int32),
        rng.integers(-64, 64, 16).astype(np.int32)))
    return progs


def mutants(program):
    """(label, mutant) catalog: every structural corruption class the
    verifier promises to catch, seeded at every applicable site."""
    out = []
    instr_at = [(i, it) for i, it in enumerate(program.items)
                if isinstance(it, KviInstr)]

    def with_item(idx, instr):
        items = list(program.items)
        items[idx] = instr
        return dataclasses.replace(program, items=tuple(items))

    for idx, it in instr_at[:6]:        # bound the catalog per program
        for role in ("dst", "src1", "src2"):
            ref = getattr(it, role)
            if ref is None or ref.space != "vreg":
                continue
            reg = program.vregs[ref.id]
            out.append((
                f"oob:{idx}:{role}",
                with_item(idx, dataclasses.replace(
                    it, **{role: dataclasses.replace(
                        ref, offset=ref.offset + reg.length)}))))
            out.append((
                f"dangle:{idx}:{role}",
                with_item(idx, dataclasses.replace(
                    it, **{role: dataclasses.replace(ref, id=57)}))))
        if it.elem_bytes == 4:
            out.append((f"elem:{idx}",
                        with_item(idx, dataclasses.replace(
                            it, elem_bytes=2))))

    for vi, reg in enumerate(program.vregs):
        if reg.length < 2:
            continue
        shrunk = VReg(reg.name, reg.id, reg.length // 2, reg.elem_bytes)
        vregs = tuple(shrunk if i == vi else r
                      for i, r in enumerate(program.vregs))
        out.append((f"shrink:{reg.name}",
                    dataclasses.replace(program, vregs=vregs)))
    return out


def execute(backend, program):
    """("ok", outputs) or ("raise", None)."""
    try:
        res = backend.run_workload(
            KviWorkload.single(program)).entry_result(0)
        return "ok", {k: np.asarray(v) for k, v in res.outputs.items()}
    except Exception:
        return "raise", None


def backends():
    return (get_backend("oracle", passes=()),
            get_backend("cyclesim", passes=(), schemes={"fuzz": CFG},
                        replicate_harts=False))


def misbehaves(program):
    """True when any backend raises or the two backends disagree."""
    oracle, sim = backends()
    s1, o1 = execute(oracle, program)
    s2, o2 = execute(sim, program)
    if s1 == "raise" or s2 == "raise":
        return True
    if sorted(o1) != sorted(o2):
        return True
    return any(not np.array_equal(o1[k], o2[k]) for k in o1)


class TestFuzzOracle:
    @pytest.mark.parametrize("pi", range(3))
    def test_stock_programs_clean_and_agree(self, pi):
        p = base_programs()[pi]
        assert verify_program(p).clean, verify_program(p).render_text()
        assert not misbehaves(p)

    @pytest.mark.parametrize("pi", range(3))
    def test_every_misbehaving_mutant_is_flagged(self, pi):
        p = base_programs()[pi]
        caught = missed = benign = 0
        for label, m in mutants(p):
            rep = verify_program(m)
            if misbehaves(m):
                if rep.clean:
                    missed += 1
                    pytest.fail(
                        f"mutant {label} of {p.name!r} breaks a backend "
                        f"but the verifier is silent")
                caught += 1
            else:
                benign += 1
        # the catalog must actually exercise the property
        assert caught >= 3, (caught, benign, missed)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestFuzzOracleHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2), st.data())
    def test_sampled_mutants_hold_the_property(self, pi, data):
        p = base_programs()[pi]
        catalog = mutants(p)
        label, m = catalog[data.draw(
            st.integers(min_value=0, max_value=len(catalog) - 1))]
        rep = verify_program(m)
        if misbehaves(m):
            assert not rep.clean, f"mutant {label} unflagged"
