"""Persistent point-cache tests: content-addressed keys (the
invalidation matrix), the JSON-lines store (integrity, last-write-wins,
GC compaction), sweep integration (cold/warm/mixed byte-identity across
executors, counter pins, delta re-sweeps) and the auto executor."""
import json

import numpy as np
import pytest

from repro.kvi.dse import (AUTO_SERIAL_MAX, DesignPoint, DesignSpace,
                           PointCache, SerialExecutor, pallas_class_key,
                           point_key, program_fingerprint, resolve_auto,
                           sweep)
from repro.kvi.dse.pointcache import (record_from_payload,
                                      record_to_payload, resolved_passes)
from repro.kvi.programs import conv2d_program, fft_program, matmul_program

# ---------------------------------------------------------------------------
# Fixtures: a 6-point space over tiny kernels (seconds per sweep)
# ---------------------------------------------------------------------------

SMALL_SPACE = DesignSpace(lanes=(2,), precisions=(8, 32))   # 6 points


def small_kernels(precision_bits, data_seed=7):
    eb = precision_bits // 8
    rng = np.random.default_rng(data_seed)
    img = rng.integers(-8, 8, (8, 8)).astype(np.int32)
    filt = rng.integers(-4, 4, (3, 3)).astype(np.int32)
    A = rng.integers(-4, 4, (8, 8)).astype(np.int32)
    B = rng.integers(-4, 4, (8, 8)).astype(np.int32)
    return {
        "conv": conv2d_program(img, filt, shift=2, elem_bytes=eb),
        "fft": fft_program(rng.integers(-64, 64, 32).astype(np.int32),
                           rng.integers(-64, 64, 32).astype(np.int32),
                           elem_bytes=eb),
        "matmul": matmul_program(A, B, shift=2, resident=True,
                                 elem_bytes=eb),
    }


def edited8_kernels(precision_bits):
    """small_kernels with *different input data* for the 8-bit programs
    only — the one-axis edit of the delta-re-sweep tests."""
    return small_kernels(precision_bits,
                         data_seed=11 if precision_bits == 8 else 7)


def saxpy_kernels(precision_bits):
    from repro.kvi.ir import KviProgramBuilder
    eb = precision_bits // 8
    x = np.arange(-32, 32, dtype=np.int32)
    b = KviProgramBuilder("saxpy")
    v = b.vreg("v", 64, elem_bytes=eb)
    b.kmemld(v, b.mem_in("x", x.astype(np.int32)))
    b.ksvmulsc(v, v, scalar=3)
    b.krelu(v, v)
    b.kmemstr(b.mem_out("y", 64), v)
    return {"saxpy": b.build()}


def fps_for(point, kernels=small_kernels):
    return {name: program_fingerprint(p)
            for name, p in kernels(point.precision_bits).items()}


# ---------------------------------------------------------------------------
# Keys: the invalidation matrix
# ---------------------------------------------------------------------------


class TestKeys:
    def test_fingerprint_stable_across_rebuilds(self):
        a = small_kernels(32)["conv"]
        b = small_kernels(32)["conv"]
        assert a is not b
        assert program_fingerprint(a) == program_fingerprint(b)

    def test_fingerprint_changes_with_data_and_structure(self):
        base = program_fingerprint(small_kernels(32)["conv"])
        edited = program_fingerprint(small_kernels(32, data_seed=11)
                                     ["conv"])
        assert base != edited                      # mem_init bytes
        assert base != program_fingerprint(small_kernels(8)["conv"])

    def test_key_stable_for_identical_inputs(self):
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        assert point_key(pt, fps_for(pt), True) == \
            point_key(pt, fps_for(pt), True)

    def test_point_dict_change_misses(self):
        a = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        fps = fps_for(a)
        base = point_key(a, fps, True)
        for other in (
                DesignPoint("shared", 1, 1, 4, precision_bits=32),
                DesignPoint("shared", 1, 1, 2, precision_bits=32,
                            spm_kbytes=32),
                DesignPoint("shared", 1, 1, 2, precision_bits=32,
                            chaining=True),
                DesignPoint("sym_mimd", 3, 3, 2, precision_bits=32)):
            assert point_key(other, fps, True) != base, other.name

    def test_program_ir_change_misses(self):
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=8)
        base = point_key(pt, fps_for(pt), True)
        edited = point_key(pt, fps_for(pt, edited8_kernels), True)
        assert base != edited

    def test_pass_spec_change_misses(self):
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        fps = fps_for(pt)
        raw = DesignPoint("shared", 1, 1, 2, precision_bits=32,
                          passes=())
        dce = DesignPoint("shared", 1, 1, 2, precision_bits=32,
                          passes=("dce",))
        keys = {point_key(p, fps, True) for p in (pt, raw, dce)}
        assert len(keys) == 3

    def test_default_pipeline_resolves_to_names(self):
        from repro.kvi.passes.pipeline import DEFAULT_PASSES
        assert resolved_passes(None) == list(DEFAULT_PASSES)
        assert resolved_passes(()) == []

    def test_calibration_version_bump_misses(self, monkeypatch):
        from repro.kvi.dse import cost
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        fps = fps_for(pt)
        base = point_key(pt, fps, True)
        monkeypatch.setattr(cost, "CALIBRATION_VERSION",
                            cost.CALIBRATION_VERSION + 1)
        assert point_key(pt, fps, True) != base

    def test_timing_version_bump_misses(self, monkeypatch):
        from repro.kvi import cyclesim
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        fps = fps_for(pt)
        base = point_key(pt, fps, True)
        monkeypatch.setattr(cyclesim, "TIMING_VERSION",
                            cyclesim.TIMING_VERSION + 1)
        assert point_key(pt, fps, True) != base

    def test_composite_flag_misses(self):
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        fps = fps_for(pt)
        assert point_key(pt, fps, True) != point_key(pt, fps, False)

    def test_measure_pallas_mode_does_not_change_key(self):
        # a measurement MODE, not an input: flipping it must keep the
        # cyclesim record warm
        a = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        b = DesignPoint("shared", 1, 1, 2, precision_bits=32,
                        measure_pallas=True)
        fps = fps_for(a)
        assert point_key(a, fps, True) == point_key(b, fps, True)

    def test_pallas_class_key_axes(self, monkeypatch):
        fps = {"saxpy": program_fingerprint(saxpy_kernels(32)["saxpy"])}
        base = pallas_class_key(fps, 32, None, 3, True)
        assert pallas_class_key(fps, 8, None, 3, True) != base
        assert pallas_class_key(fps, 32, (), 3, True) != base
        assert pallas_class_key(fps, 32, None, 4, True) != base
        from repro.kvi import cyclesim
        monkeypatch.setattr(cyclesim, "TIMING_VERSION",
                            cyclesim.TIMING_VERSION + 1)
        assert pallas_class_key(fps, 32, None, 3, True) != base


# ---------------------------------------------------------------------------
# Record (de)serialization
# ---------------------------------------------------------------------------


class TestRecordRoundtrip:
    def test_ok_record_roundtrips(self):
        from repro.kvi.dse.sweep import run_point
        pt = DesignPoint("shared", 1, 1, 2, precision_bits=32)
        rec = run_point(pt, small_kernels(32))
        back = record_from_payload(
            json.loads(json.dumps(record_to_payload(rec))), pt)
        assert back.cached and back.wall_s == 0.0
        a, b = rec.as_dict(), back.as_dict()
        a.pop("wall_s"), b.pop("wall_s"), b.pop("cached")
        assert a == b
        assert back.area.area_luteq == rec.area.area_luteq

    def test_incompatible_record_roundtrips(self):
        from repro.kvi.dse.sweep import run_point
        pt = DesignPoint("shared", 1, 1, 4, spm_kbytes=1,
                         precision_bits=32)
        def big(precision_bits):
            img = np.arange(1024, dtype=np.int32).reshape(32, 32)
            return {"conv": conv2d_program(img, np.ones((3, 3), np.int32),
                                           elem_bytes=4)}
        rec = run_point(pt, big(32))
        assert rec.status == "incompatible"
        back = record_from_payload(
            json.loads(json.dumps(record_to_payload(rec))), pt)
        assert back.status == "incompatible"
        assert back.reason == rec.reason and back.area is None


# ---------------------------------------------------------------------------
# The on-disk store
# ---------------------------------------------------------------------------


class TestStore:
    def test_last_write_wins_within_and_across_instances(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path))
        c._store("point", "k1", "p1", {"n": 1})
        c._store("point", "k1", "p1", {"n": 2})
        assert c._lookup("point", "k1", "p1") == {"n": 2}
        again = PointCache(cache_dir=str(tmp_path))
        assert again._lookup("point", "k1", "p1") == {"n": 2}
        assert again.n_entries == 1

    def test_lookup_returns_isolated_copies(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path))
        c._store("point", "k1", "p1", {"n": 1, "d": {"x": 1}})
        got = c._lookup("point", "k1", "p1")
        got["d"]["x"] = 999                 # caller mutates its copy
        assert c._lookup("point", "k1", "p1")["d"]["x"] == 1

    def test_invalidation_counted_on_label_key_mismatch(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path))
        c._store("point", "k_old", "p1", {"n": 1})
        assert c._lookup("point", "k_new", "p1") is None
        assert c.invalidations == 1
        # a genuinely new label is a plain miss, not an invalidation
        assert c._lookup("point", "k_other", "p_new") is None
        assert c.invalidations == 1
        # storing under the new key replaces the stale entry
        c._store("point", "k_new", "p1", {"n": 2})
        assert c.n_entries == 1
        assert c._lookup("point", "k_old", "p1") is None

    def test_corrupt_lines_discarded_not_fatal(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path))
        for i in range(3):
            c._store("point", f"k{i}", f"p{i}", {"n": i})
        lines = (tmp_path / "dse_point_cache.jsonl").read_text(
        ).splitlines()
        # tamper with one payload (checksum now wrong), add garbage and
        # a schema-stale line
        bad = json.loads(lines[1])
        bad["payload"]["n"] = 999
        stale = json.loads(lines[2])
        stale["v"] = 9999
        (tmp_path / "dse_point_cache.jsonl").write_text("\n".join(
            [lines[0], json.dumps(bad), "{{{not json",
             json.dumps(stale), ""]) + "\n")
        again = PointCache(cache_dir=str(tmp_path))
        assert again._lookup("point", "k0", "p0") == {"n": 0}
        assert again._lookup("point", "k1", "p1") is None
        assert again._lookup("point", "k2", "p2") is None
        assert again.corrupt_discarded == 3

    def test_gc_compaction_drops_oldest_first(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path), max_bytes=600)
        for i in range(12):
            c._store("point", f"k{i:02d}", f"p{i:02d}", {"n": i})
        assert c.store_bytes <= 600
        assert 0 < c.n_entries < 12
        # survivors are the newest entries
        survivors = {json.loads(line)["key"] for line in
                     (tmp_path / "dse_point_cache.jsonl").read_text(
                     ).splitlines()}
        assert survivors == {f"k{i:02d}"
                             for i in range(12 - len(survivors), 12)}

    def test_compaction_is_reloadable(self, tmp_path):
        c = PointCache(cache_dir=str(tmp_path))
        for i in range(4):
            c._store("point", f"k{i}", f"p{i}", {"n": i})
        c._store("point", "k0b", "p0", {"n": 99})   # replaces k0
        c.compact()
        again = PointCache(cache_dir=str(tmp_path))
        assert again.n_entries == 4
        assert again._lookup("point", "k0b", "p0") == {"n": 99}


# ---------------------------------------------------------------------------
# Sweep integration: cold / warm / mixed
# ---------------------------------------------------------------------------


N_SMALL = len(SMALL_SPACE.points())


class TestSweepIntegration:
    @pytest.fixture()
    def store_dir(self, tmp_path):
        return str(tmp_path / "cache")

    def cold(self, store_dir, **kw):
        cache = PointCache(cache_dir=store_dir)
        return sweep(SMALL_SPACE, small_kernels, max_workers=1,
                     cache=cache, **kw), cache

    def test_cold_then_warm_counters_and_bytes(self, store_dir):
        cold_res, cold_cache = self.cold(store_dir)
        assert cold_cache.hits == 0
        assert cold_cache.misses == N_SMALL
        assert cold_cache.stores == N_SMALL
        warm_cache = PointCache(cache_dir=store_dir)
        warm_res = sweep(SMALL_SPACE, small_kernels, max_workers=1,
                         cache=warm_cache)
        assert warm_cache.hits == N_SMALL
        assert warm_cache.misses == 0 and warm_cache.stores == 0
        assert all(r.cached for r in warm_res.records)
        assert not any(r.cached for r in cold_res.records)
        assert warm_res.canonical_json() == cold_res.canonical_json()
        # cache metadata is volatile-scrubbed but present in raw JSON
        assert warm_res.meta["point_cache"]["hits"] == N_SMALL
        assert warm_res.to_json()["points"][0]["cached"] is True

    def test_byte_identity_vs_uncached_and_across_executors(
            self, store_dir):
        plain = sweep(SMALL_SPACE, small_kernels, max_workers=1)
        cold_res, _ = self.cold(store_dir, executor="serial")
        assert cold_res.canonical_json() == plain.canonical_json()
        for executor in ("thread", "process"):
            res, cache = self.cold(str(store_dir) + "_" + executor,
                                   executor=executor)
            assert cache.misses == N_SMALL, executor
            assert res.canonical_json() == plain.canonical_json(), \
                executor
        # warm resolve against the serial-cold store, via every executor
        for executor in ("serial", "thread", "process"):
            cache = PointCache(cache_dir=store_dir)
            res = sweep(SMALL_SPACE, small_kernels, max_workers=1,
                        cache=cache, executor=executor)
            assert cache.hits == N_SMALL, executor
            assert res.canonical_json() == plain.canonical_json(), \
                executor

    def test_one_axis_edit_recomputes_only_the_delta(self, store_dir):
        self.cold(store_dir)
        cache = PointCache(cache_dir=store_dir)
        res = sweep(SMALL_SPACE, edited8_kernels, max_workers=1,
                    cache=cache)
        n8 = sum(p.precision_bits == 8 for p in SMALL_SPACE.points())
        assert cache.hits == N_SMALL - n8       # 32-bit points warm
        assert cache.misses == n8               # 8-bit points recompute
        assert cache.invalidations == n8        # same point, new inputs
        assert cache.stores == n8
        by_prec = {r.point.precision_bits: r.cached for r in res.records}
        assert by_prec[32] is True and by_prec[8] is False
        # the store replaced the stale 8-bit entries, no growth
        assert cache.n_entries == N_SMALL
        # byte-identity against an uncached sweep of the edited inputs
        plain = sweep(SMALL_SPACE, edited8_kernels, max_workers=1)
        assert res.canonical_json() == plain.canonical_json()

    def test_space_growth_is_a_mixed_sweep(self, store_dir):
        self.cold(store_dir)
        grown = DesignSpace(lanes=(2, 4), precisions=(8, 32))
        cache = PointCache(cache_dir=store_dir)
        res = sweep(grown, small_kernels, max_workers=1, cache=cache)
        n_grown = len(grown.points())
        assert cache.hits == N_SMALL
        assert cache.misses == n_grown - N_SMALL
        plain = sweep(grown, small_kernels, max_workers=1)
        assert res.canonical_json() == plain.canonical_json()

    def test_version_bump_invalidates_everything(self, store_dir,
                                                 monkeypatch):
        self.cold(store_dir)
        from repro.kvi.dse import cost
        monkeypatch.setattr(cost, "CALIBRATION_VERSION",
                            cost.CALIBRATION_VERSION + 1)
        cache = PointCache(cache_dir=store_dir)
        sweep(SMALL_SPACE, small_kernels, max_workers=1, cache=cache)
        assert cache.hits == 0
        assert cache.misses == N_SMALL
        assert cache.invalidations == N_SMALL

    def test_corrupted_entry_recomputed_in_sweep(self, store_dir):
        _, cold_cache = self.cold(store_dir)
        path = cold_cache.path
        with open(path) as f:
            lines = f.read().splitlines()
        bad = json.loads(lines[0])
        bad["payload"]["status"] = "tampered"
        lines[0] = json.dumps(bad)
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        cache = PointCache(cache_dir=store_dir)
        res = sweep(SMALL_SPACE, small_kernels, max_workers=1,
                    cache=cache)
        assert cache.corrupt_discarded == 1
        assert cache.hits == N_SMALL - 1 and cache.misses == 1
        assert all(r.ok for r in res.records)

    def test_incompatible_points_cache_too(self, tmp_path):
        def big(precision_bits):
            img = np.arange(1024, dtype=np.int32).reshape(32, 32)
            return {"conv": conv2d_program(img, np.ones((3, 3), np.int32),
                                           elem_bytes=4)}
        pts = [DesignPoint("shared", 1, 1, 4, spm_kbytes=1,
                           precision_bits=32)]
        c1 = PointCache(cache_dir=str(tmp_path))
        a = sweep(pts, big, max_workers=1, cache=c1)
        assert a.records[0].status == "incompatible"
        c2 = PointCache(cache_dir=str(tmp_path))
        b = sweep(pts, big, max_workers=1, cache=c2)
        assert c2.hits == 1
        assert b.records[0].status == "incompatible"
        assert b.records[0].reason == a.records[0].reason


# ---------------------------------------------------------------------------
# Pallas measurement-class caching
# ---------------------------------------------------------------------------


class TestPallasCaching:
    def test_warm_resweep_resolves_pallas_classes(self, tmp_path):
        pts = [DesignPoint("shared", 1, 1, 4, measure_pallas=True),
               DesignPoint("sym_mimd", 3, 3, 4, measure_pallas=True)]
        c1 = PointCache(cache_dir=str(tmp_path))
        cold = sweep(pts, saxpy_kernels, max_workers=1, composite=False,
                     cache=c1)
        assert c1.pallas_misses == 1 and c1.pallas_hits == 0
        c2 = PointCache(cache_dir=str(tmp_path))
        warm = sweep(pts, saxpy_kernels, max_workers=1, composite=False,
                     cache=c2)
        assert c2.pallas_hits == 1 and c2.pallas_misses == 0
        assert c2.hits == 2 and c2.misses == 0
        # the cached class payload reproduces the walltime columns and
        # the deterministic compile-cache meta exactly
        assert warm.meta["pallas"] == cold.meta["pallas"]
        for a, b in zip(cold.records, warm.records):
            assert a.kernels["saxpy"]["pallas_walltime_s"] == \
                b.kernels["saxpy"]["pallas_walltime_s"]
            assert a.kernels["saxpy"]["pallas_calls"] == \
                b.kernels["saxpy"]["pallas_calls"]
        assert warm.canonical_json() == cold.canonical_json()

    def test_point_records_persist_without_pallas_columns(self, tmp_path):
        # pallas columns attach in the parent AFTER the point record is
        # stored: a later non-pallas sweep must not inherit them
        pts = [DesignPoint("shared", 1, 1, 4, measure_pallas=True)]
        c1 = PointCache(cache_dir=str(tmp_path))
        sweep(pts, saxpy_kernels, max_workers=1, composite=False,
              cache=c1)
        c2 = PointCache(cache_dir=str(tmp_path))
        plain = sweep([DesignPoint("shared", 1, 1, 4)], saxpy_kernels,
                      max_workers=1, composite=False, cache=c2)
        assert c2.hits == 1
        assert "pallas_calls" not in plain.records[0].kernels["saxpy"]


# ---------------------------------------------------------------------------
# Auto executor selection
# ---------------------------------------------------------------------------


class TestAutoExecutor:
    def test_resolve_auto_mapping(self):
        assert resolve_auto("auto", 0) == "serial"
        assert resolve_auto("auto", AUTO_SERIAL_MAX - 1) == "serial"
        assert resolve_auto("auto", AUTO_SERIAL_MAX) == "process"
        # explicit specs are authoritative, None keeps legacy behavior
        assert resolve_auto("thread", 1000) == "thread"
        assert resolve_auto("serial", 1000) == "serial"
        assert resolve_auto(None, 1000) is None
        ex = SerialExecutor()
        assert resolve_auto(ex, 1000) is ex

    def test_warm_auto_sweep_runs_serially(self, tmp_path):
        cache = PointCache(cache_dir=str(tmp_path))
        sweep(SMALL_SPACE, small_kernels, max_workers=1, cache=cache)
        warm_cache = PointCache(cache_dir=str(tmp_path))
        res = sweep(SMALL_SPACE, small_kernels, max_workers=4,
                    cache=warm_cache, executor="auto")
        assert warm_cache.hits == N_SMALL
        assert res.meta["executor"] == "serial"

    def test_small_cold_auto_sweep_runs_serially(self):
        # 6 uncached points < AUTO_SERIAL_MAX: no spawn-pool startup
        res = sweep(SMALL_SPACE, small_kernels, max_workers=4,
                    executor="auto")
        assert res.meta["executor"] == "serial"

    def test_large_cold_auto_sweep_picks_process(self):
        pts = DesignSpace(lanes=(2, 4), precisions=(8, 16, 32)).points()
        assert len(pts) >= AUTO_SERIAL_MAX
        res = sweep(pts, small_kernels, max_workers=2,
                    executor="auto")
        assert res.meta["executor"] == "process"
