"""KVI IR construction + lowering unit tests (backend-independent)."""
import numpy as np
import pytest

from repro.configs.base import KlessydraConfig
from repro.core.isa import Instr, Scalar
from repro.kvi import (KviInstr, KviOp, KviProgramBuilder, Ref, lower)

CFG = KlessydraConfig("t", M=1, F=1, D=4, spm_kbytes=32)


def small_program():
    b = KviProgramBuilder("demo")
    h = b.mem_in("x", np.arange(16, dtype=np.int32))
    v = b.vreg("v", 16)
    w = b.vreg("w", 16)
    b.kmemld(v, h)
    b.scalar(3)
    b.ksvmulsc(w, v, scalar=2)
    b.kaddv(w, w, v)
    out = b.mem_out("y", 16)
    b.kmemstr(out, w)
    return b.build(alg_ops=32)


class TestBuilder:
    def test_program_shape(self):
        p = small_program()
        assert p.name == "demo"
        assert len(p.vregs) == 2 and len(p.mems) == 2
        assert [m.name for m in p.outputs] == ["y"]
        assert p.alg_ops == 32
        # Scalar(3) counts 3 instructions, the 4 KVI ops count 1 each
        assert p.n_instructions == 7

    def test_instrs_are_frozen(self):
        p = small_program()
        instr = [i for i in p.items if isinstance(i, KviInstr)][0]
        with pytest.raises(AttributeError):   # FrozenInstanceError
            instr.length = 99

    def test_unknown_length_mismatch_rejected(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        c = b.vreg("c", 4)
        with pytest.raises(ValueError):
            b.kaddv(a, a, c)

    def test_view_bounds_checked(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        with pytest.raises(IndexError):
            a.view(4, 8)

    def test_two_source_op_requires_src2(self):
        with pytest.raises(ValueError):
            KviInstr(KviOp.KADDV, dst=Ref("vreg", 0), src1=Ref("vreg", 1),
                     length=4)

    def test_reduction_dst_must_be_scalar_view(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        d = b.vreg("d", 8)
        with pytest.raises(ValueError):
            b.kdotp(d, a, a)          # dst view of length 8
        b.kdotp(d[3], a, a)           # single-element view is fine


class TestConstructionValidation:
    """Bad refs/views/names die at construction, naming the operand."""

    def test_negative_ref_offset_rejected(self):
        with pytest.raises(ValueError,
                           match="negative offset -1 in vreg operand #0"):
            Ref("vreg", 0, -1)

    def test_vreg_degenerate_length_rejected(self):
        b = KviProgramBuilder("bad")
        with pytest.raises(ValueError,
                           match=r"vreg 'a': length must be > 0, got 0"):
            b.vreg("a", 0)
        with pytest.raises(ValueError,
                           match=r"vreg 'a': length must be > 0, got -4"):
            b.vreg("a", -4)

    def test_vreg_elem_bytes_rejected(self):
        b = KviProgramBuilder("bad")
        with pytest.raises(ValueError, match=r"elem_bytes must be 1/2/4"):
            b.vreg("a", 8, elem_bytes=3)

    def test_view_negative_offset_rejected(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        with pytest.raises(ValueError,
                           match=r"view of vreg 'a': negative offset -2"):
            a.view(-2, 4)

    def test_view_degenerate_length_rejected(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        with pytest.raises(ValueError,
                           match=r"view of vreg 'a': length must be > 0"):
            a.view(0, 0)

    def test_view_oob_names_vreg(self):
        b = KviProgramBuilder("bad")
        a = b.vreg("a", 8)
        with pytest.raises(IndexError,
                           match=r"view \[4:12\) outside vreg 'a' of "
                                 r"length 8"):
            a.view(4, 8)

    def test_duplicate_vreg_name_rejected(self):
        b = KviProgramBuilder("dups")
        b.vreg("v", 8)
        with pytest.raises(ValueError) as ei:
            b.vreg("v", 16)
        assert str(ei.value) == "duplicate vreg name 'v' in program 'dups'"

    def test_duplicate_mem_name_rejected(self):
        b = KviProgramBuilder("dups")
        b.mem_in("x", np.arange(8, dtype=np.int32))
        with pytest.raises(ValueError) as ei:
            b.mem_out("x", 8)
        assert str(ei.value) == \
            "duplicate memory buffer name 'x' in program 'dups'"

    def test_vreg_and_mem_namespaces_are_separate(self):
        # stock matmul legitimately has both a mem "B" and a vreg "B"
        b = KviProgramBuilder("ok")
        b.mem_in("B", np.arange(8, dtype=np.int32))
        b.vreg("B", 8)                 # must not raise


class TestLowering:
    def test_trace_types_and_addresses(self):
        p = small_program()
        tr = lower(p, CFG)
        kinds = [type(i).__name__ for i in tr.items]
        assert kinds == ["Instr", "Scalar", "Instr", "Instr", "Instr"]
        ld, _, mul, add, stv = tr.items
        assert ld.op == "kmemld" and stv.op == "kmemstr"
        # v and w are distinct SPM allocations, line-aligned
        assert tr.vreg_addr[0] != tr.vreg_addr[1]
        assert mul.dst == tr.vreg_addr[1]
        assert add.src2 == tr.vreg_addr[0]

    def test_execute_matches_numpy(self):
        p = small_program()
        out = lower(p, CFG).execute()
        x = np.arange(16, dtype=np.int32)
        assert np.array_equal(out["y"], 3 * x)

    def test_view_offsets_lower_to_byte_addresses(self):
        b = KviProgramBuilder("views")
        v = b.vreg("v", 16)
        b.ksvaddsc(v.view(4, 8), v.view(0, 8), scalar=1)
        p = b.build()
        tr = lower(p, CFG)
        i = tr.items[0]
        assert i.dst == tr.vreg_addr[0] + 4 * 4
        assert i.src1 == tr.vreg_addr[0]

    def test_reduction_gets_rf_store(self):
        b = KviProgramBuilder("red")
        v = b.vreg("v", 8)
        acc = b.vreg("acc", 4)
        b.kdotp(acc[2], v, v)
        tr = lower(b.build(), CFG)
        i = tr.items[0]
        assert isinstance(i, Instr) and i.op == "kdotp"
        assert i.rf_store == (tr.vreg_addr[1], 2, 4)

    def test_scalar_blocks_become_scalars(self):
        b = KviProgramBuilder("s")
        v = b.vreg("v", 4)
        b.scalar(5)
        b.krelu(v, v)
        tr = lower(b.build(), CFG)
        assert isinstance(tr.items[0], Scalar) and tr.items[0].count == 5

    def test_legacy_builders_produce_identical_traces(self):
        """The core.programs shims must emit the same dynamic trace the
        pre-IR builders did (same cycle model => Table 2/3 unchanged)."""
        from repro.core.programs import build_conv2d
        rng = np.random.default_rng(0)
        img = rng.integers(-128, 128, (8, 8)).astype(np.int32)
        filt = rng.integers(-8, 8, (3, 3)).astype(np.int32)
        prog = build_conv2d(CFG, img, filt, shift=3)
        ops = [i.op for i in prog.items if isinstance(i, Instr)]
        # load, then per row: 9 muls + 8 adds + shift + store
        assert ops[0] == "kmemld"
        assert ops.count("ksvmulsc") == 8 * 9
        assert ops.count("kaddv") == 8 * 8
        assert ops.count("ksrav") == 8
        assert ops.count("kmemstr") == 8
        n_scalar = sum(i.count for i in prog.items if isinstance(i, Scalar))
        assert n_scalar == 40 + 8 * (6 + 9 * 3)
